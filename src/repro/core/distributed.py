"""Sharded multi-device batch execution: plan -> place -> gather.

The engine's heavy stages distribute along two orthogonal axes, both
provided here and both degrading to the identity on a single device (a
mesh of size 1 — or no mesh at all — runs exactly the single-device code):

  * **mesh-parallel index** -- the edge kernels (MS-BFS ``msbfs_dist`` /
    ``msbfs_set_dist``, ``walk_counts``) are pure pjit programs over the
    dst-sorted edge lists, so sharding the edge axis over a named 1-D mesh
    ("cells") and letting GSPMD partition the gather + segment-reduce is a
    placement decision: :func:`shard_graph_edges` re-pads the PR-4
    sentinel-pow2 buckets to a device-count-aligned capacity (a pow2
    bucket is already divisible by any pow2 device count) and
    ``device_put``\\ s them under a ``NamedSharding``. Results are
    bit-equal to single-device: the boolean-semiring ``segment_max`` is
    order-free and the walk-count ``segment_sum`` adds integer-valued
    float32s (exact below 2**24).

  * **cluster-parallel enumeration** -- sharing clusters are the natural
    data-parallel work unit (sharing graphs never cross clusters, per the
    paper's Ψ construction), so detected clusters are placed on
    per-device *engine replicas* by a greedy cost-balanced assignment
    (:func:`plan_clusters`; cluster cost ≈ Σ per-query hop budget ×
    frontier estimate from the already-built index) and executed
    concurrently, one worker thread per replica pinned with
    ``jax.default_device``. Per-device ``PathSet`` results and stats are
    gathered back into one ``BatchReport`` (``stats["per_device"]``).

A replica is a shallow engine clone owning device-local copies of the
``DeviceGraph`` views and its *own* ``SharedPathCache`` (the cache is not
thread-safe by design); ``BatchPathEngine.apply_delta`` fans every edge
delta out through :meth:`ShardedExecutor.propagate_delta`, so all replica
graphs patch in lockstep and all replica caches see the same hop-scoped
invalidation — and therefore the same epochs — as the primary.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

from .graph import DeviceGraph, Graph, pad_edge_list, pow2_ceil
from .query import midpoint_split
from ..obs import metrics as obsmetrics

__all__ = ["shard_edges", "distributed_graph", "shard_graph_edges",
           "resolve_mesh", "edge_bucket_for", "replicate_graph",
           "query_ball_cost", "cluster_costs", "plan_clusters",
           "ShardedExecutor"]

# every device-resident array field of a DeviceGraph (the placement unit)
_DG_ARRAYS = ("esrc", "edst", "ell_idx", "ell_mask",
              "r_esrc", "r_edst", "r_ell_idx", "r_ell_mask")


# ----------------------------------------------------------------------
# mesh resolution
# ----------------------------------------------------------------------
def resolve_mesh(mesh=None, n_devices: Optional[int] = None):
    """The mesh an engine executes on, or None for plain single-device.

    ``mesh`` wins when given (any ``jax.sharding.Mesh``; all axes are
    used). Otherwise ``n_devices >= 1`` builds a 1-D mesh named "cells"
    over the first N local devices — ``n_devices=1`` is a real (identity)
    mesh, so the sharded code path can be exercised on one device.
    ``None``/``0`` means no mesh.
    """
    if mesh is not None:
        return mesh
    if not n_devices:
        return None
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(f"n_devices={n_devices} but only {len(devs)} "
                         f"local devices are visible")
    return Mesh(np.array(devs[:int(n_devices)]), ("cells",))


def edge_bucket_for(m: int, n_dev: int) -> int:
    """Device-count-aligned edge capacity: the pow2 bucket of ``m``,
    grown to the next multiple of ``n_dev`` when the device count is not
    a power of two (for pow2 device counts the pow2 bucket is already
    divisible, so sharded and single-device shapes share warm compiles).
    """
    cap = max(pow2_ceil(max(int(m), 1)), int(n_dev))
    if cap % n_dev:
        cap = -(-cap // n_dev) * n_dev
    return cap


# ----------------------------------------------------------------------
# edge-list sharding (the GSPMD index layer)
# ----------------------------------------------------------------------
def shard_edges(esrc, edst, mesh, axes=None, *, n: int):
    """Place a dst-sorted edge list sharded over the mesh.

    Padding to a device multiple reuses the sentinel ``(n, n)`` pad from
    :func:`~repro.core.graph.pad_edge_list`: sentinel edges are dropped by
    every segment op and gather the zero sentinel row, so they are inert
    in both the boolean BFS semiring and the walk-count ``segment_sum``.
    (The earlier repeat-last-edge pad was only safe for ``segment_max`` —
    a repeated real edge double-counts in ``walk_counts`` unless masked.)
    ``n`` is the vertex count the sentinel encodes. Sentinel ``n`` sorts
    after every real destination, so the dst-sorted invariant survives.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    axes = tuple(mesh.axis_names) if axes is None else tuple(axes)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    m_cap = int(esrc.shape[0])
    cap = -(-m_cap // n_dev) * n_dev
    if cap > m_cap:
        esrc, edst = pad_edge_list(np.asarray(esrc), np.asarray(edst),
                                   n, cap)
    sh = NamedSharding(mesh, PartitionSpec(axes))
    return jax.device_put(esrc, sh), jax.device_put(edst, sh)


def shard_graph_edges(dg: DeviceGraph, mesh, axes=None) -> DeviceGraph:
    """A DeviceGraph whose edge lists are GSPMD-sharded over ``mesh``.

    Only the edge lists move — the ELL matrices (enumeration gathers) are
    untouched, because enumeration parallelism is cluster-level replica
    placement, not GSPMD. ``m`` stays the valid edge count: any pad added
    here is capacity, not edges.
    """
    esrc, edst = shard_edges(dg.esrc, dg.edst, mesh, axes, n=dg.n)
    r_esrc, r_edst = shard_edges(dg.r_esrc, dg.r_edst, mesh, axes, n=dg.n)
    return dataclasses.replace(dg, esrc=esrc, edst=edst,
                               r_esrc=r_esrc, r_edst=r_edst)


def distributed_graph(g: Graph, mesh, axes=None) -> DeviceGraph:
    """DeviceGraph built straight into the sharded-edge layout (ELL
    replicated on the default device; suitable for graphs whose
    index-pruned ELL fits per device)."""
    n_dev = int(np.prod(list(mesh.shape.values())))
    dg = DeviceGraph.build(g, edge_cap=edge_bucket_for(g.m, n_dev))
    return shard_graph_edges(dg, mesh, axes)


def replicate_graph(dg: DeviceGraph, device) -> DeviceGraph:
    """Device-local copy of every DeviceGraph array (committed to
    ``device``), for a cluster-enumeration replica."""
    import jax

    return dataclasses.replace(dg, **{f: jax.device_put(getattr(dg, f),
                                                        device)
                                      for f in _DG_ARRAYS})


# ----------------------------------------------------------------------
# cluster placement (the data-parallel enumeration layer)
# ----------------------------------------------------------------------
def query_ball_cost(index, qi: int, dists: tuple) -> float:
    """Estimated enumeration cost of one query:
    ``k × (|ball_a(s)| + |ball_b(t)|)``, where the balls count vertices
    within the midpoint-split hop budgets of each endpoint — a
    frontier-size estimate read straight from the index distance
    matrices (``dists`` = host ``(dist_s, dist_t)``, sentinel row
    included; sliced off here). The shared per-query term of both LPT
    placement (:func:`cluster_costs`) and GREEN/YELLOW/RED routing
    (:class:`repro.core.planner.CostRouter`). Deliberately cheap:
    callers need relative weight, not the exact DP bound.
    """
    ds, dt = dists[0][:-1], dists[1][:-1]
    _, _, k = index.queries[qi]
    a, b = midpoint_split(k)
    ball = int((ds[:, index.src_col[qi]] <= a).sum()) \
        + int((dt[:, index.tgt_col[qi]] <= b).sum())
    return float(k) * float(ball)


def cluster_costs(index, clusters: Sequence[Sequence[int]],
                  dists: Optional[tuple] = None) -> list[float]:
    """Estimated enumeration cost per cluster:
    ``cost(C) = Σ_{q ∈ C} query_ball_cost(q)``.

    ``dists`` is the engine's host memo ``(dist_s, dist_t)``; pass it on
    every hot-path call — the ``dists is None`` fallback transfers both
    matrices device→host each time, which the
    ``host_dist_transfers_total`` counter makes visible (the streaming
    loop gates on it staying flat).
    """
    if dists is None:
        obsmetrics.registry().counter("host_dist_transfers_total",
                                      site="cluster_costs").inc()
        dists = (np.asarray(index.dist_s), np.asarray(index.dist_t))
    return [sum(query_ball_cost(index, qi, dists) for qi in cl)
            for cl in clusters]


def plan_clusters(costs: Sequence[float],
                  n_replicas: int) -> tuple[list[list[int]], list[float]]:
    """Greedy cost-balanced (LPT) assignment of clusters to replicas.

    Heaviest cluster first onto the least-loaded replica — the classic
    4/3-approximate makespan heuristic, matching the work-stealing
    scheduler's submit order. Returns ``(assignment, loads)`` where
    ``assignment[r]`` lists cluster indices (ascending, so execution
    order within a replica is deterministic) and ``loads[r]`` the summed
    cost. Handles every uneven shape: more clusters than replicas (some
    replicas take several), fewer (trailing replicas stay empty), zero
    clusters (all empty). Load ties break on assignment *count* (then
    replica id) rather than always replica 0, so zero-cost clusters
    spread round-robin instead of serializing on one replica.
    """
    n_replicas = max(int(n_replicas), 1)
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], i))
    assign: list[list[int]] = [[] for _ in range(n_replicas)]
    loads = [0.0] * n_replicas
    for ci in order:
        r = min(range(n_replicas),
                key=lambda i: (loads[i], len(assign[i]), i))
        assign[r].append(ci)
        loads[r] += costs[ci]
    for a in assign:
        a.sort()
    return assign, loads


# ----------------------------------------------------------------------
# the executor: one code path for 1..D devices
# ----------------------------------------------------------------------
class ShardedExecutor:
    """Plan → place → gather for one engine.

    Owns (a) the GSPMD-sharded edge view the index kernels sweep
    (``index_dg``) and (b) the per-device engine replicas that enumerate
    clusters. Built by ``BatchPathEngine.__init__`` for *every* engine:
    with no mesh (or a 1-device mesh) ``index_dg is engine.dg``, the only
    replica is the engine itself, and :meth:`run_clusters` is the plain
    sequential loop — sharded and single-device execution share this one
    code path.
    """

    def __init__(self, engine, mesh=None, axes=None):
        self.engine = engine
        self.mesh = mesh
        self.axes = None if mesh is None else \
            (tuple(axes) if axes is not None else tuple(mesh.axis_names))
        if mesh is None:
            self.devices = [None]        # None = the default device
        else:
            self.devices = list(np.asarray(mesh.devices).ravel())
        self._replicas: Optional[list] = None
        self.in_fanout = False       # True while replica threads run —
        # replica 0 (the engine) must then plan on local, not mesh, views
        self.index_dg: DeviceGraph = engine.dg
        self.refresh_index_graph()

    # -- topology ------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.devices)

    @property
    def sharded(self) -> bool:
        return self.n_replicas > 1

    # -- graph lifecycle ----------------------------------------------
    def refresh_index_graph(self) -> None:
        """(Re)shard the engine's edge lists for the GSPMD index kernels.
        Identity without a mesh. Called after every graph mutation; the
        sharded copy keeps the engine's (monotone) edge bucket, so
        in-bucket churn re-lands in the same traced shapes."""
        if self.mesh is None:
            self.index_dg = self.engine.dg
        else:
            self.index_dg = shard_graph_edges(self.engine.dg, self.mesh,
                                              self.axes)

    def reset(self) -> None:
        """Wholesale graph swap: drop replicas (they rebuild lazily from
        the new graph) and reshard the index view."""
        self._replicas = None
        self.refresh_index_graph()

    def propagate_delta(self, applied) -> None:
        """Patch every existing replica's device views for one merged
        delta (same ``update_device_graph`` semantics as the primary) and
        reshard the index view. Replica caches are NOT touched here —
        ``BatchPathEngine._invalidate_for`` invalidates all caches with
        one shared distance sweep *before* any device view changes, which
        is what keeps the epochs identical across replicas."""
        import jax
        from .delta import update_device_graph

        if self._replicas is not None:
            for rep, dev in zip(self._replicas[1:], self.devices[1:]):
                with jax.default_device(dev):
                    new_dg, _ = update_device_graph(rep.dg, applied)
                rep.dg = replicate_graph(new_dg, dev)
                rep.g = applied.graph
                rep._host_dists = None
        self.refresh_index_graph()

    # -- replicas ------------------------------------------------------
    def replica_caches(self) -> list:
        """The caches of every *materialized* secondary replica (lazily
        created replicas sync their epoch at birth instead)."""
        if self._replicas is None:
            return []
        return [r.cache for r in self._replicas[1:] if r.cache is not None]

    def replicas(self) -> list:
        """All replicas, replica 0 being the engine itself; secondaries
        are created on first use (one device-local DeviceGraph copy and a
        fresh, epoch-synced SharedPathCache each)."""
        if self._replicas is None:
            self._replicas = [self.engine]
            for dev in self.devices[1:]:
                self._replicas.append(self._clone(dev))
        return self._replicas

    def _clone(self, device):
        import copy
        from .cache import SharedPathCache

        eng = self.engine
        rep = copy.copy(eng)
        rep.executor = None          # replicas are leaves: never re-fan-out
        rep.dg = replicate_graph(eng.dg, device)
        rep._host_dists = None
        rep.cache = None
        if eng.cache is not None:
            rep.cache = SharedPathCache(eng.cache.budget_bytes)
            rep.cache.epoch = eng.cache.epoch   # lockstep from birth
        return rep

    # -- execution -----------------------------------------------------
    def run_clusters(self, queries, index, plus: bool, min_sb: int,
                     clusters: list[list[int]], stats: dict,
                     planners: Optional[Sequence[str]] = None) -> dict:
        """Execute every sharing cluster, gathering ``{qi: QueryResult}``.

        One replica (or a single cluster): the inline sequential loop —
        byte-for-byte the single-device engine. Several: clusters are
        cost-balanced onto replicas and executed by one pinned worker
        thread per replica; per-replica stats land in
        ``stats["per_device"]``. ``planners`` (one ``"batch"``/``"basic"``
        entry per cluster, from the cost router) picks the per-cluster
        plan — ``"basic"`` runs the direct per-query path with no Ψ
        detection; ``None`` means batch everywhere. Results are exact
        either way, so the gather is a plain dict merge.
        """
        eng = self.engine

        def cluster_fn(engine, ci: int):
            if planners is not None and planners[ci] == "basic":
                return engine._cluster_basic
            return engine._cluster_work

        if not self.sharded or len(clusters) <= 1:
            results: dict = {}
            for ci, cluster in enumerate(clusters):
                out, cstats = cluster_fn(eng, ci)(queries, index, plus,
                                                  min_sb, cluster)
                results.update(out)
                _merge_stats(stats, cstats)
            return results

        reps = self.replicas()
        dists = eng._dists_host(index)
        costs = cluster_costs(index, clusters, dists=dists)
        assign, loads = plan_clusters(costs, len(reps))
        for rep in reps[1:]:
            rep._host_dists = eng._host_dists   # share the memo, read-only

        outs: list[dict] = [{} for _ in reps]
        cstats_all: list[list[dict]] = [[] for _ in reps]
        walls = [0.0] * len(reps)
        errs: list = [None] * len(reps)

        def work(ri: int) -> None:
            import jax

            rep, dev = reps[ri], self.devices[ri]
            try:
                # replica spans are roots of their worker thread's stack
                # (thread-local nesting); the recorded trace shows each
                # replica's clusters on its own timeline row
                with eng.obs.span("replica.run", replica=ri,
                                  device=str(dev),
                                  n_clusters=len(assign[ri])) as sr:
                    # the fan-out path implies a real mesh, so dev is
                    # always a concrete device (the no-mesh executor
                    # never fans out)
                    with jax.default_device(dev):
                        for ci in assign[ri]:
                            out, cst = cluster_fn(rep, ci)(
                                queries, index, plus, min_sb, clusters[ci])
                            outs[ri].update(out)
                            cstats_all[ri].append(cst)
                walls[ri] = sr.duration
            except BaseException as e:  # noqa: BLE001 — re-raised below
                errs[ri] = e

        # one worker per replica, but never more RUNNING than the host
        # has cores: on real accelerators each replica owns its compute,
        # while on virtual (forced host) devices every replica shares the
        # same cores and oversubscription only adds contention — a
        # core-capped pool drains the replica queue at full tilt either
        # way (device pinning is per work item, not per pool thread)
        workers = max(1, min(len(reps), os.cpu_count() or 1))
        self.in_fanout = True
        try:
            with ThreadPoolExecutor(max_workers=workers,
                                    thread_name_prefix="hcsp-replica") as px:
                list(px.map(work, range(len(reps))))
        finally:
            self.in_fanout = False
        for e in errs:
            if e is not None:
                raise e

        results = {}
        for ri in range(len(reps)):
            results.update(outs[ri])
            for cst in cstats_all[ri]:
                _merge_stats(stats, cst)
        stats["n_devices"] = len(reps)
        stats["per_device"] = [
            {"device": str(self.devices[ri]),
             "n_clusters": len(assign[ri]),
             "n_queries": sum(len(clusters[ci]) for ci in assign[ri]),
             "cost": loads[ri],
             "t_wall_s": walls[ri],
             "cache_hits": sum(c.get("n_cache_hits", 0)
                               for c in cstats_all[ri])}
            for ri in range(len(reps))]
        return results


def _merge_stats(stats: dict, cstats: dict) -> None:
    """Accumulate one cluster's counters/timings into the run stats."""
    for key, val in cstats.items():
        stats[key] = stats.get(key, 0) + val
