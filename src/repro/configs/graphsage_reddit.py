"""graphsage-reddit [gnn] — 2L d=128 mean-agg, fanout 25-10 [arXiv:1706.02216]."""
from ..config import GNNConfig
from ._shapes import GNN_SHAPES as SHAPES  # noqa: F401

CONFIG = GNNConfig(name="graphsage-reddit", kind="graphsage", n_layers=2,
                   d_hidden=128, aggregator="mean", mlp_layers=1,
                   extras=(("sample_sizes", (25, 10)), ("n_classes", 41)))

REDUCED = GNNConfig(name="graphsage-reduced", kind="graphsage", n_layers=2,
                    d_hidden=16, aggregator="mean", mlp_layers=1,
                    extras=(("sample_sizes", (5, 3)), ("n_classes", 8)))

FAMILY = "gnn"
