"""Config system: model/arch configs, input shapes, run options.

Every assigned architecture gets a module in ``repro/configs/<id>.py``
defining ``CONFIG`` (the exact published config), ``REDUCED`` (a small
same-family config for CPU smoke tests) and its shape table. The launcher
resolves ``--arch <id> --shape <name>`` through ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["MoEConfig", "LMConfig", "GNNConfig", "RecsysConfig",
           "PathEngineConfig", "ShapeSpec", "RunOptions"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    head_dim: Optional[int] = None
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model FLOPs)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_ff_expert * self.moe.n_experts + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ffn = 3 * d * self.moe.d_ff_expert * self.moe.top_k + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                   # meshgraphnet | graphcast | schnet | graphsage
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"
    mlp_layers: int = 2
    extras: tuple = ()          # (key, value) pairs, hashable
    dtype: str = "float32"

    def extra(self, key: str, default: Any = None) -> Any:
        return dict(self.extras).get(key, default)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int
    tower_mlp: tuple[int, ...]
    interaction: str = "dot"
    n_users: int = 5_000_000
    n_items: int = 2_000_000
    n_user_hist: int = 20       # multi-hot history ids per user (EmbeddingBag)
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class PathEngineConfig:
    """The paper's engine as a dry-run 'architecture' (billion-scale spec)."""
    name: str
    n_vertices: int
    avg_degree: int
    n_queries: int
    k: int
    ell_cap: int = 64


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                   # train | prefill | decode | gnn_full | gnn_mini
                                # | gnn_mol | recsys_train | recsys_serve
                                # | recsys_retrieval | engine_batch
    dims: tuple                 # (key, value) pairs, hashable

    def dim(self, key: str, default=None):
        return dict(self.dims).get(key, default)


@dataclasses.dataclass(frozen=True)
class RunOptions:
    mesh: str = "pod"           # "pod" (16x16) | "multipod" (2x16x16) | "host"
    remat: bool = True
    seq_parallel: bool = True   # Megatron-SP residual stream (train/prefill)
    kernel_backend: str = "jnp"  # dry-run lowers jnp; TPU uses pallas
    loss_chunk: int = 512
    attn_chunk: int = 1024
    moe_groups: int = 16
    layer_group: int = 1
    grad_accum: int = 1
    cast_params_early: bool = False  # bf16-cast before scan: fsdp gathers move bf16
    remat_policy: str = "nothing"   # "nothing" | "dots" (save matmul outputs)
    serve_param_sharding: str = "2d"  # "2d" (fsdp x tp) | "tp_only" (replicated over data)
    kv_cache_dtype: str = "bf16"    # "bf16" | "f8" (float8_e4m3 quantized KV)
    engine_frontier_shard: str = "cells"  # "cells" | "split" (V->data, W->model)
    flash_decode: bool = False      # shard_map flash-decoding over seq-sharded KV
    use_ring_gnn: bool = True
    seed: int = 0
