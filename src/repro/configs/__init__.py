"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib

ARCHS = {
    # LM family
    "granite-8b": "granite_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2.5-14b": "qwen2_5_14b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    # GNN family
    "meshgraphnet": "meshgraphnet",
    "graphcast": "graphcast",
    "schnet": "schnet",
    "graphsage-reddit": "graphsage_reddit",
    # recsys
    "two-tower-retrieval": "two_tower_retrieval",
    # the paper's engine
    "path-engine": "path_engine",
}

ASSIGNED = [a for a in ARCHS if a != "path-engine"]


def get(arch: str):
    """Returns the arch module (CONFIG, REDUCED, SHAPES, FAMILY)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def shapes_for(arch: str):
    return get(arch).SHAPES
