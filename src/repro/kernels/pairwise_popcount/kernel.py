"""Pallas kernel: all-pairs popcount(AND) over packed bitmaps.

    out[i, j] = sum_w popcount(a[i, w] & a[j, w])

This is the query-similarity hot spot (Def 4.5): |Γ(q_A) ∩ Γ(q_B)| for all
query pairs. Hash-set intersection (the paper's CPU form) becomes a dense
bit-parallel reduction: 32 vertices per word, VPU popcount, O(Q² · V/32).

Tiling: grid = (i blocks, j blocks, word blocks); each program accumulates
a (BQ, BQ) int32 tile over its word slice. VMEM per program:
2 * BQ * BW * 4B + BQ² * 4B (e.g. BQ=128, BW=512 -> 0.5 MB + 64 KB).
The word axis is innermost so the output tile stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_popcount_pallas"]


def _kernel(a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                            # (BQ, BW) uint32
    b = b_ref[...]                            # (BQ, BW) uint32
    inter = jax.lax.population_count(a[:, None, :] & b[None, :, :])
    out_ref[...] += jnp.sum(inter.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_q", "block_w", "interpret"))
def pairwise_popcount_pallas(words: jax.Array, *, block_q: int = 128,
                             block_w: int = 512,
                             interpret: bool = False) -> jax.Array:
    """words: (Q, W) uint32 packed bitmaps -> (Q, Q) int32 intersections."""
    Q, W = words.shape
    bq = min(block_q, Q)
    bw = min(block_w, W)
    grid = (pl.cdiv(Q, bq), pl.cdiv(Q, bq), pl.cdiv(W, bw))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bq, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, Q), jnp.int32),
        interpret=interpret,
    )(words, words)
