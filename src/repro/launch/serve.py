"""Batch query serving driver (the paper's deployment shape).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --queries 64 \
        --similarity 0.6 --groups 2

Builds a graph, spins the cluster scheduler over `groups` replica groups
(simulated on this host; each group is a mesh data-slice in production),
and serves batches with BatchEnum + work stealing. Reports per-batch
latency, sharing stats, and validates a result sample against the oracle.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import BatchPathEngine, EngineConfig, build_index
from ..core import generators
from ..core.clustering import cluster_queries
from ..core.similarity import similarity_matrix
from ..ft.scheduler import WorkStealingScheduler

__all__ = ["serve_batch"]


def serve_batch(engine: BatchPathEngine, queries, n_groups: int = 2,
                gamma: float = 0.5):
    """Cluster -> schedule -> process with stealing. Returns (results, info)."""
    index = build_index(engine.dg, queries)
    mu = similarity_matrix(index, backend=engine.cfg.backend)
    clusters = cluster_queries(mu, gamma)
    sched = WorkStealingScheduler(n_groups,
                                  cost_fn=lambda qs: float(len(qs)) ** 1.5)
    sched.submit(clusters)
    results = {}
    t0 = time.perf_counter()
    while sched.pending():
        for grp in range(n_groups):
            item = sched.next_for(grp)
            if item is None:
                continue
            sub = [queries[qi] for qi in item.queries]
            r = engine.process(sub, mode="batch")
            for i, qi in enumerate(item.queries):
                results[qi] = r.paths[i]
            sched.complete(item.cluster_id, True)
    wall = time.perf_counter() - t0
    return results, {"wall_s": wall, "n_clusters": len(clusters),
                     "steals": sched.steals,
                     "mu_mean": float((mu.sum() - len(queries))
                                      / max(len(queries) * (len(queries) - 1), 1))}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--similarity", type=float, default=0.6)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--k-min", type=int, default=4)
    ap.add_argument("--k-max", type=int, default=5)
    ap.add_argument("--validate", type=int, default=3)
    args = ap.parse_args()

    g = generators.community(args.n, n_comm=max(4, args.n // 2500),
                             avg_deg=6.0, seed=0)
    engine = BatchPathEngine(g, EngineConfig(min_cap=128))
    queries = generators.similar_queries(g, args.queries, args.similarity,
                                         (args.k_min, args.k_max), seed=1)
    results, info = serve_batch(engine, queries, n_groups=args.groups)
    n_paths = sum(r.shape[0] for r in results.values())
    print(f"served {len(queries)} queries -> {n_paths} paths "
          f"in {info['wall_s']:.2f}s "
          f"({info['n_clusters']} clusters, {info['steals']} steals, "
          f"mu={info['mu_mean']:.3f})")
    # oracle validation sample
    from ..core.oracle import enumerate_paths_bruteforce, path_set
    rng = np.random.default_rng(0)
    for qi in rng.choice(len(queries), size=min(args.validate, len(queries)),
                         replace=False):
        s, t, k = queries[qi]
        assert path_set(results[qi]) == \
            path_set(enumerate_paths_bruteforce(g, s, t, k))
    print(f"validated {args.validate} queries against the oracle: OK")


if __name__ == "__main__":
    main()
