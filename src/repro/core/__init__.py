"""Core: batch HC-s-t simple path query processing (the paper's contribution)."""
from .graph import Graph, DeviceGraph
from .delta import GraphDelta, AppliedDelta
from .cache import SharedPathCache
from .query import (PathQuery, QueryResult, BatchReport, Planner, Output,
                    QueryLike, ResultStatus)
from .engine import BatchPathEngine, EngineConfig, EngineOverflow, BatchResult
from .planner import CostEstimate, CostRouter, Route, RouterConfig
from .session import PathSession
from .index import build_index, QueryIndex
from .compilelog import CompileLog
from .distributed import ShardedExecutor
from . import compilelog, distributed, generators, oracle, planner

__all__ = ["Graph", "DeviceGraph", "GraphDelta", "AppliedDelta",
           "BatchPathEngine", "EngineConfig",
           "EngineOverflow", "BatchResult", "SharedPathCache",
           "PathQuery", "QueryResult", "BatchReport", "Planner", "Output",
           "QueryLike", "ResultStatus", "PathSession", "CompileLog",
           "ShardedExecutor",
           "CostEstimate", "CostRouter", "Route", "RouterConfig",
           "build_index", "QueryIndex", "compilelog", "distributed",
           "generators", "oracle", "planner"]
