"""The paper's engine as a dry-run architecture: billion-edge batch query
processing (TW/FS-scale spec from Table I) on the production mesh."""
from ..config import PathEngineConfig
from ._shapes import ENGINE_SHAPES as SHAPES  # noqa: F401

CONFIG = PathEngineConfig(name="path-engine", n_vertices=67_108_864,
                          avg_degree=16, n_queries=512, k=6, ell_cap=64)

REDUCED = PathEngineConfig(name="path-engine-reduced", n_vertices=4096,
                           avg_degree=6, n_queries=16, k=4, ell_cap=16)

FAMILY = "engine"
