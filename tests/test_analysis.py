"""repro.analysis: AST lint rules (RPL000-RPL006), waiver parsing, the
jaxpr audit self-tests, the committed dispatch budgets, and the int8
k_max guard (the static bound that replaced the silent runtime clamp)."""
import json
from pathlib import Path

import jax.numpy as jnp
import pytest

from repro.analysis import lint_source, lint_tree
from repro.analysis.astlint import iter_rule_ids
from repro.analysis.jaxpr_audit import (DEFAULT_BUDGETS_PATH, _check_budget,
                                        audit_traceable)
from repro.analysis.rules import parse_waivers

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"


def _rules(findings):
    return sorted(f.rule for f in findings)


class TestRules:
    """Each fixture trips its rule exactly once."""

    def test_rpl001_item_host_sync(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return x.sum().item()\n")
        fs = lint_source(src, "core/msbfs.py")
        assert _rules(fs) == ["RPL001"]
        assert fs[0].line == 4 and not fs[0].waived

    def test_rpl001_cast_on_traced_value(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return int(x.sum())\n")
        assert _rules(lint_source(src, "core/join.py")) == ["RPL001"]

    def test_rpl001_only_in_jit_reachable_code(self):
        # same sync in a function NOT reachable from any jit root: clean
        src = ("def host_helper(x):\n"
               "    return x.sum().item()\n")
        assert lint_source(src, "core/msbfs.py") == []

    def test_rpl001_not_applied_outside_hot_modules(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    return x.sum().item()\n")
        assert lint_source(src, "core/oracle.py") == []

    def test_rpl002_arm_import(self):
        src = "from ..kernels.msbfs_expand.ref import pack_bits\n"
        fs = lint_source(src, "core/engine.py")
        assert _rules(fs) == ["RPL002"]

    def test_rpl002_same_package_registration_allowed(self):
        src = ("from .ref import msbfs_step_ref\n"
               "from .kernel import msbfs_step_pallas\n")
        assert lint_source(src, "kernels/msbfs_expand/ops.py") == []

    def test_rpl003_undeclared_static_shape_arg(self):
        src = ("from functools import partial\n"
               "import jax\n"
               "@partial(jax.jit, static_argnames=('a_col',))\n"
               "def f(x, a_col, out_cap):\n"
               "    return x\n")
        fs = lint_source(src, "core/join.py")
        assert _rules(fs) == ["RPL003"]
        assert "out_cap" in fs[0].message

    def test_rpl004_python_loop_over_device_array(self):
        src = ("import jax\n"
               "import jax.numpy as jnp\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    xs = jnp.arange(4)\n"
               "    t = 0\n"
               "    for v in xs:\n"
               "        t = t + v\n"
               "    return t\n")
        assert _rules(lint_source(src, "core/enumerate.py")) == ["RPL004"]

    def test_rpl005_raw_pow2_shape_math(self):
        src = "def cap_for(k):\n    return 2 ** k\n"
        assert _rules(lint_source(src, "core/cache.py")) == ["RPL005"]

    def test_rpl005_exempt_in_graph_py(self):
        src = "def pow2_ceil(k):\n    return 2 ** k\n"
        assert lint_source(src, "core/graph.py") == []

    def test_rpl006_perf_counter_in_timed_module(self):
        src = ("import time\n"
               "def run_batch(qs):\n"
               "    t0 = time.perf_counter()\n"
               "    return t0\n")
        fs = lint_source(src, "core/engine.py")
        assert _rules(fs) == ["RPL006"]
        assert fs[0].line == 3 and not fs[0].waived

    def test_rpl006_bare_import_form(self):
        src = ("from time import perf_counter\n"
               "def admit(batch):\n"
               "    return perf_counter()\n")
        assert _rules(lint_source(src, "launch/serve.py")) == ["RPL006"]

    def test_rpl006_exempt_in_obs(self):
        # obs/ is the blessed definition site — the span implementation
        # necessarily reads the clock
        src = ("import time\n"
               "def now():\n"
               "    return time.perf_counter()\n")
        assert lint_source(src, "obs/trace.py") == []

    def test_rpl006_not_applied_outside_timed_modules(self):
        # ft/driver.py times external subprocess restarts, not pipeline
        # stages — deliberately off TIMED_MODULE_PATTERNS
        src = ("import time\n"
               "def wait(p):\n"
               "    return time.perf_counter()\n")
        assert lint_source(src, "ft/driver.py") == []
        assert lint_source(src, "launch/dryrun.py") == []

    def test_rpl006_waivable(self):
        src = ("import time\n"
               "def run(qs):\n"
               "    t0 = time.perf_counter()  "
               "# repro-lint: waive[RPL006] clock calibration, not a stage\n"
               "    return t0\n")
        fs = lint_source(src, "core/engine.py")
        assert len(fs) == 1 and fs[0].waived
        assert fs[0].waiver_reason == "clock calibration, not a stage"

    def test_rpl000_malformed_waiver(self):
        src = "x = 1  # repro-lint: waive[RPL999] not a known rule\n"
        assert _rules(lint_source(src, "core/cache.py")) == ["RPL000"]

    def test_rpl000_missing_reason(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return x.sum().item()  # repro-lint: waive[RPL001]\n")
        rules = _rules(lint_source(src, "core/msbfs.py"))
        assert "RPL000" in rules    # empty reason is itself a violation


class TestWaivers:
    def test_waiver_same_line(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return x.sum().item()  "
               "# repro-lint: waive[RPL001] epilogue sync, once per batch\n")
        fs = lint_source(src, "core/msbfs.py")
        assert len(fs) == 1 and fs[0].waived
        assert fs[0].waiver_reason == "epilogue sync, once per batch"

    def test_waiver_own_line_covers_next(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    # repro-lint: waive[RPL001] epilogue sync is intentional\n"
               "    return x.sum().item()\n")
        fs = lint_source(src, "core/msbfs.py")
        assert len(fs) == 1 and fs[0].waived

    def test_waiver_wrong_rule_does_not_apply(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return x.sum().item()  "
               "# repro-lint: waive[RPL004] wrong rule\n")
        fs = lint_source(src, "core/msbfs.py")
        assert len(fs) == 1 and not fs[0].waived

    def test_parse_waivers_ignores_docstrings(self):
        src = ('"""Docs mentioning waive[RPL001] syntax are not waivers."""\n'
               "x = 1\n")
        waivers, malformed = parse_waivers(src)
        assert waivers == {} and malformed == []


class TestRealTree:
    def test_lint_clean(self):
        report = lint_tree(SRC)
        assert report.n_files > 50
        assert report.ok, report.render()

    def test_cli_lint_exit_codes(self, tmp_path):
        from repro.analysis.__main__ import main
        mod = tmp_path / "core"
        mod.mkdir()
        (mod / "msbfs.py").write_text(
            "import jax\n@jax.jit\ndef f(x):\n    return x.sum().item()\n")
        assert main(["--lint", "--root", str(tmp_path)]) == 1
        (mod / "msbfs.py").write_text("def f(x):\n    return x\n")
        assert main(["--lint", "--root", str(tmp_path)]) == 0


class TestJaxprAudit:
    def test_seeded_item_detected(self):
        # the audit's reason for existing: a .item() smuggled into traced
        # code must surface as an audit/trace finding
        fs = audit_traceable(lambda x: x * x.sum().item(),
                             (jnp.ones((4,), jnp.float32),), name="seeded")
        assert [f.rule for f in fs] == ["audit/trace"]

    def test_clean_fn_passes(self):
        fs = audit_traceable(lambda x: x * x.sum(),
                             (jnp.ones((4,), jnp.float32),), name="clean")
        assert fs == []

    def test_budget_regression_detected(self):
        fs = _check_budget("f", "jnp", {"total_eqns": 10}, {"total_eqns": 5})
        assert len(fs) == 1 and "regressed" in fs[0].message
        assert _check_budget("f", "jnp", {"total_eqns": 5},
                             {"total_eqns": 5}) == []

    def test_missing_budget_is_a_finding(self):
        fs = _check_budget("f", "jnp", {"total_eqns": 10}, None)
        assert len(fs) == 1 and fs[0].rule == "audit/budget"

    def test_committed_budgets_exist_and_pin_fused_msbfs(self):
        budgets = json.loads((REPO / DEFAULT_BUDGETS_PATH).read_text())
        # satellite: the fused expand_level budget is committed
        assert "expand_level" in budgets
        # acceptance: the fused MS-BFS sweep stays at ONE kernel dispatch
        # per level on the kernel backend
        for fn in ("msbfs_dist_ell", "msbfs_set_dist_ell"):
            assert budgets[fn]["interpret"][
                "kernel_dispatches_per_level"] == 1

    @pytest.mark.slow
    def test_full_audit_clean(self):
        from repro.analysis.jaxpr_audit import run_audit
        report = run_audit(REPO / DEFAULT_BUDGETS_PATH)
        assert report.ok, report.render()


class TestKmaxGuard:
    """The int8 distance ceiling is a static precondition, not a clamp."""

    def test_out_of_range_k_max_raises(self):
        from repro.core.msbfs import K_MAX_INT8, msbfs_set_dist_ell
        n = 4
        ell = jnp.full((n + 1, 2), n, jnp.int32)
        seed = jnp.zeros((n + 1,), jnp.int8)
        with pytest.raises(ValueError) as exc:
            msbfs_set_dist_ell(ell, seed, n=n, k_max=K_MAX_INT8 + 1)
        msg = str(exc.value)
        assert f"k_max={K_MAX_INT8 + 1}" in msg
        assert "int8" in msg and "headroom" in msg

    def test_ceiling_leaves_sentinel_headroom(self):
        from repro.core.msbfs import INF_FOR, K_MAX_INT8
        assert INF_FOR(K_MAX_INT8) <= 127 - 6

    def test_in_range_k_max_accepted(self):
        from repro.core.msbfs import msbfs_set_dist_ell
        n = 4
        ell = jnp.full((n + 1, 2), n, jnp.int32)
        seed = jnp.zeros((n + 1,), jnp.int8).at[1].set(1)
        out = msbfs_set_dist_ell(ell, seed, n=n, k_max=3)
        assert out.shape == (n + 1,)

    def test_iter_rule_ids_helper(self):
        src = ("import jax\n"
               "@jax.jit\n"
               "def hot(x):\n"
               "    return x.sum().item()\n")
        assert iter_rule_ids(lint_source(src, "core/msbfs.py")) == {"RPL001"}
