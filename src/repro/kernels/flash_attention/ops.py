"""Public wrapper: GQA attention with backend switch.

Handles the GQA head expansion (q heads grouped onto kv heads) and the
(B, S, H, D) <-> (BH, S, D) layout so model code stays simple.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import resolve_backend
from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["gqa_attention"]


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, backend: str | None = None) -> jax.Array:
    """q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh), Hq % Hkv == 0.

    Returns (B, Sq, Hq, Dh).
    """
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    # expand kv heads to q heads (cheap views; XLA keeps them fused)
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    backend = resolve_backend(backend)
    if backend == "pallas":
        out = flash_attention_pallas(qf, kf, vf, causal=causal)
    elif backend == "interpret":
        out = flash_attention_pallas(qf, kf, vf, causal=causal, interpret=True)
    else:
        out = attention_ref(qf, kf, vf, causal=causal)
    return out.reshape(B, Hq, Sq, Dh).transpose(0, 2, 1, 3)
