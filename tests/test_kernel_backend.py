"""Unified kernel-backend API: registry semantics, fused-twin bit-equality,
and engine-level oracle exactness under interpret-mode dispatch."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core.graph import DeviceGraph, Graph
from repro.kernels.registry import (ENV_VAR, KernelBackend, dispatch,
                                    registered_ops, resolve_backend)


def _random_graph(n, avg_deg, seed):
    r = np.random.default_rng(seed)
    m = int(n * avg_deg)
    e = r.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    return Graph.from_edges(n, e[:, 0], e[:, 1])


class TestRegistry:
    def test_coerce_accepts_enum_and_strings(self):
        assert KernelBackend.coerce("pallas") is KernelBackend.PALLAS
        assert KernelBackend.coerce("INTERPRET") is KernelBackend.INTERPRET
        assert KernelBackend.coerce(KernelBackend.JNP) is KernelBackend.JNP

    def test_unknown_backend_raises_listing_valid(self):
        with pytest.raises(ValueError, match="pallas | interpret | jnp"):
            resolve_backend("palas")   # typo must not silently fall back

    def test_str_enum_compares_to_value(self):
        # call sites use plain string comparison on the static jit arg
        assert KernelBackend.INTERPRET == "interpret"
        assert str(KernelBackend.JNP) == "jnp"
        assert KernelBackend.PALLAS.uses_kernel
        assert KernelBackend.INTERPRET.uses_kernel
        assert not KernelBackend.JNP.uses_kernel

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "interpret")
        assert resolve_backend() is KernelBackend.INTERPRET
        # explicit beats env
        assert resolve_backend("jnp") is KernelBackend.JNP
        monkeypatch.setenv(ENV_VAR, "bogus")
        with pytest.raises(ValueError):
            resolve_backend()

    def test_auto_resolution_off_tpu(self, monkeypatch):
        monkeypatch.delenv(ENV_VAR, raising=False)
        import jax
        expect = (KernelBackend.PALLAS if jax.default_backend() == "tpu"
                  else KernelBackend.JNP)
        assert resolve_backend() is expect

    def test_dispatch_unknown_op(self):
        with pytest.raises(KeyError):
            dispatch("not_an_op", "jnp")

    def test_every_registered_op_dispatches(self):
        for name in registered_ops():
            for kb in KernelBackend:
                assert callable(dispatch(name, kb))


class TestEngineBackendConfig:
    def test_bogus_backend_raises_at_init(self):
        from repro.core.engine import BatchPathEngine, EngineConfig
        g = _random_graph(20, 3, 0)
        with pytest.raises(ValueError, match="valid backends"):
            BatchPathEngine(g, EngineConfig(kernel_backend="bogus"))

    def test_deprecated_backend_field_warns(self):
        from repro.core.engine import BatchPathEngine, EngineConfig
        g = _random_graph(20, 3, 0)
        with pytest.warns(DeprecationWarning, match="kernel_backend"):
            eng = BatchPathEngine(g, EngineConfig(backend="jnp"))
        assert eng.kernel_backend is KernelBackend.JNP

    def test_default_config_does_not_warn(self):
        from repro.core.engine import BatchPathEngine, EngineConfig
        g = _random_graph(20, 3, 0)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            BatchPathEngine(g, EngineConfig())

    def test_stats_record_backend(self):
        from repro.core.engine import BatchPathEngine, EngineConfig
        g = _random_graph(30, 3, 1)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              kernel_backend="interpret"))
        r = eng.run([(0, 5, 3)])
        assert r.stats["kernel_backend"] == "interpret"

    def test_session_kwarg_and_batch_log(self):
        from repro.core.engine import EngineConfig
        from repro.core.session import PathSession
        g = _random_graph(30, 3, 1)
        ses = PathSession(g, EngineConfig(min_cap=64),
                          kernel_backend="interpret")
        assert ses.kernel_backend == "interpret"
        ses.submit((0, 5, 3))
        ses.results()
        assert all(b["kernel_backend"] == "interpret"
                   for b in ses.batch_log)


class TestFusedStepParity:
    """msbfs_step: fused expand+dedup+distance-write vs its jnp twin."""

    @given(st.integers(4, 90), st.integers(1, 6), st.integers(1, 3),
           st.integers(0, 6))
    @settings(max_examples=10, deadline=None)
    def test_property(self, V, D, W, seed):
        from repro.kernels.msbfs_expand.ops import msbfs_step
        r = np.random.default_rng(seed)
        ell = jnp.asarray(r.integers(0, V + 1, (V, D)).astype(np.int32))
        fr = jnp.asarray(r.integers(0, 2**32, (V + 1, W), dtype=np.uint64)
                         .astype(np.uint32)).at[-1].set(0)
        vis = jnp.asarray(r.integers(0, 2**32, (V, W), dtype=np.uint64)
                          .astype(np.uint32))
        dist = jnp.asarray(r.integers(0, 9, (V, W * 32)).astype(np.int8))
        hop = int(r.integers(1, 8))
        a = msbfs_step(ell, fr, vis, dist, hop, backend="interpret")
        b = msbfs_step(ell, fr, vis, dist, hop, backend="jnp")
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    def test_all_sentinel_ell(self):
        # a fully padded ELL table (empty graph row bucket) expands nothing
        from repro.kernels.msbfs_expand.ops import msbfs_step
        V, W = 17, 2
        ell = jnp.full((V, 4), V, jnp.int32)
        fr = jnp.ones((V + 1, W), jnp.uint32).at[-1].set(0)
        vis = jnp.zeros((V, W), jnp.uint32)
        dist = jnp.full((V, W * 32), 9, jnp.int8)
        nf, nv, nd = msbfs_step(ell, fr, vis, dist, 1, backend="interpret")
        assert not np.asarray(nf).any()
        assert not np.asarray(nv).any()
        assert (np.asarray(nd) == 9).all()


class TestSweepParity:
    """Whole packed sweeps vs the segment-op reference on DeviceGraphs."""

    @given(st.integers(2, 120), st.floats(0.0, 5.0), st.integers(1, 40),
           st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_msbfs_dist_ell(self, n, avg_deg, S, seed):
        from repro.core.msbfs import edge_span, msbfs_dist, msbfs_dist_ell
        g = _random_graph(n, avg_deg, seed)
        dg = DeviceGraph.build(g)
        r = np.random.default_rng(seed)
        srcs = jnp.asarray(r.integers(0, n, S).astype(np.int32))
        mv = edge_span(dg.m, 1 << 22, dg.m_cap)
        for ell, es, ed in ((dg.r_ell_idx, dg.esrc, dg.edst),
                            (dg.ell_idx, dg.r_esrc, dg.r_edst)):
            ref = msbfs_dist(es, ed, srcs, n=dg.n, k_max=4, m_valid=mv)
            got = msbfs_dist_ell(ell, srcs, n=dg.n, k_max=4,
                                 backend="interpret")
            assert np.array_equal(np.asarray(ref), np.asarray(got))

    def test_set_dist_ell(self):
        from repro.core.msbfs import (edge_span, msbfs_set_dist,
                                      msbfs_set_dist_ell)
        g = _random_graph(64, 4, 7)
        dg = DeviceGraph.build(g)
        seed = np.zeros(dg.n + 1, np.int8)
        seed[[3, 9, 40]] = 1
        seed = jnp.asarray(seed)
        mv = edge_span(dg.m, 1 << 22, dg.m_cap)
        ref = msbfs_set_dist(dg.esrc, dg.edst, seed, n=dg.n, k_max=5,
                             m_valid=mv)
        got = msbfs_set_dist_ell(dg.r_ell_idx, seed, n=dg.n, k_max=5,
                                 backend="interpret")
        assert np.array_equal(np.asarray(ref), np.asarray(got))

    def test_empty_graph(self):
        from repro.core.msbfs import msbfs_dist, msbfs_dist_ell
        g = Graph.from_edges(5, np.empty(0, np.int32), np.empty(0, np.int32))
        dg = DeviceGraph.build(g)
        srcs = jnp.asarray(np.array([0, 3], np.int32))
        ref = msbfs_dist(dg.esrc, dg.edst, srcs, n=dg.n, k_max=3)
        got = msbfs_dist_ell(dg.r_ell_idx, srcs, n=dg.n, k_max=3,
                             backend="interpret")
        assert np.array_equal(np.asarray(ref), np.asarray(got))

    def test_walk_counts_ell(self):
        from repro.core.index import walk_counts, walk_counts_ell
        from repro.core.msbfs import edge_span
        g = _random_graph(80, 4, 11)
        dg = DeviceGraph.build(g)
        slack = np.full(dg.n + 1, 3, np.int8)
        slack[-1] = -1
        slack = jnp.asarray(slack)
        mv = edge_span(dg.m, 1 << 22, dg.m_cap)
        for ell, es, ed in ((dg.r_ell_idx, dg.esrc, dg.edst),
                            (dg.ell_idx, dg.r_esrc, dg.r_edst)):
            ref = walk_counts(es, ed, 0, slack, n=dg.n, budget=4, m_valid=mv)
            got = walk_counts_ell(ell, 0, slack, n=dg.n, budget=4,
                                  backend="interpret")
            # integer-valued f32, exact below 2**24
            assert np.array_equal(np.asarray(ref), np.asarray(got))


class TestJoinParity:
    """Row-aligned overlap join validity vs the dense _dup_mask route, on
    engine-realistic rows (each half individually simple)."""

    @staticmethod
    def _simple_rows(r, N, L, hi):
        rows = np.full((N, L), -1, np.int32)
        for i in range(N):
            rows[i] = r.choice(hi, size=L, replace=False)
        return rows

    @given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 4),
           st.integers(1, 4), st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_keyed_join(self, NA, NB, a_col, b_col, seed):
        from repro.core.join import keyed_join, sort_by_last
        r = np.random.default_rng(seed)
        A = self._simple_rows(r, NA, a_col + 1, 30)
        B = self._simple_rows(r, NB, b_col + 1, 30)
        a = sort_by_last(jnp.asarray(A), jnp.int32(NA), col=a_col)
        width = a_col + b_col + 1
        cap = 256
        pj = keyed_join(a, jnp.asarray(B), jnp.int32(NB), a_col=a_col,
                        b_col=b_col, out_cap=cap, out_width=width,
                        backend="jnp")
        pk = keyed_join(a, jnp.asarray(B), jnp.int32(NB), a_col=a_col,
                        b_col=b_col, out_cap=cap, out_width=width,
                        backend="interpret")
        assert int(pj.count) == int(pk.count)
        assert np.array_equal(np.asarray(pj.verts), np.asarray(pk.verts))

    @given(st.integers(1, 30), st.integers(1, 30), st.integers(0, 3),
           st.integers(0, 3), st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_cross_join(self, NP, NC, p_col, c_col, seed):
        from repro.core.join import cross_join
        r = np.random.default_rng(seed)
        P = self._simple_rows(r, NP, p_col + 1, 25)
        C = self._simple_rows(r, NC, c_col + 1, 25)
        width = p_col + c_col + 2
        pj = cross_join(jnp.asarray(P), jnp.int32(NP), jnp.asarray(C),
                        jnp.int32(NC), p_col=p_col, c_col=c_col,
                        out_cap=256, out_width=width, backend="jnp")
        pk = cross_join(jnp.asarray(P), jnp.int32(NP), jnp.asarray(C),
                        jnp.int32(NC), p_col=p_col, c_col=c_col,
                        out_cap=256, out_width=width, backend="interpret")
        assert int(pj.count) == int(pk.count)
        assert np.array_equal(np.asarray(pj.verts), np.asarray(pk.verts))

    @given(st.integers(1, 50), st.integers(1, 5), st.integers(1, 5),
           st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_rowwise_overlap_property(self, N, LA, LB, seed):
        from repro.kernels.path_join.ops import rowwise_overlap
        r = np.random.default_rng(seed)
        A = jnp.asarray(r.integers(-1, 12, (N, LA)).astype(np.int32))
        B = jnp.asarray(r.integers(-1, 12, (N, LB)).astype(np.int32))
        a = rowwise_overlap(A, B, backend="interpret")
        b = rowwise_overlap(A, B, backend="jnp")
        assert np.array_equal(np.asarray(a), np.asarray(b))

    @given(st.integers(1, 40), st.integers(1, 5), st.integers(1, 6),
           st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_path_member_property(self, N, L, D, seed):
        from repro.kernels.path_join.ops import path_member
        r = np.random.default_rng(seed)
        verts = jnp.asarray(r.integers(-1, 15, (N, L)).astype(np.int32))
        cand = jnp.asarray(r.integers(0, 16, (N, D)).astype(np.int32))
        a = path_member(verts, cand, backend="interpret")
        b = path_member(verts, cand, backend="jnp")
        assert np.array_equal(np.asarray(a), np.asarray(b))


class TestEngineOracle:
    """End-to-end: interpret dispatch must be oracle-exact and identical
    to the jnp engine on every planner."""

    @pytest.mark.parametrize("planner", ["basic", "basic+", "batch",
                                         "batch+", "pathenum"])
    def test_all_planners(self, planner):
        from repro.core.engine import BatchPathEngine, EngineConfig
        from repro.core.oracle import enumerate_paths_bruteforce, path_set
        g = _random_graph(48, 4, 13)
        qs = [(0, 7, 5), (1, 7, 4), (2, 9, 5)]
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              kernel_backend="interpret"))
        ref = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              kernel_backend="jnp"))
        ri = eng.run(qs, planner=planner)
        rj = ref.run(qs, planner=planner)
        for i, (s, t, k) in enumerate(qs):
            got = path_set(np.asarray(ri.results[i].paths))
            assert got == path_set(np.asarray(rj.results[i].paths))
            assert got == path_set(enumerate_paths_bruteforce(g, s, t, k))

    def test_similarity_backends_agree(self):
        from repro.core.engine import BatchPathEngine, EngineConfig
        from repro.core.index import build_index
        from repro.core.similarity import similarity_matrix
        g = _random_graph(60, 4, 17)
        eng = BatchPathEngine(g, EngineConfig())
        index = build_index(eng.dg, [(0, 7, 4), (1, 7, 4), (2, 9, 3)])
        a = similarity_matrix(index, backend="jnp")
        b = similarity_matrix(index, backend="interpret")
        np.testing.assert_allclose(a, b)
