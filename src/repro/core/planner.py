"""Cost-routed adaptive planning: GREEN / YELLOW / RED query tiers.

One global ``Planner`` flag leaves time on the table for mixed batches:
trivial queries (short hop budget, tiny frontier ball, exists-only) pay
the full similarity + detection + cache machinery whose overhead dwarfs
their enumeration, while genuinely heavy clusters are exactly where that
machinery — and sharded placement — earns its keep. This module routes
each query by a cost estimate read straight off the index distance
matrices (the same per-query term LPT placement already uses, see
:func:`repro.core.distributed.query_ball_cost`):

  * **GREEN**  -- direct bidirectional sweep off the shared index; skips
                  similarity, clustering, detection and the cross-batch
                  cache entirely. exists-only and index-unreachable
                  queries are answered by the index build itself (one
                  fused MS-BFS pass): ``dist_G(s, t) <= k`` iff a
                  ``<= k``-hop simple path exists, because shortest walks
                  are simple.
  * **YELLOW** -- the cached batch engine as today (cluster -> detect ->
                  shared enumeration -> ⊕ assembly).
  * **RED**    -- heavy clusters on a sharded engine: cost-balanced LPT
                  placement across the per-device replicas of
                  :class:`~repro.core.distributed.ShardedExecutor`.
                  Without a mesh the tier degrades to YELLOW (there is
                  nothing to place on).

The router also makes the *per-cluster* planner choice inside the batch
path: a cluster with nothing to share and no cache to consult runs the
direct per-query plan (:meth:`BatchPathEngine._cluster_basic`) instead of
paying Ψ detection — decided from the same cost model, not a global
``EngineConfig.planner`` flag. Every choice is exact either way; routing
may only change wall time, never results (the AUTO-vs-forced parity
tests pin this).

Estimation cost is one host pass over the already-memoized distance
matrices (``BatchPathEngine._dists_host``) — no device transfer, no
kernel launch; the ``route.estimate`` span and the
``routed_green|yellow|red`` counters make it observable.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

from .distributed import query_ball_cost
from .query import Output, PathQuery

__all__ = ["Route", "CostEstimate", "RouterConfig", "CostRouter",
           "admission_fast_path"]


class Route(enum.Enum):
    """Execution tier a query/cluster is routed to under ``Planner.AUTO``."""

    GREEN = "green"      # direct sweep: no clustering/detection/cache
    YELLOW = "yellow"    # cached batch engine (the default machinery)
    RED = "red"          # sharded fan-out via ShardedExecutor


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Per-query routing decision + the numbers that produced it.

    ``raw_cost`` is the unweighted enumeration estimate
    ``k × (|ball_a(s)| + |ball_b(t)|)`` shared with LPT placement;
    ``cost`` weights it by what the query actually asks for (exists-only
    is free — the index already holds the answer — count skips assembly,
    a ``limit`` caps the useful work). ``reachable`` is the index verdict
    ``dist_G(s, t) <= k``; unreachable queries cost nothing regardless of
    output kind because every planner would return an empty result.
    """

    qi: int
    cost: float
    raw_cost: float
    reachable: bool
    route: Route


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing thresholds and output-kind weights (engine-level knob:
    ``EngineConfig.router``). Defaults are deliberately coarse — the
    tiers only need to separate "overhead-dominated" from "enumeration-
    dominated" queries, not rank them precisely."""

    green_max_cost: float = 4096.0       # cost at or below: GREEN
    red_min_cost: float = float(1 << 22)  # cluster cost at or above: RED
    # (RED applies per *cluster*, post-clustering, and only on a mesh)
    count_weight: float = 0.5            # count-only: no ⊕ assembly rows
    limit_unit: float = 64.0             # est. cost per row a limit allows


class CostRouter:
    """Per-query cost estimation + tier routing + per-cluster planner
    choice, all from the index distance matrices."""

    def __init__(self, config: Optional[RouterConfig] = None):
        self.cfg = config or RouterConfig()

    def estimate(self, index, queries: Sequence[PathQuery],
                 dists: tuple) -> list[CostEstimate]:
        """One :class:`CostEstimate` per query.

        ``dists`` is the engine's host-dist memo ``(dist_s, dist_t)`` —
        required, never transferred here, so estimation costs one numpy
        pass however often the serving loop calls it.
        """
        ds = dists[0]
        cfg = self.cfg
        ests = []
        for qi, q in enumerate(queries):
            raw = query_ball_cost(index, qi, dists)
            reachable = int(ds[q.t, index.src_col[qi]]) <= q.k
            if not reachable or q.output is Output.EXISTS:
                # the index build already decided these: nothing to route
                cost = 0.0
            else:
                cost = raw * (cfg.count_weight
                              if q.output is Output.COUNT else 1.0)
                if q.limit is not None:
                    # early termination caps the useful work at ~limit rows
                    cost = min(cost, float(q.k) * q.limit * cfg.limit_unit)
            route = Route.GREEN if cost <= cfg.green_max_cost else Route.YELLOW
            ests.append(CostEstimate(qi=qi, cost=cost, raw_cost=raw,
                                     reachable=reachable, route=route))
        return ests

    def cluster_route(self, cluster: Sequence[int],
                      est_of: dict, sharded: bool) -> Route:
        """Tier of one post-clustering cluster: RED when its summed cost
        clears ``red_min_cost`` *and* a mesh exists to place it on;
        YELLOW otherwise (RED degrades to YELLOW on a single device)."""
        if sharded and sum(est_of[qi].cost for qi in cluster) \
                >= self.cfg.red_min_cost:
            return Route.RED
        return Route.YELLOW

    def cluster_planner(self, cluster: Sequence[int], est_of: dict,
                        has_cache: bool) -> str:
        """Per-cluster planner choice: ``"batch"`` (Ψ detection + shared
        enumeration + cache) or ``"basic"`` (direct per-query plan).

        A singleton cluster has nothing to share, so detection is pure
        bookkeeping — but with a cross-batch cache configured the batch
        plan still pays for itself through half-query hits, so only a
        cache-less singleton takes the direct plan. Both plans are exact;
        this choice can only move wall time.
        """
        if len(cluster) > 1 or has_cache:
            return "batch"
        return "basic"


def admission_fast_path(q: PathQuery) -> bool:
    """Pre-index GREEN predicate for streaming admission.

    True when the query is certain to route GREEN on *any* graph, so the
    server may answer it immediately instead of coalescing it into a
    micro-batch: exists-only queries always qualify (the index build
    answers them outright — estimation weights them to zero cost).
    Everything else depends on ball sizes admission cannot know without
    an index, so it waits for its micro-batch.
    """
    return q.output is Output.EXISTS
