"""Pallas kernel: path-pair overlap counting for the ⊕ join (Def 3.1).

    overlap[i, j] = #{ (p, q) : A[i, p] == B[j, q], A[i, p] >= 0 }

The enumeration hot spot (Fig 3c: join/scan dominates): joining forward and
backward half-paths requires, for every candidate pair, the simple-path
check "do the two halves share a vertex?". On CPU that is a hash probe per
pair; here it is a dense (BA, BB, LA, LB) equality reduction — regular,
vectorizable, and tiny in the L dimensions (L <= 9), so the VPU runs it at
full tilt. The wrapper derives join validity:

  keyed join  : valid = key match (last cols) & overlap == 1 (join vertex only)
  splice join : valid = overlap == 0 (prefix vs cached suffix are disjoint)

Tiling: grid = (A blocks, B blocks); each program owns a (BA, BB) int32
tile; A tile (BA, LA) and B tile (BB, LB) are VMEM-resident
(BA=BB=256, L=9 -> ~18 KB in, 256 KB out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["path_overlap_pallas", "rowwise_overlap_pallas",
           "path_member_pallas"]


def _kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                            # (BA, LA) int32
    b = b_ref[...]                            # (BB, LB) int32
    eq = (a[:, None, :, None] == b[None, :, None, :]) & (a >= 0)[:, None, :, None]
    out_ref[...] = jnp.sum(eq.astype(jnp.int32), axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def path_overlap_pallas(a_verts: jax.Array, b_verts: jax.Array,
                        *, block_a: int = 256, block_b: int = 256,
                        interpret: bool = False) -> jax.Array:
    """a_verts: (NA, LA), b_verts: (NB, LB) int32 (pad -1) -> (NA, NB) int32."""
    NA, LA = a_verts.shape
    NB, LB = b_verts.shape
    ba = min(block_a, NA)
    bb = min(block_b, NB)
    grid = (pl.cdiv(NA, ba), pl.cdiv(NB, bb))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, LA), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, LB), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ba, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((NA, NB), jnp.int32),
        interpret=interpret,
    )(a_verts, b_verts)


def _rowwise_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                            # (BN, LA) int32
    b = b_ref[...]                            # (BN, LB) int32
    eq = (a[:, :, None] == b[:, None, :]) & (a >= 0)[:, :, None]
    out_ref[...] = jnp.sum(eq.astype(jnp.int32), axis=(1, 2),
                           keepdims=True)[:, :, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def rowwise_overlap_pallas(a_verts: jax.Array, b_verts: jax.Array,
                           *, block_n: int = 1024,
                           interpret: bool = False) -> jax.Array:
    """Row-aligned overlap counts for already-enumerated join pairs:

        out[i] = #{ (p, q) : A[i, p] == B[i, q], A[i, p] >= 0 }

    The join hot loop's shape: the searchsorted bucket enumeration (or the
    cross-join index split) has already paired row i of A with row i of B,
    so the dense (NA, NB) product of :func:`path_overlap_pallas` would be
    quadratic waste — this kernel fuses the per-pair simple-path check of
    one assembled join into a single dispatch over the pair buffer.

    a_verts: (N, LA), b_verts: (N, LB) int32 (pad -1) -> (N, 1) int32.
    """
    N, LA = a_verts.shape
    LB = b_verts.shape[1]
    bn = min(block_n, N)
    return pl.pallas_call(
        _rowwise_kernel,
        grid=(pl.cdiv(N, bn),),
        in_specs=[
            pl.BlockSpec((bn, LA), lambda i: (i, 0)),
            pl.BlockSpec((bn, LB), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        interpret=interpret,
    )(a_verts, b_verts)


def _member_kernel(v_ref, c_ref, out_ref):
    v = v_ref[...]                            # (BN, L)  path prefixes
    c = c_ref[...]                            # (BN, D)  candidate vertices
    eq = (c[:, :, None] == v[:, None, :])
    out_ref[...] = jnp.sum(eq.astype(jnp.int32), axis=2)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def path_member_pallas(verts: jax.Array, cand: jax.Array,
                       *, block_n: int = 512,
                       interpret: bool = False) -> jax.Array:
    """Per-candidate membership counts against the owning path prefix:

        out[i, d] = #{ p : cand[i, d] == verts[i, p] }

    The expand superstep's duplicate-vertex mask — every frontier path's D
    ELL neighbor candidates checked against its own L-vertex prefix in one
    dispatch. verts: (N, L), cand: (N, D) int32 -> (N, D) int32.
    """
    N, L = verts.shape
    D = cand.shape[1]
    bn = min(block_n, N)
    return pl.pallas_call(
        _member_kernel,
        grid=(pl.cdiv(N, bn),),
        in_specs=[
            pl.BlockSpec((bn, L), lambda i: (i, 0)),
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.int32),
        interpret=interpret,
    )(verts, cand)
