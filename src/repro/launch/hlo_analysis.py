"""HLO text analysis: trip-adjusted FLOPs and collective traffic.

XLA's cost_analysis() counts while (scan) bodies once; this module parses
the scheduled HLO, builds the computation call graph (fusions via
``calls=``, reductions via ``to_apply=``, loops via ``body=`` with
``backend_config known_trip_count``), counts dot FLOPs and collective
bytes per computation, and folds totals through the call graph with loop
multipliers. All figures are per device (SPMD module).

Collective traffic per op = max(result bytes, sum of operand bytes)
(covers both all-gather — big result — and reduce-scatter — big operand).
"""
from __future__ import annotations

import re

__all__ = ["analyze_hlo", "count_entry_ops", "count_eqns"]

DT = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
      "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
      "c64": 8, "c128": 16}
_SHAPE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
                    r"\[([0-9,]*)\]")
_COLL = re.compile(r"= \(?[\w\[\],{}/* ]*?\b"
                   r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                   r"collective-permute)\(")
_OP = re.compile(r"^(?:ROOT )?%([\w.\-]+) = (.+)$")
_TRIP = re.compile(r'known_trip_count\D+(\d+)')


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * DT[m.group(1)]
    return total


def _result_part(rhs: str) -> str:
    """The result type prefix of an op line (before the op name + '(')."""
    i = rhs.find("(")
    return rhs[:i] if i > 0 else rhs


# bookkeeping ops that are not device work: excluded from entry-op counts
_NON_WORK_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "token"}
_OP_NAME = re.compile(r"=\s*(?:\(?[\w\[\],{}/* ]*?\)?\s*)?([a-z][\w\-]*)\(")


def count_entry_ops(hlo: str) -> int:
    """Number of *work* ops in the ENTRY computation of an HLO module —
    a compiled-dispatch-count proxy (each fusion counts once; parameters,
    constants and tuple plumbing are excluded). Used by kernels_bench to
    compare the per-level op footprint of the jnp reference arm against
    the fused kernel arm's single dispatch.
    """
    in_entry = False
    count = 0
    for line in hlo.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            if in_entry:          # entry body ended at the next header
                break
            in_entry = line.startswith("ENTRY") and "{" in line
            continue
        if not in_entry:
            continue
        m = _OP_NAME.search(line)
        if m and m.group(1) not in _NON_WORK_OPS:
            count += 1
    return count


def count_eqns(jaxpr) -> int:
    """Equations in a jaxpr, recursing into sub-jaxprs (pjit/scan/cond)
    but treating a pallas_call as ONE equation — its body is a single
    fused device dispatch, which is exactly what we are counting.

    This is the pre-compile twin of :func:`count_entry_ops`: the jaxpr
    eqn count upper-bounds the dispatch footprint (XLA fusion can only
    shrink it), is deterministic across XLA versions, and is what the
    committed ``DISPATCH_BUDGETS.json`` baselines are expressed in.
    Shared by ``kernels_bench`` and ``repro.analysis.jaxpr_audit``.
    """
    total = 0
    for eqn in jaxpr.eqns:
        total += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for val in eqn.params.values():
            for v in (val if isinstance(val, (tuple, list)) else [val]):
                if hasattr(v, "jaxpr"):          # ClosedJaxpr
                    total += count_eqns(v.jaxpr)
                elif hasattr(v, "eqns"):         # raw Jaxpr
                    total += count_eqns(v)
    return total


def analyze_hlo(hlo: str) -> dict:
    # ---- split computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = re.match(r"^(?:ENTRY )?%([\w.\-]+) ", line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
                    entry_name = cur
            continue
        if cur is not None:
            comps[cur].append(line.strip())

    entry = None
    for name in comps:
        if comps[name] is comps.get("__entry__") and name != "__entry__":
            entry = name
    comps.pop("__entry__", None)

    flops: dict[str, float] = {}
    coll: dict[str, dict[str, float]] = {}
    children: dict[str, list[tuple[str, float]]] = {}

    for name, lines in comps.items():
        shapes: dict[str, str] = {}
        f = 0.0
        cl: dict[str, float] = {}
        ch: list[tuple[str, float]] = []
        for ln in lines:
            om = _OP.match(ln)
            if not om:
                continue
            sym, rhs = om.group(1), om.group(2)
            shapes[sym] = _result_part(rhs)
            # --- dot flops
            if " dot(" in ln or rhs.startswith("dot("):
                out_b = _SHAPE.findall(_result_part(rhs))
                out_n = 1
                for dt_, dims in out_b:
                    nn = 1
                    for d in dims.split(","):
                        if d:
                            nn *= int(d)
                    out_n *= nn if out_n == 1 else 1
                lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                ops = re.search(r"dot\(([^)]*)\)", ln)
                contract = 1
                if lhs_c and ops and lhs_c.group(1):
                    lhs_sym = ops.group(1).split(",")[0].strip().lstrip("%")
                    sm = _SHAPE.search(shapes.get(lhs_sym, ""))
                    if sm:
                        ldims = sm.group(2).split(",")
                        for ci in lhs_c.group(1).split(","):
                            if ci and int(ci) < len(ldims) and ldims[int(ci)]:
                                contract *= int(ldims[int(ci)])
                f += 2.0 * out_n * contract
            # --- collectives
            cm = _COLL.search(ln)
            if cm:
                kind = cm.group(1)
                res_b = _shape_bytes(_result_part(rhs))
                opm = re.search(r"\(([^)]*)\)", rhs[rhs.find("("):])
                op_b = 0
                if opm:
                    for o in opm.group(1).split(","):
                        o = o.strip().lstrip("%")
                        op_b += _shape_bytes(shapes.get(o, ""))
                cl[kind] = cl.get(kind, 0.0) + max(res_b, op_b)
            # --- calls
            if " while(" in ln:
                bm = re.search(r"body=%([\w.\-]+)", ln)
                tm = _TRIP.search(ln)
                trip = float(tm.group(1)) if tm else 1.0
                if bm:
                    ch.append((bm.group(1), trip))
            else:
                for pat in (r"calls=%([\w.\-]+)", r"to_apply=%([\w.\-]+)",
                            r"condition=%([\w.\-]+)"):
                    for mm in re.finditer(pat, ln):
                        ch.append((mm.group(1), 1.0))
        flops[name] = f
        coll[name] = cl
        children[name] = ch

    memo_f: dict[str, float] = {}
    memo_c: dict[str, dict[str, float]] = {}

    def fold(name: str, depth=0):
        if name in memo_f or depth > 64 or name not in comps:
            return memo_f.get(name, 0.0), memo_c.get(name, {})
        tf = flops.get(name, 0.0)
        tc = dict(coll.get(name, {}))
        for callee, mult in children.get(name, []):
            if callee == name:
                continue
            cf, cc = fold(callee, depth + 1)
            tf += mult * cf
            for k, v in cc.items():
                tc[k] = tc.get(k, 0.0) + mult * v
        memo_f[name] = tf
        memo_c[name] = tc
        return tf, tc

    tf, tc = fold(entry) if entry else (sum(flops.values()), {})
    return {
        "flops_per_device": tf,
        "collective_bytes_per_device": sum(tc.values()),
        "collective_by_kind": {k: v for k, v in sorted(tc.items())},
        "entry": entry,
    }
