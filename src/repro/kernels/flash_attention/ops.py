"""Public wrapper: GQA attention with backend switch.

Handles the GQA head expansion (q heads grouped onto kv heads) and the
(B, S, H, D) <-> (BH, S, D) layout so model code stays simple.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..registry import BackendLike, dispatch, register_op
from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["gqa_attention"]


register_op(
    "flash_attention",
    pallas=lambda q, k, v, causal: flash_attention_pallas(q, k, v,
                                                          causal=causal),
    interpret=lambda q, k, v, causal: flash_attention_pallas(
        q, k, v, causal=causal, interpret=True),
    jnp=lambda q, k, v, causal: attention_ref(q, k, v, causal=causal),
)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  backend: BackendLike = None) -> jax.Array:
    """q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh), Hq % Hkv == 0.

    Returns (B, Sq, Hq, Dh).
    """
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    # expand kv heads to q heads (cheap views; XLA keeps them fused)
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    out = dispatch("flash_attention", backend)(qf, kf, vf, causal)
    return out.reshape(B, Hq, Sq, Dh).transpose(0, 2, 1, 3)
