"""Fault tolerance: checkpoint atomicity, exact resume, failure injection,
work-stealing scheduler, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.ft import DriverConfig, FailureInjector, TrainDriver
from repro.ft.scheduler import WorkStealingScheduler


def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))}
    return params


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.int32)}}
        save_checkpoint(tmp_path, 5, tree, extra={"note": "x"})
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        got, step, extra = restore_checkpoint(tmp_path, abstract)
        assert step == 5 and extra == {"note": "x"}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial(self, tmp_path):
        tree = {"a": jnp.ones((3,))}
        save_checkpoint(tmp_path, 1, tree)
        # simulate a crashed partial write
        bad = tmp_path / "step_2.tmp"
        bad.mkdir()
        (bad / "garbage.npy").write_bytes(b"xx")
        assert latest_step(tmp_path) == 1  # tmp dirs never count

    def test_gc_keeps_last(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        t = {"a": jnp.ones((2,))}
        for s in [1, 2, 3, 4]:
            mgr.save(s, t)
        steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir())
        assert steps == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_save=True)
        mgr.save(7, {"a": jnp.arange(4.0)})
        mgr.wait()
        assert latest_step(tmp_path) == 7

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 0, {"a": jnp.ones((3,))})
        with pytest.raises(ValueError):
            restore_checkpoint(tmp_path,
                               {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


def _make_driver(tmp_path, total=12, fail_at=None, ckpt_every=4):
    """Toy quadratic optimization driver with deterministic data."""
    from repro.optim import adamw_init, adamw_update

    def init_state():
        params = _toy_state()
        return params, adamw_init(params)

    @jax.jit
    def step_fn(params, opt_state, x, y):
        def loss_fn(p):
            pred = x @ p["w"] + p["b"]
            return jnp.mean((pred - y) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, m = adamw_update(grads, opt_state, params,
                                            lr=5e-2, weight_decay=0.0)
        return params, opt_state, {"loss": loss, **m}

    def batch_fn(step):
        r = np.random.default_rng(step)
        x = jnp.asarray(r.standard_normal((16, 8)).astype(np.float32))
        return x, jnp.asarray((np.asarray(x) @ np.eye(8)).astype(np.float32))

    cfg = DriverConfig(total_steps=total, ckpt_dir=str(tmp_path),
                       ckpt_every=ckpt_every, async_save=False)
    return TrainDriver(cfg, step_fn, init_state, batch_fn,
                       injector=FailureInjector(fail_at))


class TestDriver:
    def test_loss_decreases(self, tmp_path):
        out = _make_driver(tmp_path / "a", total=30).run()
        losses = [h["loss"] for h in out["history"]]
        assert np.mean(losses[-5:]) < np.mean(losses[:5])

    def test_crash_resume_is_exact(self, tmp_path):
        # uninterrupted reference
        ref = _make_driver(tmp_path / "ref").run()
        # crashed run: fails at step 9, restart resumes from step 7 ckpt
        d1 = _make_driver(tmp_path / "crash", fail_at=9)
        with pytest.raises(RuntimeError, match="injected failure"):
            d1.run()
        d2 = _make_driver(tmp_path / "crash")
        out = d2.run()
        ref_by_step = {h["step"]: h["loss"] for h in ref["history"]}
        for h in out["history"]:
            assert h["loss"] == pytest.approx(ref_by_step[h["step"]],
                                              rel=1e-6), h
        # final params identical
        for a, b in zip(jax.tree.leaves(ref["params"]),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_straggler_detection(self, tmp_path):
        d = _make_driver(tmp_path / "s", total=8)
        import time as _t
        orig = d.step_fn

        calls = {"n": 0}

        def slow_step(*a):
            calls["n"] += 1
            if calls["n"] == 7:
                _t.sleep(0.5)
            return orig(*a)

        d.step_fn = slow_step
        out = d.run()
        assert 6 in out["stragglers"]


class TestScheduler:
    def test_balanced_assignment_and_steal(self):
        sched = WorkStealingScheduler(n_groups=2)
        sched.submit([[1] * c for c in [8, 1, 1, 1, 1]])
        # group with the big cluster gets it alone; other gets the rest
        g0 = sum(i.cost for i in sched.queues[0])
        g1 = sum(i.cost for i in sched.queues[1])
        assert {g0, g1} == {8.0, 4.0}
        # drain group that has small items, then steal from the loaded one
        light = 0 if g0 < g1 else 1
        for _ in range(4):
            it = sched.next_for(light)
            sched.complete(it.cluster_id, "ok")
        it = sched.next_for(light)
        assert it is not None
        assert sched.steals == 1

    def test_failure_requeues_in_flight(self):
        sched = WorkStealingScheduler(n_groups=2)
        sched.submit([[1, 2], [3], [4]])
        it = sched.next_for(0)
        sched.fail_group(0, [it.cluster_id])
        assert sched.pending() == 3
        # the lost cluster is completable again
        seen = set()
        for g in [0, 1, 0, 1, 0, 1]:
            nxt = sched.next_for(g)
            if nxt:
                seen.add(nxt.cluster_id)
                sched.complete(nxt.cluster_id, "ok")
        assert it.cluster_id in seen

    def test_snapshot_restore(self, tmp_path):
        sched = WorkStealingScheduler(n_groups=2)
        sched.submit([[1], [2], [3]])
        it = sched.next_for(0)
        sched.complete(it.cluster_id, "done")
        it2 = sched.next_for(0)       # in flight at crash time
        sched.snapshot(tmp_path / "q.json")
        restored = WorkStealingScheduler.restore(tmp_path / "q.json", 2)
        assert it.cluster_id in restored.done
        assert restored.pending() == 2  # 1 queued + 1 requeued in-flight


class TestCompression:
    def test_int8_roundtrip_error_bound(self):
        from repro.optim.compress import compress_int8, decompress_int8
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((256,)).astype(np.float32)) * 3
        codes, scale = compress_int8(x)
        err = np.abs(np.asarray(decompress_int8(codes, scale) - x)).max()
        assert err <= float(scale) / 2 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        """EF compression: accumulated transmitted sum converges to the true
        gradient sum (the EF invariant: sum(sent) = sum(g) - final_error)."""
        from repro.optim.compress import ef_compressed_psum
        import jax
        from jax.sharding import Mesh
        try:
            from jax import shard_map
        except ImportError:          # jax < 0.5 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        r = np.random.default_rng(1)
        gs = [jnp.asarray(r.standard_normal(64).astype(np.float32)) * 10 ** (i % 3)
              for i in range(20)]
        err = jnp.zeros(64)
        sent_total = jnp.zeros(64)

        fn = shard_map(lambda g, e: ef_compressed_psum(g, e, "pod"),
                       mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        for g in gs:
            sent, err = fn(g, err)
            sent_total = sent_total + sent
        true_total = sum(np.asarray(g) for g in gs)
        np.testing.assert_allclose(np.asarray(sent_total + err), true_total,
                                   rtol=1e-4, atol=1e-3)
