"""Pure-jnp oracle for path-pair overlap counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["path_overlap_ref"]


def path_overlap_ref(a_verts: jax.Array, b_verts: jax.Array) -> jax.Array:
    eq = (a_verts[:, None, :, None] == b_verts[None, :, None, :])
    eq = eq & (a_verts >= 0)[:, None, :, None]
    return jnp.sum(eq.astype(jnp.int32), axis=(2, 3))
