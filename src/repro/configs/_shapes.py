"""Shared shape tables for the assigned architecture families."""
from ..config import ShapeSpec

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          (("seq_len", 4096), ("global_batch", 256))),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             (("seq_len", 32768), ("global_batch", 32))),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            (("seq_len", 32768), ("global_batch", 128))),
    "long_500k": ShapeSpec("long_500k", "decode",
                           (("seq_len", 524288), ("global_batch", 1))),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "gnn_full",
                               (("n_nodes", 2708), ("n_edges", 10556),
                                ("d_feat", 1433))),
    "minibatch_lg": ShapeSpec("minibatch_lg", "gnn_mini",
                              (("n_nodes", 232965), ("n_edges", 114615892),
                               ("batch_nodes", 1024), ("fanout", (15, 10)),
                               ("d_feat", 602))),
    "ogb_products": ShapeSpec("ogb_products", "gnn_full",
                              (("n_nodes", 2449029), ("n_edges", 61859140),
                               ("d_feat", 100))),
    "molecule": ShapeSpec("molecule", "gnn_mol",
                          (("n_nodes", 30), ("n_edges", 64), ("batch", 128))),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "recsys_train", (("batch", 65536),)),
    "serve_p99": ShapeSpec("serve_p99", "recsys_serve", (("batch", 512),)),
    "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve", (("batch", 262144),)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "recsys_retrieval",
                                (("batch", 1), ("n_candidates", 1_000_000),)),
}

ENGINE_SHAPES = {
    "batch_1b": ShapeSpec("batch_1b", "engine_batch",
                          (("n_vertices", 67_108_864), ("avg_degree", 16),
                           ("n_queries", 512), ("k", 6))),
}
