"""Exp-7 (Fig 13): average number of HC-s-t paths vs hop constraint k.

Paper claim: result counts grow exponentially with k.
"""
from __future__ import annotations

import numpy as np

from repro.core import BatchPathEngine, EngineConfig, Output, PathQuery
from repro.core import generators
from .common import default_graph, record


def main(scale: float = 1.0) -> list[dict]:
    g = default_graph(scale * 0.5, seed=10)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    rows = []
    prev = None
    for k in [3, 4, 5, 6]:
        qs = generators.random_queries(g, 12, (k, k), seed=20 + k)
        # count-only queries: the engine counts with reduction joins and
        # never assembles a path matrix (this figure only needs counts)
        res = eng.run([PathQuery(s, t, kk, output=Output.COUNT)
                       for s, t, kk in qs])
        counts = [res[i].count for i in range(len(qs))]
        avg = float(np.mean(counts))
        growth = (avg / prev) if prev else float("nan")
        prev = max(avg, 1e-9)
        rows.append(dict(k=k, avg_paths=avg, growth=growth))
        record(f"exp7_k{k}", avg, f"growth={growth:.2f}x")
    return rows


if __name__ == "__main__":
    main()
