"""Exp-11: open-loop SLO serving — arrival streams against StreamingServer.

The closed-loop experiments (exp8/exp12) submit a batch, drain it, and
measure the wall; that never shows what the admission layer is *for*.
This experiment replays deterministic open-loop arrival traces — Poisson
and bursty (2-state MMPP) processes, Zipf-skewed endpoints, three tenants
with different weights and deadlines, edge-churn ``GraphDelta``s
interleaved mid-stream — against the streaming server at several offered
loads, and reports per level

  * p50/p99/p99.9 end-to-end latency (queueing + service, one timeline),
  * goodput (completions that met their SLO per virtual second),
  * shed rate (overload sheds + deadline sheds) and deadline misses,
  * zero lost queries: every submitted qid resolves to exactly one
    ``QueryResult`` (OK or typed SHED — never silence).

A separate segment kills a replica group mid-batch through the
``fail_injector`` hook and asserts at-least-once recovery: the in-flight
cluster is requeued onto survivors, results land exactly once per query
id, a sample validates against the oracle, and the cross-batch
``SharedPathCache`` survives the failover.

Determinism (what makes the retrace gate CI-stable): the replay clock is
a :class:`ServiceModelClock` — a ``VirtualClock`` that charges one
*calibrated* batch quantum per engine dispatch instead of the real wall.
Admission boundaries, batch compositions, sheds, and therefore compiled
batch shapes are then identical across the warmup pass and the measured
pass (zero warm retraces), and identical across machines once latencies
are normalized by the quantum (the ``*_x`` fields). Real execution walls
are still measured per batch (``batch_wall_p50_s``) and feed the
hardware-relative latency tripwire.

``check_regression --serving`` gates the emitted BENCH_serving.json.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core import (BatchPathEngine, EngineConfig, GraphDelta,
                        PathQuery, compilelog, generators)
from repro.launch.serve import (AdmissionPolicy, GroupFailure,
                                StreamingServer, VirtualClock)
from repro.obs import metrics as obsmetrics
from .common import record

MAX_BATCH = 16
TENANTS = (          # (name, admission weight, deadline in batch quanta)
    ("gold", 4.0, 4.0),
    ("silver", 2.0, 10.0),
    ("bronze", 1.0, None),      # best-effort: no SLO, weight-1 fairness
)
TENANT_P = (0.25, 0.35, 0.40)
OUTPUT_MIX = (("paths", 0.60), ("count", 0.25), ("exists", 0.15))
# (arrival process, offered load as a multiple of calibrated capacity);
# the last level must overload the server so the shed path is exercised
LEVELS = (("poisson", 0.5), ("poisson", 1.0), ("mmpp", 3.0))


class ServiceModelClock(VirtualClock):
    """Virtual clock charging a calibrated affine cost per dispatch.

    ``StreamingServer`` charges the clock through ``advance_batch(wall,
    n_queries)`` after every batch (and fast-path dispatch); ignoring the
    noisy real wall in favor of the calibrated ``c0 + c1*Q`` model keeps
    the admission timeline — and therefore the sequence of compiled batch
    shapes — bit-identical across replays of the same trace. ``c0``/``c1``
    come from measured warm batch walls at two sizes, so the virtual
    timeline is still anchored to this machine's speed.
    """

    def __init__(self, c0_s: float, c1_s: float):
        super().__init__()
        self.c0_s, self.c1_s = float(c0_s), float(c1_s)
        self.dispatches = 0

    def advance_batch(self, dt: float, n_queries: int) -> None:
        del dt                              # model, not wall
        self.t += self.c0_s + self.c1_s * n_queries
        self.dispatches += 1


@dataclasses.dataclass
class _Event:
    t: float
    query: Optional[PathQuery] = None
    delta: Optional[GraphDelta] = None


# -- trace generation (all deterministic under one seed) -----------------

class _ZipfSampler:
    """Zipf-skewed vertex sampler: rank r gets mass 1/r^a over one fixed
    seeded permutation of the vertex set — the *same* hot vertices across
    every level and arrival, so the skew actually concentrates load (and
    warms the cross-batch cache) the way real tenant traffic does."""

    def __init__(self, n: int, seed: int, a: float = 1.05):
        ranks = np.arange(1, n + 1, dtype=np.float64)
        self.p = ranks ** -a
        self.p /= self.p.sum()
        self.perm = np.random.default_rng(seed).permutation(n)

    def draw(self, rng) -> int:
        return int(self.perm[rng.choice(len(self.perm), p=self.p)])


def _interarrivals(rng, kind: str, rate: float, n: int) -> np.ndarray:
    """n interarrival gaps for a Poisson process or a 2-state MMPP with
    the same long-run rate (slow state 0.4x, burst state 2.8x, mean
    dwell ~12 arrivals — bursty enough to spike the queue)."""
    if kind == "poisson":
        return rng.exponential(1.0 / rate, size=n)
    rates = (0.4 * rate, 2.8 * rate)
    state, gaps = 0, np.empty(n)
    for i in range(n):
        gaps[i] = rng.exponential(1.0 / rates[state])
        if rng.random() < 1.0 / 12.0:
            state = 1 - state
    return gaps


def _make_query(zipf: _ZipfSampler, rng,
                deadline_quanta_to_s: float) -> PathQuery:
    s, t = zipf.draw(rng), zipf.draw(rng)
    while t == s:
        t = zipf.draw(rng)
    k = int(rng.integers(3, 5))
    r = rng.random()
    acc = 0.0
    output = OUTPUT_MIX[-1][0]
    for name, pmass in OUTPUT_MIX:
        acc += pmass
        if r < acc:
            output = name
            break
    tenant, _, dl_quanta = TENANTS[rng.choice(len(TENANTS), p=TENANT_P)]
    deadline_s = (None if dl_quanta is None
                  else dl_quanta * deadline_quanta_to_s)
    return PathQuery(int(s), int(t), k, output=output,
                     tenant=tenant, deadline_s=deadline_s)


def _make_delta(g, rng, n_edges: int = 4) -> GraphDelta:
    """Balanced churn: n new random edges in, n original edges out.
    Re-applying the same delta is a no-op by construction (set
    semantics), so warmup and measured passes replay identical traces."""
    adds = []
    while len(adds) < n_edges:
        u, v = rng.integers(0, g.n, size=2)
        if u != v:
            adds.append((int(u), int(v)))
    eu = np.repeat(np.arange(g.n), np.diff(g.indptr))
    idx = rng.choice(len(g.indices), size=min(n_edges, len(g.indices)),
                     replace=False)
    dels = [(int(eu[i]), int(g.indices[i])) for i in idx]
    return GraphDelta.from_pairs(add=adds, remove=dels)


def _make_trace(g, zipf: _ZipfSampler, seed: int, kind: str, rate: float,
                n_arrivals: int, quantum_s: float,
                delta_every: int) -> list[_Event]:
    rng = np.random.default_rng(seed)
    times = np.cumsum(_interarrivals(rng, kind, rate, n_arrivals))
    events = [_Event(float(t), query=_make_query(zipf, rng, quantum_s))
              for t in times]
    for i in range(delta_every, n_arrivals, delta_every):
        events.append(_Event(float(times[i]), delta=_make_delta(g, rng)))
    events.sort(key=lambda e: e.t)
    return events


# -- replay driver -------------------------------------------------------

def _replay(engine, events: list[_Event], policy: AdmissionPolicy,
            cost: tuple[float, float], n_groups: int = 2,
            fail_injector=None, gamma=None):
    """Replay one trace open-loop; returns (server, arrivals, done times).

    Arrivals are stamped with their trace time (``submit(q, now=ev.t)``)
    even when the virtual clock has run ahead — an open-loop client does
    not wait for the server — and the clock never moves backwards.
    """
    clock = ServiceModelClock(*cost)
    srv = StreamingServer(engine, n_groups=n_groups, policy=policy,
                          planner="batch", warm_bias_eps=0.0, clock=clock,
                          gamma=gamma)
    srv.fail_injector = fail_injector
    arrival, done_t, pending = {}, {}, {}

    def _collect():
        for qid in srv.results:
            if qid not in done_t:
                done_t[qid] = clock()
            pending.pop(qid, None)

    # Event loop of the open-loop client: arrivals that occur while the
    # (virtual) server is busy accumulate in the queue — the clock steps
    # to whichever comes first, the next arrival or the oldest waiter's
    # max_delay expiry — so micro-batches coalesce exactly as they would
    # against a wall clock, instead of one pump per submit.
    i = 0
    while i < len(events) or pending:
        targets = []
        if i < len(events):
            targets.append(events[i].t)
        if pending:
            targets.append(min(pending.values())
                           + policy.max_delay_s + 1e-9)
        t = max(clock(), min(targets))
        while i < len(events) and events[i].t <= t:
            ev = events[i]
            i += 1
            if ev.delta is not None:
                srv.apply_delta(ev.delta)
                continue
            qid = srv.submit(ev.query, now=ev.t)
            arrival[qid] = ev.t
            if qid not in srv.results:     # fast path resolves at submit
                pending[qid] = ev.t
        clock.t = max(clock.t, t)
        srv.pump()
        _collect()
    srv.drain()
    _collect()
    return srv, arrival, done_t


def _quantiles(xs, qs=(50, 99, 99.9)):
    if len(xs) == 0:
        return [0.0] * len(qs)
    return [float(np.percentile(xs, q)) for q in qs]


def _run_level(engine, g, zipf, seed, kind, mult, capacity_qps, cost,
               n_arrivals, policy):
    """One offered-load level: identical warmup + measured replays."""
    quantum_s = cost[0] + MAX_BATCH * cost[1]
    events = _make_trace(g, zipf, seed, kind, mult * capacity_qps,
                         n_arrivals, quantum_s,
                         delta_every=max(24, n_arrivals // 8))
    # two warmup replays of the identical trace: the first pays compiles
    # with a cold cache and real delta churn; the second runs with the
    # cache fully populated and the (set-semantics) deltas now no-ops —
    # i.e. in exactly the steady state the measured pass replays, so the
    # measured pass cannot cross a new pad bucket
    _replay(engine, events, policy, cost)
    _replay(engine, events, policy, cost)
    clog = compilelog.active()
    csnap = clog.snapshot()
    msnap = obsmetrics.registry().snapshot()
    srv, arrival, done_t = _replay(engine, events, policy, cost)
    retraces = clog.retraces_since(csnap)
    window = obsmetrics.registry().since(msnap)

    queries = [ev for ev in events if ev.query is not None]
    results = {qid: srv.take(qid) for qid in list(srv.results)}
    n_lost = len(arrival) - len(results)
    ok = [qid for qid, r in results.items() if r.ok]
    shed = {qid: r for qid, r in results.items() if not r.ok}
    e2e = np.array([done_t[qid] - arrival[qid] for qid in ok])
    p50, p99, p999 = _quantiles(e2e)
    elapsed = max(srv.clock(), events[-1].t)
    good = len(ok) - srv.n_deadline_miss
    # the obs histogram must tell the same story as the exact timings
    # (within its ~19% bucket width) — dogfoods the metrics layer
    h = window.get(("serve_query_e2e_s", ()))
    tenant_wait = {
        t: w.quantile(0.5) for (name, labels), w in window.items()
        for t in [dict(labels).get("tenant")]
        if name == "serve_admission_wait_s" and t is not None}
    shed_reasons = {}
    for r in shed.values():
        shed_reasons[r.shed_reason] = shed_reasons.get(r.shed_reason, 0) + 1
    walls = [b["wall_s"] for b in srv.batch_log]
    return {
        "kind": kind, "offered_mult": mult,
        "offered_qps_virtual": mult * capacity_qps,
        "n_arrivals": len(queries),
        "n_deltas": sum(1 for ev in events if ev.delta is not None),
        "n_ok": len(ok), "n_shed": len(shed),
        "shed_rate": len(shed) / max(len(queries), 1),
        "shed_reasons": shed_reasons,
        "n_deadline_miss": srv.n_deadline_miss,
        "n_pressure_fast_path":
            _counter_delta(window, "serve_pressure_fast_path_total"),
        "n_lost": n_lost,
        "goodput_qps": good / max(elapsed, 1e-9),
        "p50_s": p50, "p99_s": p99, "p999_s": p999,
        # quantum-normalized latencies: machine-independent under the
        # deterministic service model (what the baseline gate compares)
        "p50_x": p50 / quantum_s, "p99_x": p99 / quantum_s,
        "p999_x": p999 / quantum_s,
        "obs_p99_s": h.quantile(0.99) if h is not None else 0.0,
        "tenant_wait_p50_s": tenant_wait,
        "n_batches": len(srv.batch_log),
        "batch_wall_p50_s": float(np.percentile(walls, 50)) if walls else 0.0,
        "warm_retraces": retraces,
        "tenants": _sum_tenants(srv.batch_log),
    }


def _counter_delta(window: dict, name: str) -> int:
    return int(sum(v for (n, _), v in window.items()
                   if n == name and isinstance(v, float)))


def _sum_tenants(batch_log) -> dict:
    out: dict = {}
    for b in batch_log:
        for t, c in b.get("tenants", {}).items():
            out[t] = out.get(t, 0) + c
    return out


# -- failover segment ----------------------------------------------------

def _failover_segment(engine, g, cost, seed=977):
    """Kill replica group 0 mid-batch; assert at-least-once recovery."""
    quantum_s = cost[0] + MAX_BATCH * cost[1]
    rng = np.random.default_rng(seed)
    queries = [PathQuery(int(s), int(t), int(k))
               for s, t, k in generators.random_queries(g, 48, (3, 4),
                                                        seed=seed)]
    events = [_Event(float(i) * quantum_s * 0.05, query=q)
              for i, q in enumerate(queries)]
    state = {"n_seen": 0}

    def injector(grp, item):
        # group 0 completes its first item, then dies executing its
        # second — that item is mid-flight, the exact at-least-once case
        if grp == 0:
            state["n_seen"] += 1
            if state["n_seen"] == 2:
                raise GroupFailure(grp)

    policy = AdmissionPolicy(max_batch=MAX_BATCH, min_batch=1,
                             max_delay_s=0.4 * quantum_s)
    cache_before = engine.cache
    srv, arrival, done_t = _replay(engine, events, policy, cost,
                                   n_groups=3, fail_injector=injector,
                                   gamma=0.9)
    results = {qid: srv.take(qid) for qid in list(srv.results)}
    n_lost = len(arrival) - len(results)
    n_dup = len(results) - len(set(results))    # dict => 0 by contract
    # sample-validate requeued work actually produced correct answers
    from repro.core.oracle import enumerate_paths_bruteforce, path_set
    oracle_ok = True
    for qid in rng.choice(sorted(results), size=3, replace=False):
        r = results[qid]
        truth = path_set(enumerate_paths_bruteforce(
            engine.g, r.query.s, r.query.t, r.query.k))
        if path_set(r.paths) != truth:
            oracle_ok = False
    cache_kept = (engine.cache is cache_before
                  and engine.cache is not None
                  and engine.cache.info()["entries"] > 0)
    dead_after_failover = sorted(srv.dead_groups)
    # a replacement replica joins: the revived group serves again
    srv.revive_group(0)
    state["n_seen"] = -10 ** 9          # disarm the injector
    extra = [srv.submit(q) for q in queries[:MAX_BATCH]]
    srv.drain()
    revived_ok = all(qid in srv.results for qid in extra) \
        and 0 not in srv.dead_groups
    return {
        "n_queries": len(queries), "n_groups": 3,
        "failovers": srv.n_failovers, "requeued": srv.sched.requeued,
        "steals": srv.sched.steals, "dead_groups": dead_after_failover,
        "n_lost": n_lost, "n_dup": n_dup,
        "oracle_ok": oracle_ok, "cache_kept": cache_kept,
        "cache_entries_after": (engine.cache.info()["entries"]
                                if engine.cache else 0),
        "revived_ok": revived_ok,
    }


# -- calibration + main --------------------------------------------------

def _calibrate(engine, g) -> tuple[float, float]:
    """Fit the affine service model ``wall ≈ c0 + c1*Q`` from warm walls
    of full and quarter micro-batches through the complete serving path
    (assembly + clustering + scheduler + engine). ``c0`` is the fixed
    dispatch overhead small admissions pay; ``c1`` the per-query cost."""
    def _warm_wall(size: int) -> float:
        queries = generators.random_queries(g, size, (3, 4), seed=11)
        srv = StreamingServer(engine, n_groups=2, planner="batch",
                              warm_bias_eps=0.0,
                              policy=AdmissionPolicy(max_batch=size,
                                                     min_batch=size,
                                                     max_delay_s=0.0))
        walls = []
        for _ in range(3):
            for q in queries:
                srv.submit(q)
            srv.drain()
            walls.append(srv.batch_log[-1]["wall_s"])
        return max(min(walls), 1e-5)

    small = MAX_BATCH // 4
    w_full, w_small = _warm_wall(MAX_BATCH), _warm_wall(small)
    c1 = max((w_full - w_small) / (MAX_BATCH - small), w_full / 256)
    c0 = max(w_small - small * c1, w_full / 64)
    return c0, c1


def main(scale: float = 1.0) -> dict:
    n = max(400, int(4000 * scale))
    g = generators.community(n, n_comm=max(2, n // 1000), avg_deg=5.0,
                             seed=7)
    engine = BatchPathEngine(g, EngineConfig(min_cap=64, log_compiles=True,
                                             cache_bytes=64 << 20))
    cost = _calibrate(engine, g)
    quantum = cost[0] + MAX_BATCH * cost[1]    # full-batch service time
    capacity_qps = MAX_BATCH / quantum
    zipf = _ZipfSampler(n, seed=7)
    n_arrivals = max(128, int(320 * min(scale, 1.0)))
    policy = AdmissionPolicy(
        max_batch=MAX_BATCH, min_batch=4, max_delay_s=1.5 * quantum,
        max_queue=2 * MAX_BATCH, shed_expired=True,
        tenant_weights={name: w for name, w, _ in TENANTS})

    levels = []
    for li, (kind, mult) in enumerate(LEVELS):
        lv = _run_level(engine, g, zipf, 100 + li, kind, mult,
                        capacity_qps, cost, n_arrivals, policy)
        levels.append(lv)
        record(f"exp11_{kind}_{mult}x_p99", lv["p99_s"] * 1e6,
               f"goodput={lv['goodput_qps']:.0f}qps "
               f"shed={lv['shed_rate']:.0%} lost={lv['n_lost']}")

    warm_retraces = sum(lv["warm_retraces"] for lv in levels)
    n_lost_total = sum(lv["n_lost"] for lv in levels)
    top = levels[-1]
    assert n_lost_total == 0, f"lost {n_lost_total} queries"
    assert warm_retraces == 0, \
        f"open-loop replay retraced warm shapes: {warm_retraces}"
    assert top["n_shed"] > 0, "overload level shed nothing"
    assert top["goodput_qps"] > 0, "overload level made no goodput"

    fo = _failover_segment(engine, g, cost)
    record("exp11_failover", fo["requeued"],
           f"failovers={fo['failovers']} lost={fo['n_lost']} "
           f"dup={fo['n_dup']} cache_kept={int(fo['cache_kept'])}")
    assert fo["failovers"] >= 1 and fo["requeued"] >= 1
    assert fo["n_lost"] == 0 and fo["n_dup"] == 0
    assert fo["oracle_ok"] and fo["cache_kept"] and fo["revived_ok"]

    summary = {
        "n": n, "max_batch": MAX_BATCH,
        "quantum_s": quantum, "service_c0_s": cost[0],
        "service_c1_s": cost[1], "capacity_qps_virtual": capacity_qps,
        "n_arrivals_per_level": n_arrivals,
        "tenant_weights": {name: w for name, w, _ in TENANTS},
        "tenant_deadline_quanta": {name: d for name, _, d in TENANTS},
        "policy": {"max_batch": policy.max_batch,
                   "min_batch": policy.min_batch,
                   "max_delay_quanta": 1.5,
                   "max_queue": policy.max_queue},
        "levels": levels,
        "warm_retraces": warm_retraces,
        "n_lost_total": n_lost_total,
        "failover": fo,
    }
    out = (Path("BENCH_serving.json") if scale >= 1.0
           else Path("results/BENCH_serving.json"))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, default=str))
    return summary


if __name__ == "__main__":
    main()
