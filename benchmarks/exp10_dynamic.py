"""Exp-10: evolving-graph serving — incremental deltas vs full invalidation.

Streaming workloads interleave queries with continuous edge arrivals (the
fraud-detection example; PathEnum's real-time setting). This experiment
runs a repeating query stream over a mutating graph in two identically
configured sessions serving identical traffic:

  * delta   -- ``session.apply_delta``: CSR merge, patched device views,
               hop-scoped cache invalidation (only entries whose hop
               radius the damage reaches are evicted).
  * rebuild -- ``session.update_graph(Graph.from_edges(...))``: the
               pre-delta path — full rebuild, every cache entry dropped.

Each round applies one small delta (<= 1% of edges, drawn from the
background-churn regime: edges outside the query neighborhoods) to both
arms, times the mutation itself, then serves the query batch and logs
retained cache entries / hits / batch wall. The delta arm is validated
oracle-exact against a fresh ``from_edges`` rebuild engine every round,
and the merged graph is asserted bit-equal to the rebuilt one.

Acceptance (default scale): a small delta preserves >= 50% of cache
entries (vs 0 under full invalidation), results stay oracle-exact, and
``apply_delta`` beats construct-plus-``update_graph`` wall time. At tiny
CI scales the graph has no hop-cold region, so the retention/latency
asserts relax (correctness asserts never do).

Compile telemetry (``EngineConfig.log_compiles``): the delta arm runs with
the retrace recorder on. The sentinel-padded pow2 edge buckets must keep
the edge-shape kernels (``msbfs_dist`` / ``msbfs_set_dist`` /
``walk_counts``) warm across every in-bucket round — asserted
``warm_retraces == 0`` at *every* scale (CI wires this smoke). A final
bucket-crossing delta (inserts pushing ``m`` past its pow2 bucket)
measures the one-off retrace cost and the warm-vs-cold batch wall.
"""
from __future__ import annotations

import json
import time
from collections import Counter
from pathlib import Path

import jax
import numpy as np

from repro.core import EngineConfig, GraphDelta, PathSession, generators
from repro.core.graph import Graph
from repro.core.oracle import (bfs_dist_from, enumerate_paths_bruteforce,
                               path_set)

from .common import record

# kernels whose traced shapes depend on the device edge lists: the ones
# the pow2 sentinel buckets exist to keep warm (enumeration caps are
# value-planned and may legitimately re-bucket as the workload drifts)
EDGE_KERNELS = frozenset({"msbfs_dist", "msbfs_set_dist", "walk_counts"})


def _edge_arrays(g: Graph):
    return np.repeat(np.arange(g.n), np.diff(g.indptr)), \
        g.indices.astype(np.int64)


def _churn_pool(g: Graph, queries) -> np.ndarray:
    """Vertices beyond every query's hop radius — where background churn
    (the bulk of real edge arrivals) lands. Empty on tiny graphs."""
    hot = np.zeros(g.n, bool)
    for s, t, k in queries:
        hot |= bfs_dist_from(g, s, k) <= k
        hot |= bfs_dist_from(g, t, k, reverse=True) <= k
    return np.flatnonzero(~hot)


def _make_delta(g: Graph, pool: np.ndarray, n_edges: int, rng) -> GraphDelta:
    """n_edges deletions of existing pool-internal edges + n_edges inserts
    between pool vertices (falls back to anywhere when the pool is thin)."""
    src, dst = _edge_arrays(g)
    # the pool must offer enough absent ordered pairs for the insert side,
    # or the rejection loop below could never terminate
    if pool.size >= 8 and pool.size * (pool.size - 1) >= 4 * n_edges:
        cold = np.zeros(g.n, bool)
        cold[pool] = True
        cand = np.flatnonzero(cold[src] & cold[dst])
        verts = pool
    else:
        cand = np.arange(g.m)
        verts = np.arange(g.n)
    pick = rng.choice(cand.size, size=min(n_edges, cand.size), replace=False)
    dels = list(zip(src[cand[pick]].tolist(), dst[cand[pick]].tolist()))
    have = set(zip(src.tolist(), dst.tolist()))
    adds = []
    tries = 0
    while len(adds) < n_edges:
        tries += 1
        if tries > 20 * n_edges:          # pool saturated: draw anywhere
            verts = np.arange(g.n)
        u, v = (int(x) for x in rng.choice(verts, 2, replace=False))
        if u != v and (u, v) not in have:
            adds.append((u, v))
            have.add((u, v))
    return GraphDelta.from_pairs(add=adds, remove=dels)


def _absent_pairs(g: Graph, verts: np.ndarray, count: int, rng) -> list:
    """``count`` distinct absent non-loop edges among ``verts`` (vectorized
    bulk rejection — a crossing delta can need thousands of inserts)."""
    src, dst = _edge_arrays(g)
    have = set(zip(src.tolist(), dst.tolist()))
    # fail fast instead of spinning forever on a saturated pool (callers
    # pre-check feasibility and widen to the whole vertex set otherwise)
    assert verts.size * (verts.size - 1) >= 2 * count, \
        f"vertex pool ({verts.size}) cannot supply {count} absent pairs"
    adds: list = []
    seen = set()
    while len(adds) < count:
        u = rng.choice(verts, size=4 * count)
        v = rng.choice(verts, size=4 * count)
        for a, b in zip(u.tolist(), v.tolist()):
            if a != b and (a, b) not in have and (a, b) not in seen:
                adds.append((a, b))
                seen.add((a, b))
                if len(adds) == count:
                    break
    return adds


def _edited_edges(g: Graph, delta: GraphDelta):
    """The successor edge list a rebuild caller would construct (vectorized
    numpy edit — the status-quo path gets a fair, fast implementation)."""
    src, dst = _edge_arrays(g)
    key = src * g.n + dst
    keep = ~np.isin(key, delta.del_src * g.n + delta.del_dst)
    return (np.concatenate([src[keep], delta.add_src]),
            np.concatenate([dst[keep], delta.add_dst]))


def main(scale: float = 1.0) -> dict:
    n = max(400, int(6000 * scale))
    rounds = 4
    g0 = generators.community(n, n_comm=max(4, n // 500), avg_deg=5.0, seed=0)
    queries = generators.similar_queries(
        g0, max(8, int(16 * min(scale, 1.0))), similarity=0.85,
        k_range=(3, 4), seed=1)
    cfg = EngineConfig(min_cap=128, cache_bytes=128 << 20,
                       log_compiles=True)
    s_delta = PathSession(g0, cfg)
    s_rebuild = PathSession(g0, EngineConfig(min_cap=128,
                                             cache_bytes=128 << 20))
    rng = np.random.default_rng(2)
    n_edges = max(2, int(0.0025 * g0.m))          # well under the 1% budget
    pool = _churn_pool(g0, queries)
    strict = pool.size >= 8 * n_edges             # hop-cold region exists
    # mutation-latency comparison only means something once the rebuild
    # actually costs something; tiny CI graphs rebuild in ~1ms
    strict_latency = strict and g0.m >= 15_000

    # warm both arms: jit compiles, cold cache fill, one untimed delta so
    # the delta arm's MS-BFS shapes are compiled before timing
    s_delta.run(queries)
    s_rebuild.run(queries)
    warm = _make_delta(s_delta.engine.g, pool, n_edges, rng)
    s_delta.apply_delta(warm)
    s_rebuild.update_graph(Graph.from_edges(n, *_edited_edges(g0, warm)))
    s_delta.run(queries)
    s_rebuild.run(queries)

    log = []
    warm_kernels: Counter = Counter()   # compiles observed in warm rounds
    for rnd in range(rounds):
        g_cur = s_delta.engine.g
        delta = _make_delta(g_cur, _churn_pool(g_cur, queries), n_edges, rng)
        assert (delta.n_add + delta.n_del) <= max(0.01 * g_cur.m, 4), \
            "delta exceeds the small-delta budget"
        entries_before = len(s_delta.cache)

        t0 = time.perf_counter()
        rep = s_delta.apply_delta(delta)
        t_apply = time.perf_counter() - t0
        t0 = time.perf_counter()
        new_src, new_dst = _edited_edges(g_cur, delta)
        s_rebuild.update_graph(Graph.from_edges(n, new_src, new_dst))
        # apply_delta blocks on its device work before reporting; give the
        # rebuild arm the same completed-work timing semantics
        dgb = s_rebuild.engine.dg
        jax.block_until_ready((dgb.esrc, dgb.edst, dgb.ell_idx,
                               dgb.r_esrc, dgb.r_edst, dgb.r_ell_idx))
        t_update = time.perf_counter() - t0

        # both arms must land on the identical graph
        ga, gb = s_delta.engine.g, s_rebuild.engine.g
        assert (np.array_equal(ga.indptr, gb.indptr)
                and np.array_equal(ga.indices, gb.indices)), "merge != rebuild"

        t0 = time.perf_counter()
        r_delta = s_delta.run(queries)
        w_delta = time.perf_counter() - t0
        t0 = time.perf_counter()
        r_rebuild = s_rebuild.run(queries)
        w_rebuild = time.perf_counter() - t0

        # oracle-exact against a brute-force reference on the new graph
        sample = np.random.default_rng(rnd).choice(
            len(queries), size=min(3, len(queries)), replace=False)
        for qi in sample:
            s, t, k = queries[qi]
            truth = path_set(enumerate_paths_bruteforce(ga, s, t, k))
            assert path_set(r_delta[qi].paths) == truth, f"delta arm q{qi}"
            assert path_set(r_rebuild[qi].paths) == truth, f"rebuild arm q{qi}"

        warm_kernels.update(rep.get("compiled_kernels", {}))
        warm_kernels.update(r_delta.stats.get("compiled_kernels", {}))
        log.append({
            "round": rnd, "delta_edges": delta.n_add + delta.n_del,
            "entries_before": entries_before,
            "cache_kept": rep["cache_kept"], "cache_evicted": rep["cache_evicted"],
            "t_apply_delta_s": t_apply, "t_update_graph_s": t_update,
            "batch_wall_delta_s": w_delta, "batch_wall_rebuild_s": w_rebuild,
            "hits_delta": r_delta.stats["n_cache_hits"],
            "hits_rebuild": r_rebuild.stats["n_cache_hits"],
            "mat_delta": r_delta.stats["n_materialized"],
            "mat_rebuild": r_rebuild.stats["n_materialized"],
            "compiles_delta": rep.get("n_compiles", 0)
            + r_delta.stats.get("n_compiles", 0),
        })

    # -- bucket-crossing churn: the one mutation class allowed to retrace.
    # Insert enough edges to push m past its pow2 bucket, then measure the
    # cold (retracing) batch vs the immediately-following warm batch.
    eng = s_delta.engine
    g_cur = eng.g
    m_warm = int(g_cur.m)      # the edge count every warm-loop metric saw
    need = eng.dg.m_cap - g_cur.m + 1
    pool_c = _churn_pool(g_cur, queries)
    # the cold pool must offer enough absent pairs for the crossing
    # inserts (same feasibility guard as _make_delta), else draw anywhere
    verts_c = pool_c if pool_c.size * (pool_c.size - 1) >= 4 * need \
        else np.arange(g_cur.n)
    crossing = GraphDelta.from_pairs(
        add=_absent_pairs(g_cur, verts_c, need, rng))
    m_cap_before = eng.dg.m_cap
    t0 = time.perf_counter()
    rep_cross = s_delta.apply_delta(crossing)
    t_apply_cross = time.perf_counter() - t0
    assert eng.dg.m_cap > m_cap_before, "crossing delta stayed in bucket?"
    t0 = time.perf_counter()
    r_cross = s_delta.run(queries)
    w_cross = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_after = s_delta.run(queries)
    w_after = time.perf_counter() - t0
    s, t, k = queries[0]
    truth = path_set(enumerate_paths_bruteforce(eng.g, s, t, k))
    assert path_set(r_cross[0].paths) == truth, "crossing arm q0"
    assert path_set(r_after[0].paths) == truth, "post-crossing arm q0"
    crossing_kernels = Counter(rep_cross.get("compiled_kernels", {}))
    crossing_kernels.update(r_cross.stats.get("compiled_kernels", {}))

    retained = [r["cache_kept"] / max(r["entries_before"], 1) for r in log]
    p50_delta = float(np.median([r["batch_wall_delta_s"] for r in log]))
    p50_rebuild = float(np.median([r["batch_wall_rebuild_s"] for r in log]))
    t_apply_med = float(np.median([r["t_apply_delta_s"] for r in log]))
    t_update_med = float(np.median([r["t_update_graph_s"] for r in log]))
    summary = {
        "n": n, "m": m_warm, "m_final": int(s_delta.engine.g.m),
        "n_queries": len(queries),
        "rounds": rounds, "delta_edges_per_round": n_edges * 2,
        "strict": bool(strict), "strict_latency": bool(strict_latency),
        "retained_frac_mean": float(np.mean(retained)),
        "retained_frac_min": float(np.min(retained)),
        "p50_batch_s_delta": p50_delta, "p50_batch_s_rebuild": p50_rebuild,
        "t_apply_delta_med_s": t_apply_med,
        "t_update_graph_med_s": t_update_med,
        "apply_speedup": t_update_med / max(t_apply_med, 1e-9),
        "hits_delta_total": sum(r["hits_delta"] for r in log),
        "hits_rebuild_total": sum(r["hits_rebuild"] for r in log),
        # in-bucket churn must keep every edge-shape kernel warm
        "warm_retraces": sum(c for name, c in warm_kernels.items()
                             if name in EDGE_KERNELS),
        "warm_compiles_by_kernel": dict(warm_kernels),
        "bucket_crossing": {
            "delta_edges": crossing.n_add,
            "m_cap_before": m_cap_before, "m_cap_after": eng.dg.m_cap,
            "t_apply_s": t_apply_cross,
            "batch_wall_cold_s": w_cross,      # pays the retraces
            "batch_wall_warm_s": w_after,      # next round: warm again
            "retraces_by_kernel": dict(crossing_kernels),
            "edge_kernel_retraces": sum(c for name, c in
                                        crossing_kernels.items()
                                        if name in EDGE_KERNELS),
        },
        "rounds_log": log,
        "cache": s_delta.cache.info(),
    }
    record("exp10_dynamic_delta", p50_delta * 1e6,
           f"retained={summary['retained_frac_mean']:.2f} "
           f"hits={summary['hits_delta_total']} strict={int(strict)}")
    record("exp10_dynamic_rebuild", p50_rebuild * 1e6,
           f"retained=0.00 hits={summary['hits_rebuild_total']}")
    record("exp10_apply_vs_update", t_apply_med * 1e6,
           f"update_graph={t_update_med * 1e6:.0f}us "
           f"speedup={summary['apply_speedup']:.2f}x")
    record("exp10_bucket_crossing", w_cross * 1e6,
           f"warm={w_after * 1e6:.0f}us "
           f"edge_retraces={summary['bucket_crossing']['edge_kernel_retraces']} "
           f"warm_loop_retraces={summary['warm_retraces']}")
    # the committed artifact records the full-scale workload; tiny smoke
    # runs (CI) must not clobber it — they write under results/ instead
    out = (Path("BENCH_dynamic.json") if scale >= 1.0
           else Path("results/BENCH_dynamic.json"))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, default=str))

    # full invalidation drops everything, by construction
    assert summary["hits_rebuild_total"] == 0, "rebuild arm kept warm state?"
    # shape-stability contract, scale-independent: in-bucket churn never
    # retraces an edge-shape kernel (CI smoke-asserts this via the json)
    assert summary["warm_retraces"] == 0, summary["warm_compiles_by_kernel"]
    assert summary["bucket_crossing"]["edge_kernel_retraces"] > 0, \
        "crossing should have paid (and measured) the edge-kernel retrace"
    if strict:
        assert summary["retained_frac_min"] >= 0.5, (
            f"small delta must preserve >=50% of cache entries, got "
            f"{summary['retained_frac_min']:.2f}")
        assert p50_delta <= p50_rebuild, (
            f"warm p50 batch ({p50_delta:.4f}s) must not exceed the "
            f"full-invalidation arm ({p50_rebuild:.4f}s)")
    if strict_latency:
        assert t_apply_med < t_update_med, (
            f"apply_delta ({t_apply_med:.4f}s) must beat construct + "
            f"update_graph ({t_update_med:.4f}s)")
    return summary


if __name__ == "__main__":
    main()
