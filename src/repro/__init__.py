"""repro: batch HC-s-t path query processing framework (JAX, multi-pod).

Reproduction + beyond-paper optimization of "Batch Hop-Constrained s-t
Simple Path Query Processing in Large Graphs" (CS.DB 2023), plus the
assigned-architecture model zoo, distributed runtime and launchers.
"""
__version__ = "1.0.0"
