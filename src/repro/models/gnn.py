"""GNN zoo: MeshGraphNet, GraphCast(-style), SchNet, GraphSAGE.

Message passing is ``segment_sum`` over an edge list (JAX has no CSR SpMM;
this gather/scatter form IS the system, per the assignment), with two
execution paths:

  * local  -- single-shard edge list (smoke tests, minibatch_lg sampled
              blocks, molecule batches; data-parallel over the batch).
  * ring   -- full-graph shapes: nodes row-partitioned over all mesh axes,
              edges bucketed by (dst_owner, src_owner); P ring steps rotate
              the node-feature shard with ``collective_permute`` while each
              shard aggregates its incoming bucket — comm volume N·F per
              layer (the minimum for row-partitioned SpMM), fully
              overlappable with the bucket GEMMs. This replaces CUDA
              scatter-atomics with a TPU-native systolic schedule.

Aggregation op per config (sum/mean/max). MLPs follow each paper's shape
(2-layer + LayerNorm for MGN/GraphCast; shifted-softplus for SchNet).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import GNNConfig

__all__ = ["init_gnn_params", "gnn_param_logical", "gnn_forward", "gnn_loss",
           "ring_aggregate"]


# ----------------------------------------------------------------------
# small building blocks
# ----------------------------------------------------------------------

def _mlp_init(rng, sizes, n_hidden_layers=2, layer_norm=True):
    dims = [sizes[0]] + [sizes[1]] * (n_hidden_layers - 1) + [sizes[-1]]
    keys = jax.random.split(rng, len(dims))
    p = {"w": [], "b": []}
    for i in range(len(dims) - 1):
        p["w"].append(jax.random.normal(keys[i], (dims[i], dims[i + 1]),
                                        jnp.float32) / np.sqrt(dims[i]))
        p["b"].append(jnp.zeros((dims[i + 1],), jnp.float32))
    if layer_norm:
        p["ln_g"] = jnp.ones((dims[-1],), jnp.float32)
        p["ln_b"] = jnp.zeros((dims[-1],), jnp.float32)
    return p


def _mlp(p, x, act=jax.nn.relu):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1:
            x = act(x)
    if "ln_g" in p:
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["ln_g"] + p["ln_b"]
    return x


def _mlp_logical(p):
    return jax.tree.map(lambda _: (None,) , p)  # GNN params replicated


def _segment(msgs, dst, n, op):
    if op == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n)
        c = jax.ops.segment_sum(jnp.ones((msgs.shape[0], 1), msgs.dtype), dst,
                                num_segments=n)
        return s / jnp.maximum(c, 1.0)
    if op == "max":
        s = jax.ops.segment_max(msgs, dst, num_segments=n)
        return jnp.where(jnp.isfinite(s), s, 0.0)
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


# ----------------------------------------------------------------------
# ring-distributed aggregation (full-graph shapes)
# ----------------------------------------------------------------------

def ring_aggregate(h_loc, edge_src, edge_dst, edge_mask, axis_name: str,
                   msg_fn=None, op: str = "sum"):
    """Row-partitioned SpMM by ring rotation.

    h_loc    : (N_loc, F) this shard's node features.
    edge_src : (P, Eb) int32 — for src-block b, local index of src within b.
    edge_dst : (P, Eb) int32 — local dst index (this shard's range).
    edge_mask: (P, Eb) bool.
    msg_fn   : optional map over gathered src features (default identity).
    """
    P = edge_src.shape[0]
    N_loc, F = h_loc.shape[0], h_loc.shape[-1]
    me = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % P) for i in range(P)]

    def body(r, carry):
        acc, rot = carry
        blk = (me - r) % P                       # block id `rot` holds now
        es = jax.lax.dynamic_index_in_dim(edge_src, blk, 0, keepdims=False)
        ed = jax.lax.dynamic_index_in_dim(edge_dst, blk, 0, keepdims=False)
        em = jax.lax.dynamic_index_in_dim(edge_mask, blk, 0, keepdims=False)
        src_h = rot[es]                          # (Eb, F)
        msgs = msg_fn(src_h, ed) if msg_fn else src_h
        msgs = jnp.where(em[:, None], msgs, 0.0)
        acc = acc + jax.ops.segment_sum(msgs, jnp.where(em, ed, N_loc),
                                        num_segments=N_loc + 1)[:-1]
        rot = jax.lax.ppermute(rot, axis_name, perm)
        return acc, rot

    acc0 = jnp.zeros((N_loc,) + ((msg_fn(h_loc[:1], jnp.zeros(1, jnp.int32)).shape[-1],)
                                 if msg_fn else (F,)), h_loc.dtype)
    acc, _ = jax.lax.fori_loop(0, P, body, (acc0, h_loc))
    return acc


# ----------------------------------------------------------------------
# parameter init per architecture
# ----------------------------------------------------------------------

def init_gnn_params(rng, cfg: GNNConfig, d_in: int, d_out: int) -> dict:
    d = cfg.d_hidden
    L = cfg.n_layers
    keys = jax.random.split(rng, L * 4 + 8)
    ki = iter(range(len(keys)))
    if cfg.kind == "graphsage":
        p = {"layers": []}
        dims = [d_in] + [d] * L
        for l in range(L):
            p["layers"].append({
                "w_self": jax.random.normal(keys[next(ki)], (dims[l], d)) / np.sqrt(dims[l]),
                "w_nbr": jax.random.normal(keys[next(ki)], (dims[l], d)) / np.sqrt(dims[l]),
                "b": jnp.zeros((d,)),
            })
        p["out"] = jax.random.normal(keys[next(ki)], (d, d_out)) / np.sqrt(d)
        return p
    if cfg.kind in ("meshgraphnet", "graphcast"):
        blocks = [{
            "edge_mlp": _mlp_init(keys[next(ki)], (3 * d, d, d), cfg.mlp_layers),
            "node_mlp": _mlp_init(keys[next(ki)], (2 * d, d, d), cfg.mlp_layers),
        } for _ in range(L)]
        return {
            "node_enc": _mlp_init(keys[next(ki)], (d_in, d, d), cfg.mlp_layers),
            "edge_enc": _mlp_init(keys[next(ki)], (4, d, d), cfg.mlp_layers),
            # stacked (L, ...) so the forward scans + remats per block
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "node_dec": _mlp_init(keys[next(ki)], (d, d, d_out), cfg.mlp_layers,
                                  layer_norm=False),
        }
    if cfg.kind == "schnet":
        rbf = cfg.extra("rbf", 300)
        blocks = [{
            "filter1": jax.random.normal(keys[next(ki)], (rbf, d)) / np.sqrt(rbf),
            "filter2": jax.random.normal(keys[next(ki)], (d, d)) / np.sqrt(d),
            "w_in": jax.random.normal(keys[next(ki)], (d, d)) / np.sqrt(d),
            "w_out1": jax.random.normal(keys[next(ki)], (d, d)) / np.sqrt(d),
            "w_out2": jax.random.normal(keys[next(ki)], (d, d)) / np.sqrt(d),
        } for _ in range(L)]
        return {
            "embed": jax.random.normal(keys[next(ki)], (100, d)) * 0.1,
            "in_proj": jax.random.normal(keys[next(ki)], (d_in, d)) / np.sqrt(d_in),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "out1": jax.random.normal(keys[next(ki)], (d, d // 2)) / np.sqrt(d),
            "out2": jax.random.normal(keys[next(ki)], (d // 2, d_out)) / np.sqrt(d // 2),
        }
    raise ValueError(cfg.kind)


def gnn_param_logical(params) -> Any:
    """GNN params are small -> replicated."""
    return jax.tree.map(lambda p: tuple(None for _ in p.shape), params)


# ----------------------------------------------------------------------
# forward (local edge-list path; ring path hooks via aggregate_fn)
# ----------------------------------------------------------------------

def _ssp(x):  # shifted softplus (SchNet)
    return jax.nn.softplus(x) - np.log(2.0)


def gnn_forward(params, batch, cfg: GNNConfig, constrain=None):
    """batch: dict with nodes/edge_src/edge_dst (+kind-specific extras).

    constrain(x, logical_axes): sharding hook — node arrays get
    ("cells", None), edge arrays ("cells", None). Without these, GSPMD
    replicates the (E, d) edge latents on big full-batch graphs
    (measured 241 GiB/device on graphcast x ogb_products).
    """
    if constrain is None:
        constrain = lambda x, axes: x
    cn = lambda x: constrain(x, ("cells",) + (None,) * (x.ndim - 1))
    nodes = batch["nodes"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    N = nodes.shape[0]
    op = cfg.aggregator

    def local_agg(h_src_feats, dst_idx, want_op=op):
        m = cn(h_src_feats)
        if emask is not None:
            m = jnp.where(emask[:, None], m, 0.0 if want_op != "max" else -jnp.inf)
            dst_idx = jnp.where(emask, dst_idx, N)
            out = _segment(m, dst_idx, N + 1, want_op)[:-1]
            return cn(out)
        return cn(_segment(m, dst_idx, N, want_op))

    if cfg.kind == "graphsage":
        h = cn(nodes)
        for lp in params["layers"]:
            nbr = local_agg(h[src], dst, "mean")
            h = jax.nn.relu(h @ lp["w_self"] + nbr @ lp["w_nbr"] + lp["b"])
            h = cn(h / jnp.maximum(
                jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6))
        return h @ params["out"]

    if cfg.kind in ("meshgraphnet", "graphcast"):
        h = cn(_mlp(params["node_enc"], nodes))
        ef = batch.get("edge_feat")
        if ef is None:
            ef = jnp.zeros((src.shape[0], 4), nodes.dtype)
        e = cn(_mlp(params["edge_enc"], ef))

        def block(carry, bp):
            h, e = carry
            msg_in = cn(jnp.concatenate([e, h[src], h[dst]], -1))
            e = cn(e + _mlp(bp["edge_mlp"], msg_in))
            agg = local_agg(e, dst, op)
            h = cn(h + _mlp(bp["node_mlp"], jnp.concatenate([h, agg], -1)))
            return (h, e), ()

        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
        (h, e), _ = jax.lax.scan(block, (h, e), params["blocks"])
        return _mlp(params["node_dec"], h)

    if cfg.kind == "schnet":
        if "atom_types" in batch:
            h = params["embed"][batch["atom_types"]]
        else:
            h = nodes @ params["in_proj"]
        h = cn(h)
        rbf = batch["edge_rbf"]                     # (E, n_rbf) precomputed

        def block(h, bp):
            w = cn(_ssp(rbf @ bp["filter1"]) @ bp["filter2"])  # (E, d) cfconv
            msg = (h @ bp["w_in"])[src] * w
            agg = local_agg(msg, dst, "sum")
            h = cn(h + _ssp(agg @ bp["w_out1"]) @ bp["w_out2"])
            return h, ()

        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(block, h, params["blocks"])
        atom_e = _ssp(h @ params["out1"]) @ params["out2"]
        return atom_e                                # (N, d_out) per-atom energy

    raise ValueError(cfg.kind)


def gnn_loss(params, batch, cfg: GNNConfig, constrain=None):
    out = gnn_forward(params, batch, cfg, constrain=constrain)
    nmask = batch.get("node_mask")
    if cfg.kind == "graphsage":                     # node classification
        labels = batch["labels"]
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        if nmask is not None:
            return jnp.sum(nll * nmask) / jnp.maximum(nmask.sum(), 1.0)
        return nll.mean()
    if cfg.kind == "schnet":                        # energy regression (sum-pool)
        if nmask is not None:
            energy = jnp.sum(out * nmask[:, None])
        else:
            energy = jnp.sum(out)
        return jnp.mean((energy - jnp.sum(batch["targets"])) ** 2)
    # node regression (meshgraphnet / graphcast)
    err = (out - batch["targets"]) ** 2
    if nmask is not None:
        return jnp.sum(err * nmask[:, None]) / jnp.maximum(nmask.sum() * out.shape[-1], 1.0)
    return err.mean()
