"""MoE FFN (moonshot-v1-16b-a3b: 64e top-6; olmoe-1b-7b: 64e top-8).

Grouped sort-based dispatch with static capacity — no (T, E, C) one-hot
tensors (the GShard einsum formulation is O(T*E*C) memory and cannot
compile at 1M-token batches). Tokens are split into G groups (sharded over
the data axis, GShard-style) and each group dispatches locally:

  router top-k -> flat (Tg*k) expert ids -> argsort -> rank-in-expert via
  searchsorted -> capacity mask -> scatter to (G, E, C, D) -> grouped
  expert einsum -> gather back -> gate-weighted combine (drops get 0).

The (G, E, C, D) dispatch buffer is sharded over BOTH the group axis
("batch" = data) and the expert axis ("expert" = model); the reshard
between token-sharded x and expert-sharded dispatch lowers to the EP
all-to-all. Without grouping, XLA replicates the dispatch scatter and
per-device memory explodes (measured: 314 GiB -> small on
moonshot train_4k). Aux loss is the standard Switch fraction-product.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..config import LMConfig

__all__ = ["moe_ffn", "moe_ffn_dense_ref"]


def moe_ffn(h, lp, cfg: LMConfig, constrain, groups: int = 16):
    """h: (B, S, D) -> (B, S, D), aux loss scalar."""
    mc = cfg.moe
    B, S, D = h.shape
    T = B * S
    G = math.gcd(T, max(groups, 1))
    Tg = T // G
    E, k = mc.n_experts, mc.top_k
    C = max(int(Tg * k / E * mc.capacity_factor), 1)
    dt = h.dtype

    x = h.reshape(G, Tg, D)
    x = constrain(x, ("batch", None, None))
    logits = (x @ lp["router"].astype(dt)).astype(jnp.float32)   # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, k)                        # (G, Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    def dispatch(xg, eg, gg):
        flat_e = eg.reshape(-1)                                  # (Tg*k,)
        flat_t = jnp.repeat(jnp.arange(Tg), k)
        flat_g = gg.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        rank = jnp.arange(Tg * k) - jnp.searchsorted(se, se, side="left")
        keep = rank < C
        slot = jnp.where(keep, se * C + rank, E * C)             # drop slot
        disp = jnp.zeros((E * C + 1, D), dt).at[slot].set(xg[st])
        return disp[:-1].reshape(E, C, D), slot, st, keep, sg

    disp, slot, st, keep, sg = jax.vmap(dispatch)(x, eids, gates)
    disp = constrain(disp, ("batch", "expert", None, None))

    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", disp, lp["e_gate"].astype(dt)))
    u = jnp.einsum("gecd,edf->gecf", disp, lp["e_up"].astype(dt))
    eh = constrain(g * u, ("batch", "expert", None, None))
    eo = jnp.einsum("gecf,efd->gecd", eh, lp["e_down"].astype(dt))
    eo = constrain(eo, ("batch", "expert", None, None))

    def combine(eog, slotg, stg, keepg, sgg):
        flat_out = eog.reshape(E * C, D)
        back = jnp.where(keepg[:, None],
                         flat_out[jnp.minimum(slotg, E * C - 1)], 0)
        return jnp.zeros((Tg, D), dt).at[stg].add(
            back * sgg[:, None].astype(dt))

    out = jax.vmap(combine)(eo, slot, st, keep, sg)
    out = constrain(out, ("batch", None, None))
    return out.reshape(B, S, D), aux


def moe_ffn_dense_ref(h, lp, cfg: LMConfig):
    """Oracle: evaluate every expert densely, weight by router gates."""
    mc = cfg.moe
    B, S, D = h.shape
    x = h.reshape(B * S, D).astype(jnp.float32)
    logits = x @ lp["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, mc.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[jnp.arange(x.shape[0])[:, None], eids].set(gates)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", x, lp["e_gate"].astype(jnp.float32)))
    u = jnp.einsum("td,edf->tef", x, lp["e_up"].astype(jnp.float32))
    eo = jnp.einsum("tef,efd->ted", g * u, lp["e_down"].astype(jnp.float32))
    out = jnp.einsum("ted,te->td", eo, w)
    return out.reshape(B, S, D).astype(h.dtype)
