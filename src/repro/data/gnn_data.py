"""Synthetic graph batches for the GNN zoo (offline stand-ins).

Builds jit-ready batches in the formats steps.py expects: flat padded
edge-list batches (full-graph / sampled blocks) and batched small
molecules (positions + RBF edge features for SchNet).
"""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph
from ..config import GNNConfig, ShapeSpec

__all__ = ["flat_batch", "molecule_batch", "sampled_batch", "rbf_expand"]


def rbf_expand(dist: np.ndarray, n_rbf: int, cutoff: float) -> np.ndarray:
    """SchNet Gaussian radial basis."""
    centers = np.linspace(0.0, cutoff, n_rbf, dtype=np.float32)
    gamma = n_rbf / cutoff
    return np.exp(-gamma * (dist[..., None] - centers) ** 2).astype(np.float32)


def flat_batch(cfg: GNNConfig, shape: ShapeSpec, g: Graph, d_feat: int,
               d_out: int, seed: int = 0, n_pad: int | None = None,
               e_pad: int | None = None) -> dict:
    rng = np.random.default_rng(seed)
    N = n_pad or -(-g.n // 512) * 512
    E = e_pad or -(-g.m // 512) * 512
    src, dst = g.edges_by_dst
    b = {
        "nodes": _padf(rng.standard_normal((g.n, d_feat), dtype=np.float32), N),
        "edge_src": _padi(src, E),
        "edge_dst": _padi(dst, E),
        "edge_mask": _mask(g.m, E),
        "node_mask": _mask(g.n, N),
    }
    if cfg.kind == "schnet":
        pos = rng.standard_normal((g.n, 3)).astype(np.float32) * 3
        d = np.linalg.norm(pos[src] - pos[dst], axis=-1)
        b["edge_rbf"] = _padf(rbf_expand(d, cfg.extra("rbf", 300),
                                         cfg.extra("cutoff", 10.0)), E)
        b["targets"] = _padf(rng.standard_normal(g.n).astype(np.float32), N)
    elif cfg.kind == "graphsage":
        ncls = cfg.extra("n_classes", 41)
        b["labels"] = _padi(rng.integers(0, ncls, g.n), N)
    else:
        b["edge_feat"] = _padf(rng.standard_normal((g.m, 4), dtype=np.float32), E)
        b["targets"] = _padf(
            rng.standard_normal((g.n, d_out), dtype=np.float32), N)
    return b


def sampled_batch(cfg: GNNConfig, g: Graph, roots: np.ndarray,
                  fanouts: tuple[int, ...], d_feat: int, d_out: int,
                  seed: int = 0, n_pad: int | None = None,
                  e_pad: int | None = None) -> dict:
    from ..models.sampler import sample_blocks
    rng = np.random.default_rng(seed)
    blk = sample_blocks(g, roots, fanouts, rng, node_cap=n_pad, edge_cap=e_pad)
    N, E = blk.node_ids.shape[0], blk.edge_src.shape[0]
    feats = rng.standard_normal((N, d_feat)).astype(np.float32)
    b = {"nodes": feats,
         "edge_src": blk.edge_src, "edge_dst": blk.edge_dst,
         "edge_mask": blk.edge_mask,
         "node_mask": blk.node_ids >= 0}
    if cfg.kind == "schnet":
        d = rng.random(E).astype(np.float32) * cfg.extra("cutoff", 10.0)
        b["edge_rbf"] = rbf_expand(d, cfg.extra("rbf", 300),
                                   cfg.extra("cutoff", 10.0))
        b["targets"] = rng.standard_normal(N).astype(np.float32)
    elif cfg.kind == "graphsage":
        b["labels"] = rng.integers(0, cfg.extra("n_classes", 41), N).astype(np.int32)
        b["node_mask"] = blk.root_mask     # loss only on roots
    else:
        b["edge_feat"] = rng.standard_normal((E, 4)).astype(np.float32)
        b["targets"] = rng.standard_normal((N, d_out)).astype(np.float32)
    return b


def molecule_batch(cfg: GNNConfig, n_graphs: int, n_atoms: int, n_edges: int,
                   d_feat: int, d_out: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    B, N, E = n_graphs, n_atoms, n_edges
    pos = rng.standard_normal((B, N, 3)).astype(np.float32) * 2
    src = rng.integers(0, N, (B, E)).astype(np.int32)
    dst = rng.integers(0, N, (B, E)).astype(np.int32)
    b = {"nodes": rng.standard_normal((B, N, d_feat)).astype(np.float32),
         "edge_src": src, "edge_dst": dst,
         "edge_mask": np.ones((B, E), bool),
         "node_mask": np.ones((B, N), bool)}
    if cfg.kind == "schnet":
        d = np.linalg.norm(
            np.take_along_axis(pos, src[..., None], 1)
            - np.take_along_axis(pos, dst[..., None], 1), axis=-1)
        b["atom_types"] = rng.integers(0, 20, (B, N)).astype(np.int32)
        b["edge_rbf"] = rbf_expand(d, cfg.extra("rbf", 300),
                                   cfg.extra("cutoff", 10.0))
        b["targets"] = rng.standard_normal(B).astype(np.float32)
    elif cfg.kind == "graphsage":
        b["labels"] = rng.integers(0, cfg.extra("n_classes", 41),
                                   (B, N)).astype(np.int32)
    else:
        b["edge_feat"] = rng.standard_normal((B, E, 4)).astype(np.float32)
        b["targets"] = rng.standard_normal((B, N, d_out)).astype(np.float32)
    return b


def _padf(x: np.ndarray, n: int) -> np.ndarray:
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad)


def _padi(x: np.ndarray, n: int) -> np.ndarray:
    return np.pad(x.astype(np.int32), (0, n - x.shape[0]))


def _mask(k: int, n: int) -> np.ndarray:
    m = np.zeros(n, bool)
    m[:k] = True
    return m
