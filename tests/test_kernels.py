"""Per-kernel shape/dtype sweeps: interpret-mode kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

rng = np.random.default_rng(0)


class TestMsbfsExpand:
    @pytest.mark.parametrize("V,D,W", [(16, 2, 1), (64, 5, 2), (130, 8, 4),
                                       (257, 3, 7)])
    def test_sweep(self, V, D, W):
        from repro.kernels.msbfs_expand.kernel import msbfs_expand_pallas
        from repro.kernels.msbfs_expand.ref import msbfs_expand_ref
        ell = jnp.asarray(rng.integers(0, V + 1, (V, D)).astype(np.int32))
        fr = jnp.asarray(
            rng.integers(0, 2**32, (V + 1, W), dtype=np.uint64).astype(np.uint32))
        fr = fr.at[-1].set(0)
        a = msbfs_expand_pallas(ell, fr, interpret=True, block_v=32, block_w=2)
        b = msbfs_expand_ref(ell, fr)
        assert np.array_equal(np.asarray(a), np.asarray(b))

    @given(st.integers(4, 80), st.integers(1, 6), st.integers(1, 3),
           st.integers(0, 5))
    @settings(max_examples=15, deadline=None)
    def test_property(self, V, D, W, seed):
        from repro.kernels.msbfs_expand import ops
        r = np.random.default_rng(seed)
        ell = jnp.asarray(r.integers(0, V + 1, (V, D)).astype(np.int32))
        fr = jnp.asarray(
            r.integers(0, 2**32, (V + 1, W), dtype=np.uint64).astype(np.uint32))
        a = ops.msbfs_hop_packed(ell, fr, backend="interpret")
        b = ops.msbfs_hop_packed(ell, fr, backend="jnp")
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_pack_unpack_roundtrip(self):
        from repro.kernels.msbfs_expand.ref import pack_bits, unpack_bits
        bits = jnp.asarray(rng.random((33, 70)) < 0.5)
        assert np.array_equal(np.asarray(unpack_bits(pack_bits(bits), 70)),
                              np.asarray(bits))


class TestPairwisePopcount:
    @pytest.mark.parametrize("Q,V", [(3, 40), (17, 333), (64, 1000),
                                     (5, 31), (9, 65)])
    def test_sweep(self, Q, V):
        from repro.kernels.pairwise_popcount import ops
        g = jnp.asarray(rng.random((Q, V)) < 0.4)
        ref = ops.pairwise_intersections(g, backend="jnp")
        itp = ops.pairwise_intersections(g, backend="interpret")
        assert np.array_equal(np.asarray(ref), np.asarray(itp))
        # ground truth on a couple of pairs
        gn = np.asarray(g)
        assert int(np.asarray(ref)[0, 1]) == int((gn[0] & gn[1]).sum())

    @given(st.integers(2, 20), st.integers(8, 120), st.integers(0, 4))
    @settings(max_examples=15, deadline=None)
    def test_property_symmetric_diag(self, Q, V, seed):
        from repro.kernels.pairwise_popcount import ops
        r = np.random.default_rng(seed)
        g = jnp.asarray(r.random((Q, V)) < 0.3)
        out = np.asarray(ops.pairwise_intersections(g, backend="interpret"))
        assert np.array_equal(out, out.T)
        assert np.array_equal(np.diag(out), np.asarray(g).sum(1))


class TestPathJoin:
    @pytest.mark.parametrize("NA,NB,LA,LB", [(8, 8, 3, 3), (37, 23, 5, 4),
                                             (100, 64, 9, 8), (1, 5, 2, 6)])
    def test_sweep(self, NA, NB, LA, LB):
        from repro.kernels.path_join import ops
        A = jnp.asarray(rng.integers(-1, 40, (NA, LA)).astype(np.int32))
        B = jnp.asarray(rng.integers(-1, 40, (NB, LB)).astype(np.int32))
        r1 = ops.path_overlap(A, B, backend="jnp")
        r2 = ops.path_overlap(A, B, backend="interpret")
        assert np.array_equal(np.asarray(r1), np.asarray(r2))

    def test_join_validity_semantics(self):
        from repro.kernels.path_join import ops
        A = jnp.asarray(np.array([[0, 1, 2], [3, 4, 5]], np.int32))
        B = jnp.asarray(np.array([[9, 2], [5, 2], [7, 5]], np.int32))
        valid = np.asarray(ops.keyed_join_valid(A, 2, B, 1,
                                                backend="interpret"))
        # A0 (ends 2) joins B0 (ends 2, no overlap beyond key) -> True
        # A0 with B1 (ends 2 but contains 5? no -> shares only key) -> True
        assert valid[0, 0]
        assert valid[0, 1]
        # A1 ends 5; B2 ends 5 but also fine; B1 contains 5 but ends 2
        assert valid[1, 2]
        assert not valid[1, 0]

    def test_splice_validity(self):
        from repro.kernels.path_join import ops
        P = jnp.asarray(np.array([[0, 1], [2, 3]], np.int32))
        C = jnp.asarray(np.array([[4, 5], [1, 9]], np.int32))
        v = np.asarray(ops.splice_join_valid(P, 1, C, 1, backend="interpret"))
        assert v[0, 0] and not v[0, 1]   # (0,1)x(1,9) shares vertex 1
        assert v[1, 0] and v[1, 1]


class TestEllSpmm:
    @pytest.mark.parametrize("V,D,F,op", [(32, 4, 8, "sum"), (100, 5, 19, "sum"),
                                          (64, 3, 33, "max"), (130, 7, 128, "sum")])
    def test_sweep(self, V, D, F, op):
        from repro.kernels.ell_spmm import ops
        ell = jnp.asarray(rng.integers(0, V + 1, (V, D)).astype(np.int32))
        x = jnp.asarray(rng.standard_normal((V, F)).astype(np.float32))
        a = ops.ell_aggregate(ell, x, op=op, backend="jnp")
        b = ops.ell_aggregate(ell, x, op=op, backend="interpret")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_matches_segment_sum(self):
        from repro.kernels.ell_spmm import ops
        from repro.core.graph import DeviceGraph
        from repro.core import generators
        g = generators.erdos(50, 4.0, seed=3)
        dg = DeviceGraph.build(g)
        x = jnp.asarray(rng.standard_normal((g.n, 7)).astype(np.float32))
        # ELL over out-edges aggregates x over out-neighbors
        agg = ops.ell_aggregate(dg.ell_idx, x, op="sum", backend="interpret")
        src, dst = g.r_edges_by_dst   # edges of G keyed by src
        ref = jax.ops.segment_sum(x[jnp.asarray(src)], jnp.asarray(dst),
                                  num_segments=g.n)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(ref), atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,hd,causal", [
        (1, 16, 16, 2, 1, 8, True), (2, 64, 64, 4, 2, 32, True),
        (2, 64, 64, 4, 4, 32, False), (1, 1, 128, 8, 2, 16, True),
        (3, 33, 65, 6, 3, 24, True)])
    def test_sweep(self, B, Sq, Skv, Hq, Hkv, hd, causal):
        from repro.kernels.flash_attention import ops
        r = np.random.default_rng(1)
        q = jnp.asarray(r.standard_normal((B, Sq, Hq, hd)).astype(np.float32))
        k = jnp.asarray(r.standard_normal((B, Skv, Hkv, hd)).astype(np.float32))
        v = jnp.asarray(r.standard_normal((B, Skv, Hkv, hd)).astype(np.float32))
        a = ops.gqa_attention(q, k, v, causal=causal, backend="jnp")
        b = ops.gqa_attention(q, k, v, causal=causal, backend="interpret")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-4)

    def test_bf16(self):
        from repro.kernels.flash_attention import ops
        r = np.random.default_rng(2)
        q = jnp.asarray(r.standard_normal((2, 32, 4, 16)), jnp.bfloat16)
        k = jnp.asarray(r.standard_normal((2, 32, 2, 16)), jnp.bfloat16)
        v = jnp.asarray(r.standard_normal((2, 32, 2, 16)), jnp.bfloat16)
        a = ops.gqa_attention(q, k, v, backend="jnp").astype(jnp.float32)
        b = ops.gqa_attention(q, k, v, backend="interpret").astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-2, rtol=3e-2)

    def test_chunked_jnp_twin_matches_exact(self):
        """models.transformer.chunked_attention == exact softmax reference."""
        from repro.models.transformer import chunked_attention
        from repro.kernels.flash_attention.ref import attention_ref
        r = np.random.default_rng(3)
        B, Sq, Skv, Hq, Hkv, hd = 2, 24, 48, 4, 2, 16
        q = jnp.asarray(r.standard_normal((B, Sq, Hq, hd)).astype(np.float32))
        k = jnp.asarray(r.standard_normal((B, Skv, Hkv, hd)).astype(np.float32))
        v = jnp.asarray(r.standard_normal((B, Skv, Hkv, hd)).astype(np.float32))
        out = chunked_attention(q, k, v, causal=True, q_offset=Skv - Sq,
                                chunk=16)
        kk = jnp.repeat(k, Hq // Hkv, axis=2).transpose(0, 2, 1, 3).reshape(
            B * Hq, Skv, hd)
        vv = jnp.repeat(v, Hq // Hkv, axis=2).transpose(0, 2, 1, 3).reshape(
            B * Hq, Skv, hd)
        qq = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
        ref = attention_ref(qq, kk, vv, causal=True).reshape(
            B, Hq, Sq, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)
