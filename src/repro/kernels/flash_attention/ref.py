"""Pure-jnp oracle: exact softmax attention (materializes scores)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (BH, Sq, Dh); k, v: (BH, Skv, Dh)."""
    Dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (Dh ** 0.5)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # decode offset alignment
        mask = jnp.arange(Skv)[None, :] <= qpos
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
