"""Graph containers for the batch HC-s-t path engine.

The host-side ``Graph`` is built with numpy (CSR both directions, padded-ELL
views, destination-sorted edge lists). Device views are materialized lazily
as jnp arrays. All layouts are static-shape so every downstream stage is
jit-compilable:

  * CSR            -- indptr/indices, canonical storage.
  * edge list      -- (src, dst) sorted by dst; drives segment-reduce hops.
  * padded ELL     -- (V, max_deg_cap) neighbor matrix padded with the
                      sentinel row ``V`` (frontier tables carry one extra
                      zero row); drives the Pallas kernels and the
                      enumeration gather. Vertices with deg > cap spill to a
                      COO remainder (power-law safety valve).

Shape stability under mutation: every *device* view is quantized to a
power-of-two bucket so incremental edge churn (``delta.apply_delta``)
re-uses warm XLA compiles instead of retracing on each new ``(m,)``.
Edge lists are padded with **sentinel edges** ``(n, n)``: ``edst = n`` is
out of segment range, so ``segment_max`` / ``segment_sum`` drop the
message, and ``esrc = n`` gathers the all-zero sentinel row that every
frontier table carries — a sentinel edge is inert in both the boolean BFS
semiring and the walk-count DP. ELL capacities are bucketed the same way,
so a touched row growing within its bucket never changes the ``(n, cap)``
kernel shapes.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Optional, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:   # the "jax.Array" annotations below; jax itself is
    import jax      # imported lazily so host-only use never inits a device

__all__ = ["Graph", "DeviceGraph", "EllView", "pow2_ceil", "pad_edge_list"]

SENTINEL = -1


def pow2_ceil(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1) — the shared shape-bucket
    rounding for every device view (edge-list pads, ELL capacities, the
    delta path's scatter widths and MS-BFS hop budgets)."""
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def pad_edge_list(esrc: np.ndarray, edst: np.ndarray, n: int,
                  cap: int) -> tuple[np.ndarray, np.ndarray]:
    """Sentinel-pad a dst-sorted edge list to ``cap`` entries.

    Sentinel edges are ``(n, n)``: dropped by segment reductions over
    ``num_segments = n`` and reading the zero sentinel row on gathers, so
    the padded list is semantically identical to the exact one. ``n``
    sorts after every real destination, so the dst-sorted invariant (and
    ``indices_are_sorted=True`` segment ops) survives the pad.
    """
    m = int(esrc.shape[0])
    if cap < m:
        raise ValueError(f"edge bucket {cap} smaller than edge count {m}")
    if cap == m:
        return esrc.astype(np.int32, copy=False), \
            edst.astype(np.int32, copy=False)
    pad = np.full(cap - m, n, dtype=np.int32)
    return (np.concatenate([esrc.astype(np.int32, copy=False), pad]),
            np.concatenate([edst.astype(np.int32, copy=False), pad]))


@dataclasses.dataclass(frozen=True)
class EllView:
    """Padded ELL adjacency: idx[v, d] = d-th out-neighbor or n (sentinel)."""

    idx: np.ndarray          # (n, cap) int32, padded with n
    mask: np.ndarray         # (n, cap) bool
    spill_src: np.ndarray    # (n_spill,) int32 COO remainder
    spill_dst: np.ndarray    # (n_spill,) int32
    cap: int


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph, CSR in both directions. Vertices are 0..n-1."""

    n: int
    indptr: np.ndarray       # (n+1,) int64 — out-edges CSR
    indices: np.ndarray      # (m,) int32, sorted within row
    r_indptr: np.ndarray     # (n+1,) int64 — in-edges CSR (reverse graph)
    r_indices: np.ndarray    # (m,) int32

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(n: int, src, dst, dedup: bool = True) -> "Graph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size:
            keep = src != dst  # drop self loops: never on a simple path twice
            src, dst = src[keep], dst[keep]
        if dedup and src.size:
            key = src * n + dst
            _, uniq = np.unique(key, return_index=True)
            src, dst = src[uniq], dst[uniq]
        indptr, indices = _csr(n, src, dst)
        r_indptr, r_indices = _csr(n, dst, src)
        return Graph(n=n, indptr=indptr, indices=indices,
                     r_indptr=r_indptr, r_indices=r_indices)

    # ------------------------------------------------------------------
    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def in_degree(self) -> np.ndarray:
        return np.diff(self.r_indptr)

    def neighbors(self, v: int, reverse: bool = False) -> np.ndarray:
        ip, ix = (self.r_indptr, self.r_indices) if reverse else (self.indptr, self.indices)
        return ix[ip[v]:ip[v + 1]]

    # -- edge lists sorted by destination (segment-reduce friendly) ----
    @cached_property
    def edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of G with dst non-decreasing."""
        dst = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.r_indptr))
        src = self.r_indices
        return src.astype(np.int32), dst

    @cached_property
    def r_edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of G_r with dst non-decreasing (i.e. edges of G keyed by src)."""
        dst = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        src = self.indices
        return src.astype(np.int32), dst

    # -- padded ELL views ----------------------------------------------
    def ell(self, cap: Optional[int] = None, reverse: bool = False) -> EllView:
        ip, ix = (self.r_indptr, self.r_indices) if reverse else (self.indptr, self.indices)
        deg = np.diff(ip).astype(np.int64)
        if cap is None:
            cap = int(deg.max()) if self.n else 1
        cap = max(int(cap), 1)
        idx = np.full((self.n, cap), self.n, dtype=np.int32)
        # vectorized fill of the first `cap` neighbors per row
        take = np.minimum(deg, cap)
        rows = np.repeat(np.arange(self.n), take)
        cols = _ragged_arange(take)
        flat = np.repeat(ip[:-1], take) + cols
        idx[rows, cols] = ix[flat]
        mask = idx != self.n
        # spill: neighbors beyond cap
        extra = deg - take
        s_rows = np.repeat(np.arange(self.n, dtype=np.int32), extra)
        s_cols = _ragged_arange(extra) + np.repeat(take, extra)
        s_flat = np.repeat(ip[:-1], extra) + s_cols
        return EllView(idx=idx, mask=mask,
                       spill_src=s_rows, spill_dst=ix[s_flat].astype(np.int32),
                       cap=cap)

    def reverse(self) -> "Graph":
        return Graph(n=self.n, indptr=self.r_indptr, indices=self.r_indices,
                     r_indptr=self.indptr, r_indices=self.indices)

    # -- incremental mutation ------------------------------------------
    def apply_delta(self, delta) -> tuple["Graph", np.ndarray]:
        """Successor graph after a :class:`~repro.core.delta.GraphDelta`.

        Merges the (deduplicated, self-loop-free) edge mutations into both
        CSR directions without re-sorting the kept edges — equivalent to a
        ``from_edges`` rebuild on the edited edge list, in time
        proportional to ``m + |delta| log m``. Returns ``(new_graph,
        touched)`` where ``touched`` holds the unique endpoints of every
        *effective* change (no-op inserts/deletes excluded); an empty
        ``touched`` means ``new_graph is self``.
        """
        from .delta import apply_delta as _apply_delta
        applied = _apply_delta(self, delta)
        return applied.graph, applied.touched


def _csr(n: int, src: np.ndarray, dst: np.ndarray):
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst.astype(np.int32)


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated."""
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offs = np.repeat(np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    return np.arange(total, dtype=np.int64) - offs


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """jnp views of a Graph (built once per engine instance).

    ``m`` is the *valid* edge count; the edge arrays themselves are padded
    to the ``m_cap`` pow2 bucket with sentinel ``(n, n)`` edges (see
    :func:`pad_edge_list`), and ELL capacities are pow2-bucketed, so the
    traced shapes of every downstream kernel stay constant while the graph
    mutates within its buckets.
    """

    n: int
    m: int                   # valid edge count (m_valid); arrays hold m_cap
    # forward direction
    esrc: "jax.Array"        # (m_cap,) int32 sorted by dst, sentinel = n
    edst: "jax.Array"
    ell_idx: "jax.Array"     # (n, cap) int32, pad = n
    ell_mask: "jax.Array"
    # reverse direction
    r_esrc: "jax.Array"
    r_edst: "jax.Array"
    r_ell_idx: "jax.Array"
    r_ell_mask: "jax.Array"
    ell_cap: int
    r_ell_cap: int

    @property
    def m_cap(self) -> int:
        """Padded edge-bucket capacity (== m when built with pad=False)."""
        return int(self.esrc.shape[0])

    @property
    def m_valid(self) -> int:
        """Valid (non-sentinel) edge count — alias of ``m``, named for the
        kernels it is threaded through."""
        return self.m

    @staticmethod
    def build(g: Graph, ell_cap: Optional[int] = None, *,
              pad: bool = True, edge_cap: Optional[int] = None,
              min_ell_caps: tuple[int, int] = (1, 1),
              ) -> "DeviceGraph":
        """Materialize device views.

        pad=True (default) quantizes every shape to pow2 buckets: edge
        lists sentinel-padded to ``edge_cap`` (default ``pow2_ceil(m)``)
        and, when ``ell_cap`` is not given, ELL capacities bucketed to
        ``pow2_ceil(max degree)`` per direction, floored at
        ``min_ell_caps`` (fwd, rev) — the delta path passes its current
        caps so a rebuild never shrinks a bucket and grow/shrink churn
        around a boundary cannot thrash. pad=False keeps the exact
        legacy shapes (tests use it to assert padded/unpadded parity).
        """
        import jax.numpy as jnp

        if pad and ell_cap is None:
            deg = np.diff(g.indptr)
            r_deg = np.diff(g.r_indptr)
            cap_f = max(pow2_ceil(int(deg.max()) if deg.size else 1),
                        min_ell_caps[0])
            cap_r = max(pow2_ceil(int(r_deg.max()) if r_deg.size else 1),
                        min_ell_caps[1])
        else:
            cap_f = cap_r = ell_cap
        ell = g.ell(cap=cap_f)
        rell = g.reverse().ell(cap=cap_r)
        if ell.spill_src.size or rell.spill_src.size:
            raise ValueError(
                "ell_cap too small: spill present; enumeration requires the "
                "full ELL (pass ell_cap=None or >= max degree)")
        esrc, edst = g.edges_by_dst
        r_esrc, r_edst = g.r_edges_by_dst
        if pad:
            cap = pow2_ceil(g.m) if edge_cap is None else int(edge_cap)
            esrc, edst = pad_edge_list(esrc, edst, g.n, cap)
            r_esrc, r_edst = pad_edge_list(r_esrc, r_edst, g.n, cap)
        return DeviceGraph(
            n=g.n, m=g.m,
            esrc=jnp.asarray(esrc), edst=jnp.asarray(edst),
            ell_idx=jnp.asarray(ell.idx), ell_mask=jnp.asarray(ell.mask),
            r_esrc=jnp.asarray(r_esrc), r_edst=jnp.asarray(r_edst),
            r_ell_idx=jnp.asarray(rell.idx), r_ell_mask=jnp.asarray(rell.mask),
            ell_cap=ell.cap, r_ell_cap=rell.cap,
        )

    def direction(self, reverse: bool):
        """(ell_idx, ell_mask) for a search direction."""
        if reverse:
            return self.r_ell_idx, self.r_ell_mask
        return self.ell_idx, self.ell_mask
