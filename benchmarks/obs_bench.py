"""Observability benchmark + CI gate inputs (``--only obs``).

Two claims back the ``repro.obs`` overhead budget, both measured here on
the exp8 cross-batch workload (community graph, similarity-0.8 queries):

  * **cost**: tracing adds <= 5% to a warm (pure cache-hit) batch wall —
    both arms run in-process on the same engine, untraced first, so the
    comparison is same-hardware/same-state;
  * **coverage**: the exported Chrome trace names every pipeline stage
    (detect -> cluster -> cache -> index -> per-level MS-BFS -> join ->
    assemble -> transfer) and its per-stage durations explain >= 90% of
    the enumeration batch wall (``obs.trace.coverage``).

Also pinned: traced results are bit-identical to untraced, and the traced
measurement window compiles nothing (spans introduce no host-shape
drift). Writes ``results/trace_exp8.json`` (open in ui.perfetto.dev) and
``results/BENCH_obs.json`` for ``check_regression --obs``.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from repro.core.oracle import path_set
from repro.obs import trace as obstrace

from .common import record

# the stages the acceptance gate requires the warm exp8 trace to name
REQUIRED_STAGES = (
    "engine.run", "cluster.queries", "detect.cluster", "cache.get",
    "index.build", "msbfs.level", "enumerate.node", "enumerate.cluster",
    "join.keyed", "assemble.query", "transfer.paths",
)


def _workload(scale: float):
    n = max(300, int(4000 * scale))
    g = generators.community(n, n_comm=max(2, n // 1500), avg_deg=5.0,
                             seed=0)
    queries = generators.similar_queries(
        g, max(8, int(24 * min(scale, 1.0))), similarity=0.8,
        k_range=(3, 4), seed=1)
    return g, queries


def _best_of(engine, queries, repeats: int):
    best, last = None, None
    for _ in range(repeats):
        r = engine.run(queries)
        w = r.stats["t_wall_s"]
        best = w if best is None else min(best, w)
        last = r
    return best, last


def main(scale: float = 1.0, repeats: int = 3) -> dict:
    g, queries = _workload(scale)
    cfg = EngineConfig(min_cap=128, cache_bytes=256 << 20,
                       log_compiles=True)
    eng = BatchPathEngine(g, cfg)

    # warm both the jit caches and the cross-batch path cache, so the
    # measured arms compare a pure cache-hit batch (exp8's steady state)
    eng.run(queries)
    eng.run(queries)

    # -- overhead: untraced arm, then traced arm, same engine/state -----
    snap = eng.compile_log.snapshot()
    obstrace.disable()
    t_off, r_off = _best_of(eng, queries, repeats)
    obstrace.enable()
    eng.obs.reset()
    t_on, r_on = _best_of(eng, queries, repeats)
    warm_retraces = sum(eng.compile_log.since(snap).values())
    overhead_s = t_on - t_off
    overhead_rel = overhead_s / max(t_off, 1e-9)

    # traced results must be bit-identical to untraced
    parity_ok = all(
        path_set(r_on[qi].paths) == path_set(r_off[qi].paths)
        for qi in range(len(queries)))

    # -- coverage: full exp8 phase pattern under tracing ----------------
    # fresh cache so the cold batch actually enumerates (msbfs/join/splice
    # spans); the warm batch then exercises the pure-hit path; the host
    # materialization above already recorded transfer.paths spans
    eng2 = BatchPathEngine(g, cfg)
    eng2.obs.reset()
    r_cold = eng2.run(queries)
    r_warm = eng2.run(queries)
    for qi in range(len(queries)):
        assert path_set(r_warm[qi].paths) == path_set(r_off[qi].paths), qi
    out_dir = Path("results")
    out_dir.mkdir(parents=True, exist_ok=True)
    doc = eng2.obs.export(out_dir / "trace_exp8.json")
    obstrace.disable()

    loaded = obstrace.load(out_dir / "trace_exp8.json")  # parse round-trip
    names = obstrace.stage_names(loaded)
    missing = sorted(s for s in REQUIRED_STAGES if s not in names)
    cov_cold = obstrace.coverage(loaded, root="engine.run", occurrence=0)
    cov_warm = obstrace.coverage(loaded, root="engine.run", occurrence=-1)

    record("obs_warm_untraced", t_off * 1e6,
           f"hits={r_off.stats['n_cache_hits']}")
    record("obs_warm_traced", t_on * 1e6,
           f"overhead={overhead_rel:+.1%} spans={len(doc['traceEvents'])} "
           f"cov_cold={cov_cold:.2f} cov_warm={cov_warm:.2f}")
    if missing:
        record("obs_missing_stages", 0.0, ";".join(missing))

    result = {
        "n": g.n, "n_queries": len(queries), "repeats": repeats,
        "t_untraced_s": t_off, "t_traced_s": t_on,
        "overhead_s": overhead_s, "overhead_rel": overhead_rel,
        "parity_ok": parity_ok, "warm_retraces": warm_retraces,
        "n_events": len(doc["traceEvents"]),
        "stages": sorted(names), "missing_stages": missing,
        "coverage_cold": cov_cold, "coverage_warm": cov_warm,
        "cold_materialized": r_cold.stats["n_materialized"],
        "warm_cache_hits": r_warm.stats["n_cache_hits"],
    }
    (out_dir / "BENCH_obs.json").write_text(
        json.dumps(result, indent=1, default=str))
    return result


if __name__ == "__main__":
    main()
