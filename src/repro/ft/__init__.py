from .driver import TrainDriver, DriverConfig, FailureInjector
from .scheduler import WorkStealingScheduler

__all__ = ["TrainDriver", "DriverConfig", "FailureInjector",
           "WorkStealingScheduler"]
