"""Mesh-agnostic sharded checkpointing with async save and elastic restore.

Format: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (flattened
key paths) + ``manifest.json`` (tree structure, shapes, dtypes, step,
content hashes). Writes go to ``step_<n>.tmp`` and are atomically renamed —
a crash mid-save never corrupts the latest checkpoint (the FT driver then
resumes from the previous step; tests exercise this).

Leaves are saved as *global* logical arrays (device_get assembles shards),
so a restore can re-shard onto a different mesh/device count — the elastic
scaling path: ``restore_checkpoint(dir, abstract, shardings)`` device_puts
each leaf with the *new* sharding. At real multi-pod scale the same format
is written per-host with disjoint shard slices; the manifest carries the
global shape either way.

Async: ``CheckpointManager(..., async_save=True)`` snapshots to host
memory synchronously (cheap) and writes in a background thread, overlapping
I/O with the next training steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir: str | Path, step: int, tree,
                    extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step}"
    tmp = ckpt_dir / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "bytes": int(arr.nbytes),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for d in ckpt_dir.iterdir()
             if (m := _STEP_RE.match(d.name)) and (d / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, abstract_tree,
                       sharding_tree=None, step: Optional[int] = None):
    """Restore into the structure of `abstract_tree`, placing each leaf with
    `sharding_tree` (elastic re-shard) when given. Returns (tree, step, extra)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_abs = _flatten(abstract_tree)
    flat_sh = _flatten(sharding_tree) if sharding_tree is not None else None
    out = {}
    for key, spec in flat_abs.items():
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(d / ent["file"])
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tuple(spec.shape)}")
        if flat_sh is not None and key in flat_sh:
            out[key] = jax.device_put(arr.astype(spec.dtype), flat_sh[key])
        else:
            out[key] = jax.numpy.asarray(arr.astype(spec.dtype))
    # rebuild the tree
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
    ordered = [out["/".join(_path_str(p) for p in path)]
               for path, _ in leaves_paths]
    return (jax.tree_util.tree_unflatten(treedef, ordered), step,
            manifest.get("extra", {}))


class CheckpointManager:
    """Keeps the last `keep` checkpoints; optional async background writes."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        self.wait()
        if self._error:
            raise self._error
        # snapshot to host synchronously (device buffers may mutate next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            def work():
                try:
                    save_checkpoint(self.dir, step, host_tree, extra)
                    self._gc()
                except BaseException as e:  # noqa: BLE001
                    self._error = e
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.dir, step, host_tree, extra)
            self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, abstract_tree, sharding_tree=None, step=None):
        return restore_checkpoint(self.dir, abstract_tree, sharding_tree, step)

    def latest_step(self):
        return latest_step(self.dir)

    def _gc(self) -> None:
        steps = sorted(int(_STEP_RE.match(d.name).group(1))
                       for d in self.dir.iterdir()
                       if _STEP_RE.match(d.name) and d.is_dir())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
