"""CI bench-regression gate over the committed benchmark baselines.

Replaces the ad-hoc inline ``python -c`` assertions the smoke job used to
carry. Two kinds of checks:

  * **structural** (deterministic, hardware-independent): warm-loop
    retraces must be zero, the intentional bucket-crossing retrace must
    have been observed, sharded results must equal single-device.
  * **latency** (hardware-dependent, gated with a threshold): the smoke
    run's warm p50 batch wall must not regress more than ``--max-regression``
    (default 25%) against the committed baseline, and the sharded smoke
    must clear ``--min-sharded-speedup`` when several devices are visible.

Usage (what .github/workflows/ci.yml runs)::

    python -m benchmarks.check_regression \
        --baseline benchmarks/baselines/BENCH_dynamic_smoke.json \
        --current results/BENCH_dynamic.json
    python -m benchmarks.check_regression \
        --sharded results/BENCH_sharded.json --min-sharded-speedup 1.5

Baselines are committed from a run on the same workload scale the smoke
job uses; wall-clock comparisons across *different* hardware are noisy,
so the latency gate is a coarse 25% tripwire, not a microbenchmark —
pass ``--max-regression 0`` to skip it entirely.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

FAILURES: list[str] = []


def _fail(msg: str) -> None:
    FAILURES.append(msg)
    print(f"FAIL: {msg}")


def _ok(msg: str) -> None:
    print(f"  ok: {msg}")


def check_dynamic(current: dict, baseline: dict, max_regression: float) -> None:
    # structural: the pow2 sentinel buckets must keep the warm loop warm
    if current.get("warm_retraces", -1) != 0:
        _fail(f"warm loop retraced: {current.get('warm_retraces')} "
              f"({current.get('warm_compiles_by_kernel')})")
    else:
        _ok("warm loop retraces: 0")
    crossing = current.get("bucket_crossing", {})
    if crossing.get("edge_kernel_retraces", 0) <= 0:
        _fail("bucket-crossing retrace not observed (the crossing phase "
              "did not exercise the edge kernels)")
    else:
        _ok(f"bucket-crossing retraces observed: "
            f"{crossing['edge_kernel_retraces']}")
    # machine-relative property (both arms measured in the SAME run, so
    # this holds on any hardware): the hop-scoped delta arm must not be
    # slower than the full-invalidation rebuild arm. exp10 itself only
    # guarantees it above tiny scales (strict_latency), so gate on that.
    if current.get("strict_latency"):
        d, r = current.get("p50_batch_s_delta"), current.get("p50_batch_s_rebuild")
        if d is not None and r is not None and d > r:
            _fail(f"delta arm p50 {d * 1e3:.1f}ms slower than rebuild arm "
                  f"{r * 1e3:.1f}ms in the same run")
        else:
            _ok(f"delta p50 {d * 1e3:.1f}ms <= rebuild p50 {r * 1e3:.1f}ms")
    # latency tripwire vs the committed smoke baseline
    if max_regression <= 0:
        print("  (latency gate skipped)")
        return
    cur = current.get("p50_batch_s_delta")
    base = baseline.get("p50_batch_s_delta")
    if cur is None or base is None:
        _fail("p50_batch_s_delta missing from current or baseline json")
        return
    limit = base * (1.0 + max_regression)
    if cur > limit:
        _fail(f"warm p50 regressed: {cur * 1e3:.1f}ms vs baseline "
              f"{base * 1e3:.1f}ms (limit {limit * 1e3:.1f}ms)")
    else:
        _ok(f"warm p50 {cur * 1e3:.1f}ms <= {limit * 1e3:.1f}ms "
            f"(baseline {base * 1e3:.1f}ms + {max_regression:.0%})")


def check_kernels(current: dict, baseline: dict | None) -> None:
    """Gate the fused-kernel dispatch contract (all structural/deterministic):
    the packed sweeps must stay warm, and the fused arm must dispatch
    strictly fewer ops per MS-BFS level than the jnp reference arm — both
    within this run and against the committed jnp baseline."""
    if current.get("warm_retraces", -1) != 0:
        _fail(f"kernel warm sweeps retraced: {current.get('warm_retraces')} "
              f"({current.get('warm_compiles_by_kernel')})")
    else:
        _ok("kernel warm sweeps retraces: 0")
    disp = current.get("dispatch", {})
    fused = disp.get("fused_eqns_per_level")
    ref = disp.get("jnp_eqns_per_level")
    if fused is None or ref is None:
        _fail("dispatch counts missing from kernels json")
        return
    if fused >= ref:
        _fail(f"fused arm dispatches {fused} eqns/level, not fewer than "
              f"the jnp arm's {ref}")
    else:
        _ok(f"fused eqns/level {fused} < jnp {ref} "
            f"(compiled jnp entry ops: {disp.get('jnp_hlo_entry_ops')})")
    if baseline is not None:
        base_ref = baseline.get("dispatch", {}).get("jnp_eqns_per_level")
        if base_ref is None:
            _fail("jnp_eqns_per_level missing from kernels baseline")
        elif fused >= base_ref:
            _fail(f"fused eqns/level {fused} not below committed jnp "
                  f"baseline {base_ref}")
        else:
            _ok(f"fused eqns/level {fused} < committed jnp baseline "
                f"{base_ref}")


def check_obs(current: dict, max_overhead: float) -> None:
    """Gate the observability contract (results/BENCH_obs.json):
    traced == untraced results, zero warm retraces with tracing on, the
    exported trace names every pipeline stage and explains >= 90% of the
    batch wall, and the traced warm arm costs <= ``max_overhead`` (with a
    10ms absolute floor — sub-millisecond walls make a relative gate
    pure noise)."""
    if not current.get("parity_ok", False):
        _fail("traced results differ from untraced (parity broken)")
    else:
        _ok("traced == untraced results")
    if current.get("warm_retraces", -1) != 0:
        _fail(f"tracing retraced the warm loop: "
              f"{current.get('warm_retraces')}")
    else:
        _ok("traced warm loop retraces: 0")
    missing = current.get("missing_stages", ["<field missing>"])
    if missing:
        _fail(f"trace is missing pipeline stages: {missing}")
    else:
        _ok(f"trace names all required stages "
            f"({len(current.get('stages', []))} span names)")
    cov = current.get("coverage_cold", 0.0)
    if cov < 0.90:
        _fail(f"stage spans explain only {cov:.0%} of the enumeration "
              f"batch wall (need >= 90%)")
    else:
        _ok(f"stage coverage {cov:.0%} of batch wall "
            f"(warm batch: {current.get('coverage_warm', 0.0):.0%})")
    if max_overhead <= 0:
        print("  (overhead gate skipped)")
        return
    t_off = current.get("t_untraced_s")
    t_on = current.get("t_traced_s")
    if t_off is None or t_on is None:
        _fail("t_untraced_s / t_traced_s missing from obs json")
        return
    limit = max(max_overhead * t_off, 0.010)
    if t_on - t_off > limit:
        _fail(f"tracing overhead {(t_on - t_off) * 1e3:.1f}ms on a "
              f"{t_off * 1e3:.1f}ms warm batch exceeds "
              f"{limit * 1e3:.1f}ms")
    else:
        _ok(f"tracing overhead {(t_on - t_off) * 1e3:+.1f}ms on "
            f"{t_off * 1e3:.1f}ms warm batch (limit {limit * 1e3:.1f}ms)")


def check_static(budgets: Path | None) -> None:
    """Structural gate over the committed dispatch budgets: run the layer-2
    jaxpr audit (repro.analysis) — every hot function must trace without
    host callbacks, stay within its DISPATCH_BUDGETS.json eqn budget, and
    keep the fused kernels at their committed dispatches per level."""
    from repro.analysis import run_audit
    report = run_audit(budgets)
    for f in report.violations:
        _fail(f.render())
    if report.ok:
        _ok(f"jaxpr audit clean: {report.n_functions} hot function(s) "
            f"within committed dispatch budgets")


def check_routing(current: dict, baseline: dict | None,
                  min_speedup: float, max_regression: float) -> None:
    """Gate the cost-routing contract (results/BENCH_routing.json from
    exp12): AUTO must be result-equal to the forced planners, actually
    route both GREEN and YELLOW on the mixed workload, add zero warm
    retraces, keep the lone-query admission wait inside the deadline
    bound, and not lose to the best single global planner (both arms
    measured in the SAME run, so the speedup gate is machine-relative)."""
    if not current.get("parity_ok", False):
        _fail("AUTO results differ from forced planners (parity broken)")
    else:
        _ok("AUTO == forced planners on every output kind")
    if current.get("warm_retraces", -1) != 0:
        _fail(f"routing retraced the warm loop: "
              f"{current.get('warm_retraces')}")
    else:
        _ok("routed warm loop retraces: 0")
    routed = current.get("routed", {})
    if routed.get("green", 0) <= 0 or routed.get("yellow", 0) <= 0:
        _fail(f"mixed workload did not exercise both tiers: {routed}")
    else:
        _ok(f"routed green={routed['green']} yellow={routed['yellow']} "
            f"red={routed.get('red', 0)}")
    if not current.get("fast_path_ok", False):
        _fail("exists-only query did not resolve via the submit fast path")
    else:
        _ok("streaming fast path answered exists at submit")
    wait, bound = (current.get("admission_wait_max_s"),
                   current.get("admission_bound_s"))
    if wait is None or bound is None:
        _fail("admission_wait_max_s / admission_bound_s missing")
    elif wait > bound:
        _fail(f"lone-query admission wait {wait:.3f}s exceeds deadline "
              f"bound {bound:.3f}s")
    else:
        _ok(f"admission wait {wait:.3f}s <= bound {bound:.3f}s")
    speedup = current.get("speedup_vs_best_single", 0.0)
    if speedup < min_speedup:
        _fail(f"AUTO speedup {speedup:.2f}x vs best single planner < "
              f"required {min_speedup:.2f}x")
    else:
        _ok(f"AUTO {speedup:.2f}x vs best single planner "
            f"(>= {min_speedup:.2f}x)")
    # latency tripwire vs the committed smoke baseline
    if baseline is None or max_regression <= 0:
        print("  (routing latency gate skipped)")
        return
    cur, base = current.get("t_auto_s"), baseline.get("t_auto_s")
    if cur is None or base is None:
        _fail("t_auto_s missing from current or baseline routing json")
        return
    limit = base * (1.0 + max_regression)
    if cur > limit:
        _fail(f"AUTO wall regressed: {cur * 1e3:.1f}ms vs baseline "
              f"{base * 1e3:.1f}ms (limit {limit * 1e3:.1f}ms)")
    else:
        _ok(f"AUTO wall {cur * 1e3:.1f}ms <= {limit * 1e3:.1f}ms "
            f"(baseline {base * 1e3:.1f}ms + {max_regression:.0%})")


def check_serving(current: dict, baseline: dict | None,
                  max_regression: float) -> None:
    """Gate the open-loop SLO serving contract (BENCH_serving.json from
    exp11): >= 3 offered-load levels with zero lost queries and zero warm
    retraces, the overload level must actually shed while still making
    goodput, the mid-stream failover must recover with nothing lost or
    duplicated and the cross-batch cache intact, and each level's
    quantum-normalized p99 (``p99_x`` — machine-independent under the
    deterministic service model) must not regress vs the committed smoke
    baseline."""
    levels = current.get("levels", [])
    if len(levels) < 3:
        _fail(f"only {len(levels)} offered-load level(s) (need >= 3)")
    else:
        names = ", ".join("{} {}x".format(lv.get("kind"),
                                          lv.get("offered_mult"))
                          for lv in levels)
        _ok(f"{len(levels)} offered-load levels ({names})")
    lost = current.get("n_lost_total", -1)
    if lost != 0:
        _fail(f"open loop lost {lost} queries (every submitted qid must "
              f"resolve to exactly one OK or SHED result)")
    else:
        _ok("zero lost queries across all levels")
    if current.get("warm_retraces", -1) != 0:
        _fail(f"open-loop replay retraced warm shapes: "
              f"{current.get('warm_retraces')}")
    else:
        _ok("measured replays warm retraces: 0")
    if levels:
        top = max(levels, key=lambda lv: lv.get("offered_mult", 0.0))
        if top.get("n_shed", 0) <= 0:
            _fail(f"overload level ({top.get('offered_mult')}x) shed "
                  f"nothing — the shed path went unexercised")
        elif top.get("goodput_qps", 0.0) <= 0.0:
            _fail("overload level made zero goodput")
        else:
            _ok(f"overload level shed {top['n_shed']} "
                f"({top.get('shed_reasons')}) at goodput "
                f"{top['goodput_qps']:.0f} qps")
    fo = current.get("failover", {})
    if fo.get("failovers", 0) < 1 or fo.get("requeued", 0) < 1:
        _fail(f"mid-stream failover not exercised: {fo}")
    elif fo.get("n_lost", -1) != 0 or fo.get("n_dup", -1) != 0:
        _fail(f"failover lost {fo.get('n_lost')} / duplicated "
              f"{fo.get('n_dup')} results")
    elif not (fo.get("cache_kept") and fo.get("oracle_ok")
              and fo.get("revived_ok")):
        _fail(f"failover recovery incomplete: cache_kept="
              f"{fo.get('cache_kept')} oracle_ok={fo.get('oracle_ok')} "
              f"revived_ok={fo.get('revived_ok')}")
    else:
        _ok(f"failover absorbed: {fo['requeued']} cluster(s) requeued, "
            f"0 lost, 0 dup, cache kept "
            f"({fo.get('cache_entries_after')} entries)")
    if baseline is None or max_regression <= 0:
        print("  (serving latency gate skipped)")
        return
    for lv, blv in zip(levels, baseline.get("levels", [])):
        cur, base = lv.get("p99_x"), blv.get("p99_x")
        tag = f"{lv.get('kind')} {lv.get('offered_mult')}x"
        if cur is None or base is None:
            _fail(f"p99_x missing for level {tag}")
            continue
        limit = base * (1.0 + max_regression)
        if cur > limit:
            _fail(f"{tag}: normalized p99 regressed: {cur:.2f} quanta vs "
                  f"baseline {base:.2f} (limit {limit:.2f})")
        else:
            _ok(f"{tag}: p99 {cur:.2f} quanta <= {limit:.2f} "
                f"(baseline {base:.2f} + {max_regression:.0%})")


def check_sharded(current: dict, min_speedup: float) -> None:
    if not current.get("equal", False):
        _fail("sharded results are NOT equal to single-device")
    else:
        _ok("sharded == single-device")
    if current.get("warm_retraces", -1) != 0:
        _fail(f"sharded warm loop retraced: {current.get('warm_retraces')}")
    else:
        _ok("sharded warm loop retraces: 0")
    n_dev = current.get("n_devices", 1)
    speedup = current.get("speedup", 0.0)
    if n_dev <= 1:
        print(f"  (speedup gate skipped: {n_dev} device)")
    elif speedup < min_speedup:
        # replica concurrency is capped at host cores — report it so a
        # miss on a constrained runner is diagnosable at a glance
        _fail(f"sharded speedup {speedup:.2f}x < required "
              f"{min_speedup:.2f}x on {n_dev} devices "
              f"({current.get('cpu_count', '?')} host cores)")
    else:
        _ok(f"sharded speedup {speedup:.2f}x on {n_dev} devices "
            f"(>= {min_speedup:.2f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=Path, default=None,
                    help="committed BENCH_dynamic baseline json")
    ap.add_argument("--current", type=Path, default=None,
                    help="this run's results/BENCH_dynamic.json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed warm-p50 slowdown vs baseline "
                         "(0.25 = 25%%; 0 skips the latency gate)")
    ap.add_argument("--sharded", type=Path, default=None,
                    help="this run's results/BENCH_sharded.json")
    ap.add_argument("--min-sharded-speedup", type=float, default=1.5,
                    help="required sharded-vs-single warm speedup when "
                         "more than one device is visible")
    ap.add_argument("--kernels", type=Path, default=None,
                    help="this run's results/BENCH_kernels.json")
    ap.add_argument("--kernels-baseline", type=Path, default=None,
                    help="committed BENCH_kernels baseline json (optional; "
                         "adds the fused-vs-committed-jnp dispatch gate)")
    ap.add_argument("--obs", type=Path, default=None,
                    help="this run's results/BENCH_obs.json (observability "
                         "overhead/coverage gate)")
    ap.add_argument("--max-obs-overhead", type=float, default=0.05,
                    help="allowed traced-vs-untraced warm-batch overhead "
                         "(0.05 = 5%%, with a 10ms absolute floor; 0 skips "
                         "the overhead gate)")
    ap.add_argument("--static", action="store_true",
                    help="run the repro.analysis jaxpr audit against the "
                         "committed dispatch budgets")
    ap.add_argument("--static-budgets", type=Path, default=None,
                    help="DISPATCH_BUDGETS.json path (default: "
                         "benchmarks/baselines/DISPATCH_BUDGETS.json)")
    ap.add_argument("--routing", type=Path, default=None,
                    help="this run's results/BENCH_routing.json (cost-"
                         "routing parity/retrace/admission/speedup gate)")
    ap.add_argument("--routing-baseline", type=Path, default=None,
                    help="committed BENCH_routing baseline json (optional; "
                         "adds the AUTO-wall latency tripwire)")
    ap.add_argument("--min-routing-speedup", type=float, default=1.0,
                    help="required AUTO speedup vs the best single global "
                         "planner (same-run, machine-relative)")
    ap.add_argument("--serving", type=Path, default=None,
                    help="this run's results/BENCH_serving.json (open-loop "
                         "SLO serving gate: lost/retraces/shed/failover)")
    ap.add_argument("--serving-baseline", type=Path, default=None,
                    help="committed BENCH_serving baseline json (optional; "
                         "adds the per-level normalized-p99 tripwire)")
    args = ap.parse_args()
    if (args.current is None and args.sharded is None
            and args.kernels is None and args.obs is None
            and args.routing is None and args.serving is None
            and not args.static):
        ap.error("nothing to check: pass --current, --sharded, --kernels, "
                 "--obs, --routing, --serving and/or --static")

    if args.current is not None:
        if args.baseline is None:
            ap.error("--current needs --baseline")
        print(f"dynamic: {args.current} vs baseline {args.baseline}")
        check_dynamic(json.loads(args.current.read_text()),
                      json.loads(args.baseline.read_text()),
                      args.max_regression)
    if args.sharded is not None:
        print(f"sharded: {args.sharded}")
        check_sharded(json.loads(args.sharded.read_text()),
                      args.min_sharded_speedup)
    if args.kernels is not None:
        print(f"kernels: {args.kernels}"
              + (f" vs baseline {args.kernels_baseline}"
                 if args.kernels_baseline else ""))
        base = (json.loads(args.kernels_baseline.read_text())
                if args.kernels_baseline else None)
        check_kernels(json.loads(args.kernels.read_text()), base)
    if args.obs is not None:
        print(f"obs: {args.obs}")
        check_obs(json.loads(args.obs.read_text()), args.max_obs_overhead)
    if args.routing is not None:
        print(f"routing: {args.routing}"
              + (f" vs baseline {args.routing_baseline}"
                 if args.routing_baseline else ""))
        base = (json.loads(args.routing_baseline.read_text())
                if args.routing_baseline else None)
        check_routing(json.loads(args.routing.read_text()), base,
                      args.min_routing_speedup, args.max_regression)
    if args.serving is not None:
        print(f"serving: {args.serving}"
              + (f" vs baseline {args.serving_baseline}"
                 if args.serving_baseline else ""))
        base = (json.loads(args.serving_baseline.read_text())
                if args.serving_baseline else None)
        check_serving(json.loads(args.serving.read_text()), base,
                      args.max_regression)
    if args.static:
        print("static: jaxpr audit vs committed dispatch budgets")
        check_static(args.static_budgets)
    if FAILURES:
        sys.exit(f"{len(FAILURES)} regression check(s) failed")
    print("all regression checks passed")


if __name__ == "__main__":
    main()
