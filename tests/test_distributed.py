"""Multi-device semantics tests (8 fake CPU devices via subprocess — the
XLA device-count flag must be set before jax initializes, so these run in
isolated interpreters)."""
import subprocess
import sys
import textwrap

import jax
import pytest

pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs the modern jax sharding API (jax.make_mesh axis_types, "
           "jax.set_mesh, jax.shard_map); installed jax is too old")


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PREAMBLE = """
import os, sys
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
"""


def test_flash_decode_matches_baseline():
    """shard_map flash-decoding == gathered-KV decode on a (2, 4) mesh."""
    out = _run(PREAMBLE + """
import dataclasses
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_bundle
from repro.models.sharding import Rules
from repro.config import RunOptions
from repro.models import transformer
from repro import configs as cr

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = Rules(mesh)
cfg = cr.get("granite-8b").REDUCED
B, S = 4, 32
params = transformer.init_lm_params(jax.random.PRNGKey(0), cfg, tp=4)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
outs = {}
with jax.set_mesh(mesh):
    for fd in [False, True]:
        opts = RunOptions(flash_decode=fd, attn_chunk=8, seq_parallel=False)
        cache = transformer.init_cache(cfg, B, S, dtype=jnp.float32)
        # pre-fill some cache content at positions 0..9
        k0 = jax.random.normal(jax.random.PRNGKey(2),
                               (cfg.n_layers, B, 10, cfg.n_kv_heads, cfg.hd))
        cache["k"] = cache["k"].at[:, :, :10].set(k0)
        cache["v"] = cache["v"].at[:, :, :10].set(k0 * 0.5)
        cache["pos"] = jnp.int32(10)
        c_spec = jax.tree.map(
            lambda ax: rules.sharding(*ax) if isinstance(ax, tuple) else rules.sharding(),
            transformer.cache_logical(False),
            is_leaf=lambda x: isinstance(x, tuple))
        cache = jax.device_put(cache, c_spec)
        constrain = lambda x, axes: jax.lax.with_sharding_constraint(
            x, rules.sharding(*axes))
        logits, _ = jax.jit(
            lambda p, t, c: transformer.decode_step(p, t, c, cfg, opts,
                                                    constrain))(params, toks, cache)
        outs[fd] = np.asarray(logits)
err = np.abs(outs[True] - outs[False]).max()
print("MAXERR", err)
assert err < 2e-3, err
""")
    assert "MAXERR" in out


def test_distributed_msbfs_matches_single_device():
    """Vertex-sharded MS-BFS hop under pjit == single-device reference."""
    out = _run(PREAMBLE + """
from repro.core.graph import DeviceGraph
from repro.core import generators
from repro.core.msbfs import msbfs_dist
from jax.sharding import PartitionSpec as P, NamedSharding

g = generators.erdos(512, 4.0, seed=0)
dg = DeviceGraph.build(g)
srcs = jnp.asarray(np.arange(16, dtype=np.int32))
# pad the (already pow2 sentinel-padded) edge list to a device multiple
# by repeating the last entry (sentinel or duplicate edge: both are
# no-ops in the boolean BFS semiring)
m_cap = int(dg.esrc.shape[0])
m8 = -(-m_cap // 8) * 8
pad = m8 - m_cap
esrc_p = jnp.concatenate([dg.esrc, jnp.repeat(dg.esrc[-1:], pad)])
edst_p = jnp.concatenate([dg.edst, jnp.repeat(dg.edst[-1:], pad)])
ref = np.asarray(msbfs_dist(esrc_p, edst_p, srcs, n=g.n, k_max=4))

mesh = jax.make_mesh((8,), ("cells",),
                     axis_types=(jax.sharding.AxisType.Auto,))
with jax.set_mesh(mesh):
    esrc = jax.device_put(esrc_p, NamedSharding(mesh, P("cells")))
    edst = jax.device_put(edst_p, NamedSharding(mesh, P("cells")))
    dist = np.asarray(msbfs_dist(esrc, edst, srcs, n=g.n, k_max=4))
print("EQ", np.array_equal(ref, dist))
assert np.array_equal(ref, dist)
""")
    assert "EQ True" in out


def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,2) — elastic scaling."""
    out = _run(PREAMBLE + """
import tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import save_checkpoint, restore_checkpoint

tree = {"w": jnp.arange(64.0).reshape(8, 8), "s": jnp.float32(3.0)}
m1 = jax.make_mesh((4, 2), ("data", "model"),
                   axis_types=(jax.sharding.AxisType.Auto,) * 2)
placed = {"w": jax.device_put(tree["w"], NamedSharding(m1, P("data", "model"))),
          "s": tree["s"]}
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 3, placed)
    m2 = jax.make_mesh((2, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = {"w": NamedSharding(m2, P("data", "model")),
          "s": NamedSharding(m2, P())}
    got, step, _ = restore_checkpoint(d, abstract, sh)
    assert step == 3
    assert np.array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.mesh.devices.size == 4
print("RESHARD OK")
""")
    assert "RESHARD OK" in out


def test_ring_aggregate_matches_segment_sum():
    """GNN ring SpMM (collective_permute schedule) == local segment_sum."""
    out = _run(PREAMBLE + """
from jax.sharding import PartitionSpec as P
from repro.models.gnn import ring_aggregate

P_DEV = 8
N_loc, F, Eb = 16, 5, 40
N = P_DEV * N_loc
rng = np.random.default_rng(0)
h = rng.standard_normal((N, F)).astype(np.float32)
# random edges; bucket by (dst_owner, src_owner)
E = 500
src = rng.integers(0, N, E)
dst = rng.integers(0, N, E)
es = np.zeros((P_DEV, P_DEV, Eb), np.int32)
ed = np.zeros((P_DEV, P_DEV, Eb), np.int32)
em = np.zeros((P_DEV, P_DEV, Eb), bool)
fill = np.zeros((P_DEV, P_DEV), int)
kept = []
for s_, d_ in zip(src, dst):
    po, so = d_ // N_loc, s_ // N_loc
    i = fill[po, so]
    if i >= Eb:
        continue
    es[po, so, i] = s_ % N_loc
    ed[po, so, i] = d_ % N_loc
    em[po, so, i] = True
    fill[po, so] += 1
    kept.append((s_, d_))
ref = np.zeros((N, F), np.float32)
for s_, d_ in kept:
    ref[d_] += h[s_]

mesh = jax.make_mesh((P_DEV,), ("cells",),
                     axis_types=(jax.sharding.AxisType.Auto,))
fn = jax.shard_map(
    lambda hh, a, b, c: ring_aggregate(hh, a[0], b[0], c[0], "cells"),
    mesh=mesh,
    in_specs=(P("cells"), P("cells"), P("cells"), P("cells")),
    out_specs=P("cells"), check_vma=False)
got = np.asarray(fn(h.reshape(P_DEV * N_loc, F), es, ed, em))
print("MAXERR", np.abs(got - ref).max())
assert np.allclose(got, ref, atol=1e-5)
""")
    assert "MAXERR" in out
