"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms on TPU v5e
constants:

    compute    = FLOPs            / (chips * 197e12 bf16 FLOP/s)
    memory     = HBM bytes        / (chips * 819e9 B/s)
    collective = collective bytes / (chips * 50e9 B/s per ICI link)

Numerator sources (all reported side by side; the *_est columns drive the
bottleneck verdict):

  * MODEL_FLOPS        -- analytic 6*N*D / 2*N_active*D etc. (exact)
  * hlo_flops_raw      -- compiled.cost_analysis() per device * chips.
                          XLA counts while (scan) bodies ONCE, so this
                          undercounts layer loops; kept as a lower bound.
  * hlo_flops_est      -- dot-ops parsed from HLO text with while-loop trip
                          counts recovered from each loop's condition
                          (constant bound of the induction variable), so
                          scan bodies are multiplied out. Primary estimate.
  * collective_bytes   -- HLO collective census (result-shape bytes of
                          all-gather/all-reduce/reduce-scatter/all-to-all/
                          collective-permute), trip-adjusted the same way.
  * hbm_bytes          -- analytic traffic model per family (weights/optimizer
                          streams + activation read/write incl. remat factor)
                          cross-checked against cost_analysis bytes.

CPU-backend caveat (recorded per cell): XLA-CPU lowers bf16 dots via f32
converts and sometimes hoists them (inflating temp memory); TPU consumes
bf16 natively. Memory-fit verdicts quote both raw and adjusted peaks.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

HW = {
    "peak_flops": 197e12,     # bf16 per chip (v5e)
    "hbm_bw": 819e9,          # B/s per chip
    "link_bw": 50e9,          # B/s per ICI link
    "hbm_cap": 16 * 2**30,    # v5e HBM
}

__all__ = ["analyze_cell", "analyze_dir", "hlo_dot_flops", "main"]

DT = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
      "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_SHAPE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                    r"\[([0-9,]*)\]")


def _nelems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def hlo_dot_flops(hlo: str) -> tuple[float, dict]:
    """Parse dot/convolution FLOPs per computation, resolve while-loop trip
    counts from loop conditions, and fold the call tree.

    Returns (total_flops_per_device, debug dict).
    """
    # --- split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        st = line.rstrip()
        if not st:
            continue
        if not line.startswith(" "):           # computation header
            m = re.match(r"^(?:ENTRY )?%?([\w.\-]+)", st)
            if m and "{" in st:
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(st.strip())

    # --- per-computation: symbol shapes, dot flops, calls
    dot_flops: dict[str, float] = {}
    calls: dict[str, list[tuple[str, str]]] = {}   # comp -> [(kind, callee)]
    consts: dict[str, dict[str, int]] = {}         # comp -> {sym: int const}
    for name, lines in comps.items():
        shapes: dict[str, tuple[str, str]] = {}
        flops = 0.0
        cl = []
        cs = {}
        for ln in lines:
            m = re.match(r"(?:ROOT )?%?([\w.\-]+) = ", ln)
            if not m:
                continue
            sym = m.group(1)
            sm = _SHAPE.search(ln.split("=", 1)[1])
            if sm:
                shapes[sym] = (sm.group(1), sm.group(2))
            cm = re.search(r"s32\[\] constant\((\d+)\)", ln)
            if cm:
                cs[sym] = int(cm.group(1))
            if " dot(" in ln:
                out = _SHAPE.search(ln.split("=", 1)[1])
                lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                ops = re.search(r"dot\(([^)]*)\)", ln)
                if out and ops:
                    out_n = _nelems(out.group(2))
                    contract = 1
                    if lhs_c and lhs_c.group(1):
                        lhs_sym = ops.group(1).split(",")[0].strip().lstrip("%")
                        if lhs_sym in shapes:
                            ldims = shapes[lhs_sym][1].split(",")
                            for ci in lhs_c.group(1).split(","):
                                if ci and int(ci) < len(ldims) and ldims[int(ci)]:
                                    contract *= int(ldims[int(ci)])
                    flops += 2.0 * out_n * contract
            for kind, pat in (("while_body", r"body=%?([\w.\-]+)"),
                              ("while_cond", r"condition=%?([\w.\-]+)"),
                              ("call", r"(?:to_apply|calls)=%?([\w.\-]+)")):
                for mm in re.finditer(pat, ln):
                    cl.append((kind, mm.group(1)))
        dot_flops[name] = flops
        calls[name] = cl
        consts[name] = cs

    # --- while trip counts: cond computation compares induction to constant
    trip_of_cond: dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            if "compare(" in ln and ("direction=LT" in ln or "direction=LE" in ln):
                syms = re.findall(r"%([\w.\-]+)", ln.split("compare(", 1)[1])
                for s in syms:
                    if s in consts.get(name, {}):
                        t = consts[name][s]
                        trip_of_cond[name] = t + (1 if "LE" in ln else 0)

    # --- fold: total flops of comp = own + calls (+ body*trip for whiles)
    memo: dict[str, float] = {}

    def fold(name: str, depth=0) -> float:
        if name in memo or depth > 50:
            return memo.get(name, 0.0)
        total = dot_flops.get(name, 0.0)
        body_trip = None
        # pair body with cond to find trip
        conds = [c for k, c in calls.get(name, []) if k == "while_cond"]
        for c in conds:
            if c in trip_of_cond:
                body_trip = trip_of_cond[c]
        for kind, callee in calls.get(name, []):
            if callee == name:
                continue
            if kind == "while_body":
                t = body_trip if body_trip else 1
                total += t * fold(callee, depth + 1)
            elif kind == "call":
                total += fold(callee, depth + 1)
        memo[name] = total
        return total

    entry = None
    for name in comps:
        if "main" in name or entry is None:
            if entry is None or "main" in name:
                entry = name
    # HLO text: whiles appear as ops inside computations; handle top-level:
    # fold every computation reachable from the entry via ops' body/cond refs
    # (while ops live inside computations, captured in calls above).
    total = fold(entry) if entry else sum(dot_flops.values())
    return total, {"trips": trip_of_cond, "entry": entry}


def _analytic_hbm(meta: dict, chips: int) -> float:
    """Per-step global HBM traffic (bytes), coarse but family-aware."""
    fam = meta.get("family")
    if fam == "lm":
        N, Na = meta["params"], meta["active_params"]
        toks = meta["tokens"]
        L, = (meta["n_layers"],)
        d_traffic = 0.0
        if meta["kind"] == "train":
            d_traffic += Na * 2 * 3           # bf16 weights read fwd+bwd+rematfwd
            d_traffic += N * 4 * 5            # f32 master r/w + m,v r/w
            act = toks * L * meta.get("d_model", 0)
        else:
            d_traffic += Na * 2               # weights read once per step
        d_traffic += meta.get("kv_cache_bytes", 0)
        # activation traffic ~ 24 bytes per token-layer-channel (bf16 r/w
        # through qkv/attn/ffn incl. one remat recompute)
        dm = meta.get("seq_len", 1)
        d_traffic += toks * L * 24 * 2 * (Na / max(L, 1)) ** 0  # placeholder 0-exp
        return d_traffic
    if fam == "gnn":
        E, N, L = meta["edges"], meta["nodes"], meta["n_layers"]
        d = 512 if "graphcast" in str(meta) else 128
        return L * (E + N) * d * 4 * 6
    if fam == "recsys":
        return meta["weight_bytes"] * 0.01 + meta["batch"] * 4096
    return meta.get("weight_bytes", 0)


def _collective_bytes(rec: dict) -> tuple[float, float]:
    """(raw, trip_adjusted-ish) total collective bytes per device from the
    census. Without reliable per-computation trips in the census, the
    adjusted figure multiplies in-loop collectives by n_layers."""
    census = rec.get("collectives", {}).get("per_computation", {})
    L = rec.get("meta", {}).get("n_layers", 1) or 1
    raw = adj = 0.0
    for comp, kinds in census.items():
        b = sum(v["bytes"] for v in kinds.values())
        raw += b
        # heuristics: collectives inside while bodies (comp name pattern)
        if "while" in comp or "body" in comp or "fused" in comp:
            adj += b * L
        else:
            adj += b
    return raw, adj


def analyze_cell(rec: dict) -> dict:
    chips = rec["n_devices"]
    meta = rec["meta"]
    model_flops = float(meta.get("model_flops", 0.0))
    raw = rec.get("cost_analysis", {}) or {}
    hlo_flops_raw = (raw.get("flops") or 0.0) * chips
    hbm_raw = (raw.get("bytes accessed") or 0.0)
    coll_raw, coll_adj = _collective_bytes(rec)
    if rec.get("collective_bytes_est") is not None:
        coll_adj = rec["collective_bytes_est"]
    hlo_est = rec.get("hlo_flops_est")
    flops_best = (hlo_est * chips) if hlo_est else max(model_flops,
                                                       hlo_flops_raw)

    compute_s = flops_best / (chips * HW["peak_flops"])
    memory_s = max(hbm_raw, _analytic_hbm(meta, chips) / chips) / HW["hbm_bw"]
    collective_s = coll_adj / HW["link_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    total = max(sum(terms.values()), 1e-30)
    peak = rec["memory"]["peak_device_bytes"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "model_flops": model_flops,
        "hlo_flops_raw": hlo_flops_raw,
        "flops_used": flops_best,
        "useful_ratio": round(model_flops / max(flops_best, 1.0), 4),
        "collective_by_kind": rec.get("collective_by_kind", {}),
        "hbm_bytes_dev": hbm_raw,
        "coll_bytes_dev_raw": coll_raw,
        "coll_bytes_dev_adj": coll_adj,
        **{k: round(v, 9) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "bound_fraction": round(terms[dominant] / total, 4),
        "peak_gib": round(peak / 2**30, 2),
        "fits_hbm": bool(peak <= HW["hbm_cap"]),
        "roofline_step_s": round(terms[dominant], 9),
    }


def analyze_dir(dryrun_dir: str | Path) -> list[dict]:
    out = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "mesh": rec.get("mesh"), "error": rec.get("error")})
            continue
        out.append(analyze_cell(rec))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    rows = analyze_dir(args.dryrun_dir)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(rows, indent=1))
    hdr = (f"{'arch':22s} {'shape':14s} {'mesh':8s} {'dominant':10s} "
           f"{'frac':>6s} {'compute_s':>11s} {'memory_s':>11s} "
           f"{'collect_s':>11s} {'peak GiB':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:22s} {r['shape']:14s} {r['mesh']:8s} FAILED")
            continue
        print(f"{r['arch']:22s} {r['shape']:14s} {r['mesh']:8s} "
              f"{r['dominant']:10s} {r['bound_fraction']:6.2f} "
              f"{r['compute_s']:11.3e} {r['memory_s']:11.3e} "
              f"{r['collective_s']:11.3e} {r['peak_gib']:9.2f}")


if __name__ == "__main__":
    main()
