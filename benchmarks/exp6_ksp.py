"""Exp-6 (Fig 12): comparison with adapted k-shortest-path algorithms.

The paper adapts DkSP [34] and OnePass [35] by dropping their similarity
constraints and enumerating until the hop constraint. The essence of both
adaptations is *best-first path enumeration without the HC index prune*;
we implement that (`ksp_adapted`: uniform-cost search over partial paths,
host-side, the same class of traversal those codebases perform) and
reproduce the claim: index-pruned enumeration wins by orders of magnitude.
"""
from __future__ import annotations

import heapq
import time

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import record, time_planner


def ksp_adapted(g, s: int, t: int, k: int, limit: int = 10_000_000):
    """Best-first (shortest-first) simple-path enumeration, no index prune."""
    out = []
    heap = [(0, (s,))]
    visited_budget = limit
    while heap and visited_budget > 0:
        length, path = heapq.heappop(heap)
        visited_budget -= 1
        u = path[-1]
        if u == t and length >= 1:
            out.append(path)
            continue
        if length == k:
            continue
        for v in g.neighbors(u):
            v = int(v)
            if v in path:
                continue
            heapq.heappush(heap, (length + 1, path + (v,)))
    return out


def main(scale: float = 1.0) -> list[dict]:
    g = generators.community(int(20000 * scale), n_comm=8, avg_deg=6.0, seed=8)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    qs = generators.random_queries(g, 8, (6, 6), seed=9)
    t_batch, _ = time_planner(eng, qs, "batch")
    t0 = time.perf_counter()
    n_paths = 0
    budget = 2_000_000                      # pop budget; reached => lower bound
    capped = False
    for s, t, k in qs:
        found = ksp_adapted(g, s, t, k, limit=budget)
        n_paths += len(found)
    t_ksp = time.perf_counter() - t0
    record("exp6_batch", t_batch * 1e6, f"n_queries={len(qs)}")
    record("exp6_ksp_adapted", t_ksp * 1e6,
           f"slowdown>={t_ksp / t_batch:.1f}x;paths={n_paths}")
    return [dict(t_batch=t_batch, t_ksp=t_ksp)]


if __name__ == "__main__":
    main()
