"""Public wrapper: all-pairs |Γ_A ∩ Γ_B| from boolean reachability rows."""
from __future__ import annotations

import jax

from .. import resolve_backend
from ..msbfs_expand.ref import pack_bits
from .kernel import pairwise_popcount_pallas
from .ref import pairwise_popcount_ref, intersections_bool_ref

__all__ = ["pairwise_intersections"]


def pairwise_intersections(gamma_bits: jax.Array,
                           backend: str | None = None) -> jax.Array:
    """gamma_bits: (Q, V) bool -> (Q, Q) int32 intersection sizes."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return intersections_bool_ref(gamma_bits)
    words = pack_bits(gamma_bits)
    if backend == "pallas":
        return pairwise_popcount_pallas(words)
    return pairwise_popcount_pallas(words, interpret=True)
