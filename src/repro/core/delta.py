"""Dynamic-graph subsystem: batched edge deltas with incremental CSR merge.

Streaming workloads (fraud detection, real-time social graphs) interleave
queries with continuous edge arrivals. Rebuilding the graph from scratch
(``Graph.from_edges``) for every mutation re-sorts the whole edge list and
forces the serving stack to cold-start; this module makes a mutation
proportional to its *size* instead:

  * ``GraphDelta``    -- a normalized batch of edge insertions/deletions
                         (self-loops dropped, duplicates collapsed, vertex
                         set fixed — matching ``from_edges`` semantics).
  * ``apply_delta``   -- successor graph by sorted-key CSR merge in both
                         directions: kept edges are copied in bulk, the
                         few changed rows absorb the inserts, nothing is
                         re-sorted. Returns the *effective* change set
                         (edges actually inserted/removed after no-op
                         elimination) and the touched vertices — the
                         locality radius everything downstream (ELL row
                         refresh, hop-scoped cache invalidation) keys off.
  * ``update_device_graph`` -- patches a :class:`DeviceGraph` in place of a
                         full rebuild: edge lists re-uploaded sentinel-
                         padded inside their pow2 shape bucket (no traced
                         shape changes while churn stays in-bucket), only
                         touched ELL rows recomputed and scattered; falls
                         back to ``DeviceGraph.build`` when a row outgrows
                         the current ELL capacity.
  * ``host_set_dist``   -- BFS from the touched frontier for hop-scoped
                         cache invalidation. Both endpoints of every
                         changed edge are seeds, so frontier distances
                         agree on the old, new, and union graphs — one
                         sweep over the *old* CSR certifies cached state
                         and its fresh recomputation alike.

Delta semantics: deletions apply first, then insertions —
``new = (old − remove) ∪ add``. Deleting an absent edge and inserting a
present one are no-ops and do not mark vertices as touched.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from .graph import (DeviceGraph, Graph, _ragged_arange, pad_edge_list,
                    pow2_ceil)

__all__ = ["GraphDelta", "AppliedDelta", "apply_delta",
           "update_device_graph", "host_set_dist", "pow2_ceil"]


def _normalize_pairs(src, dst, drop_self_loops: bool) -> tuple[np.ndarray, np.ndarray]:
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError("src/dst arrays must have equal length")
    if src.size and (src.min() < 0 or dst.min() < 0):
        raise ValueError("vertex ids must be >= 0")
    if drop_self_loops and src.size:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    if src.size:  # dedupe pairs without knowing n (delta is graph-agnostic)
        pairs = np.unique(np.stack([src, dst], axis=1), axis=0)
        src, dst = pairs[:, 0], pairs[:, 1]
    return src, dst


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A normalized batch of edge mutations against a fixed vertex set.

    Insertions drop self-loops (never on a simple path, mirroring
    ``Graph.from_edges``) and both lists are deduplicated at construction,
    so a delta is a pair of edge *sets*. Vertex-id bounds are checked
    against the graph at apply time.
    """

    add_src: np.ndarray
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray

    def __post_init__(self):
        a_s, a_d = _normalize_pairs(self.add_src, self.add_dst,
                                    drop_self_loops=True)
        d_s, d_d = _normalize_pairs(self.del_src, self.del_dst,
                                    drop_self_loops=False)
        object.__setattr__(self, "add_src", a_s)
        object.__setattr__(self, "add_dst", a_d)
        object.__setattr__(self, "del_src", d_s)
        object.__setattr__(self, "del_dst", d_d)

    @classmethod
    def from_pairs(cls, add: Sequence = (), remove: Sequence = ()) -> "GraphDelta":
        """Build from iterables of ``(u, v)`` pairs."""
        add = np.asarray(list(add), dtype=np.int64).reshape(-1, 2)
        rem = np.asarray(list(remove), dtype=np.int64).reshape(-1, 2)
        return cls(add[:, 0], add[:, 1], rem[:, 0], rem[:, 1])

    @classmethod
    def empty(cls) -> "GraphDelta":
        z = np.zeros(0, np.int64)
        return cls(z, z, z, z)

    @property
    def n_add(self) -> int:
        return int(self.add_src.size)

    @property
    def n_del(self) -> int:
        return int(self.del_src.size)

    def __bool__(self) -> bool:
        return self.n_add > 0 or self.n_del > 0

    def max_vertex(self) -> int:
        """Largest vertex id referenced (-1 for an empty delta)."""
        parts = [a for a in (self.add_src, self.add_dst,
                             self.del_src, self.del_dst) if a.size]
        return int(max(int(a.max()) for a in parts)) if parts else -1


class AppliedDelta(NamedTuple):
    """Result of merging one delta: the successor graph plus the effective
    change set (after no-op elimination) in both decoded and key form."""

    graph: Graph
    added_src: np.ndarray     # (na,) int64 — edges actually inserted
    added_dst: np.ndarray
    removed_src: np.ndarray   # (nr,) int64 — edges actually removed
    removed_dst: np.ndarray
    touched: np.ndarray       # (nt,) int64 — unique endpoints of all changes

    @property
    def n_changed(self) -> int:
        return int(self.added_src.size + self.removed_src.size)


def _member(a: np.ndarray, b_sorted: np.ndarray) -> np.ndarray:
    """Mask over ``a``: which elements occur in sorted array ``b_sorted``."""
    if b_sorted.size == 0 or a.size == 0:
        return np.zeros(a.size, dtype=bool)
    pos = np.searchsorted(b_sorted, a)
    hit = pos < b_sorted.size
    out = np.zeros(a.size, dtype=bool)
    out[hit] = b_sorted[pos[hit]] == a[hit]
    return out


def _merge_disjoint_sorted(kept: np.ndarray, added: np.ndarray) -> np.ndarray:
    """Merge two sorted, disjoint key arrays in O(len) — no re-sort."""
    if added.size == 0:
        return kept
    if kept.size == 0:
        return added
    out = np.empty(kept.size + added.size, dtype=kept.dtype)
    # final index of each element = own rank + #smaller elements of the other
    out[np.arange(kept.size) + np.searchsorted(added, kept)] = kept
    out[np.arange(added.size) + np.searchsorted(kept, added)] = added
    return out


def _csr_keys(indptr: np.ndarray, indices: np.ndarray, n: int) -> np.ndarray:
    """``row * n + col`` keys of a CSR, ascending (rows sorted, cols sorted
    within each row)."""
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    return rows * n + indices


def _merged_csr(indptr: np.ndarray, indices: np.ndarray, n: int,
                removed_keys: np.ndarray, added_keys: np.ndarray,
                key_old: Optional[np.ndarray] = None,
                ) -> tuple[np.ndarray, np.ndarray]:
    """One direction of the CSR merge; all key arrays ``row * n + col``
    ascending."""
    if key_old is None:
        key_old = _csr_keys(indptr, indices, n)
    kept = key_old[~_member(key_old, removed_keys)]
    new_key = _merge_disjoint_sorted(kept, added_keys)
    # indptr shifts by the cumulative per-row degree change — O(n + d),
    # no O(m) bincount over the whole edge list
    delta_deg = (np.bincount(added_keys // n, minlength=n)
                 - np.bincount(removed_keys // n, minlength=n)).astype(np.int64)
    new_indptr = indptr + np.concatenate([[0], np.cumsum(delta_deg)])
    return new_indptr, (new_key % n).astype(np.int32)


def apply_delta(g: Graph, delta: GraphDelta) -> AppliedDelta:
    """Merge a delta into ``g``: ``new = (old − remove) ∪ add``.

    Equivalent to ``Graph.from_edges`` on the edited edge list (the
    property tests assert this bit-for-bit, both CSR directions), but kept
    edges are copied without re-sorting. Requires a deduplicated graph
    (``from_edges`` default).
    """
    n = g.n
    if delta.max_vertex() >= n:
        raise ValueError(f"delta references vertices outside the graph "
                         f"(n={n}, max id {delta.max_vertex()})")
    key_old = _csr_keys(g.indptr, g.indices, n)
    add_key = delta.add_src * n + delta.add_dst          # unique by construction
    del_key = delta.del_src * n + delta.del_dst
    # effective change set: deleting an absent edge / inserting a present
    # one is a no-op; delete-then-insert of a present edge cancels out
    removed = del_key[_member(del_key, key_old) & ~_member(del_key, add_key)]
    added = add_key[~_member(add_key, key_old)]
    if removed.size == 0 and added.size == 0:
        z = np.zeros(0, np.int64)
        return AppliedDelta(graph=g, added_src=z, added_dst=z,
                            removed_src=z, removed_dst=z, touched=z)

    indptr, indices = _merged_csr(g.indptr, g.indices, n, removed, added,
                                  key_old=key_old)
    # reverse direction: rekey (u, v) -> v * n + u
    removed_r = np.sort((removed % n) * n + removed // n)
    added_r = np.sort((added % n) * n + added // n)
    r_indptr, r_indices = _merged_csr(g.r_indptr, g.r_indices, n,
                                      removed_r, added_r)
    g2 = Graph(n=n, indptr=indptr, indices=indices,
               r_indptr=r_indptr, r_indices=r_indices)
    touched = np.unique(np.concatenate([added // n, added % n,
                                        removed // n, removed % n]))
    return AppliedDelta(graph=g2,
                        added_src=added // n, added_dst=added % n,
                        removed_src=removed // n, removed_dst=removed % n,
                        touched=touched)


# ----------------------------------------------------------------------
# device-view patching
# ----------------------------------------------------------------------

def _ell_rows(g: Graph, rows: np.ndarray, cap: int, reverse: bool,
              ) -> tuple[np.ndarray, np.ndarray]:
    """(len(rows), cap) padded-ELL idx/mask for a subset of vertices."""
    ip, ix = (g.r_indptr, g.r_indices) if reverse else (g.indptr, g.indices)
    deg = (ip[rows + 1] - ip[rows]).astype(np.int64)
    idx = np.full((rows.size, cap), g.n, dtype=np.int32)
    r = np.repeat(np.arange(rows.size), deg)
    c = _ragged_arange(deg)
    idx[r, c] = ix[np.repeat(ip[rows], deg) + c]
    return idx, idx != g.n


def _scatter_rows(g: Graph, ell_idx, ell_mask, rows: np.ndarray, cap: int,
                  reverse: bool):
    """Scatter recomputed ELL rows into the device matrices. Rows are
    padded to a power of two by repeating the first row (duplicate indices
    write identical content), so repeated small deltas reuse one scatter
    shape instead of compiling per row count."""
    import jax.numpy as jnp

    pad = pow2_ceil(rows.size)
    rows = np.concatenate([rows, np.full(pad - rows.size, rows[0],
                                         rows.dtype)])
    idx, mask = _ell_rows(g, rows, cap, reverse=reverse)
    rows = jnp.asarray(rows.astype(np.int32))
    return (ell_idx.at[rows].set(jnp.asarray(idx)),
            ell_mask.at[rows].set(jnp.asarray(mask)))


def update_device_graph(dg: DeviceGraph, applied: AppliedDelta,
                        ) -> tuple[DeviceGraph, bool]:
    """Patch device views for a merged delta; ``(new_dg, incremental)``.

    Every updated view keeps its shape bucket: edge lists are re-uploaded
    sentinel-padded to the *current* ``m_cap`` (growing to the next pow2
    bucket only when the valid count outgrows it — shrinking never
    reclaims, so repeated grow/shrink around a boundary cannot thrash),
    and the padded ELL matrices — the big (n, cap) buffers the kernels
    read — are updated by scattering only the touched rows. In-bucket
    churn therefore changes no traced shape and re-uses every warm
    compile. Falls back to a full ``DeviceGraph.build`` when a touched
    row outgrows the current ELL capacity (the ELL must stay spill-free
    for enumeration); the rebuild re-buckets and is the one mutation that
    may retrace — at most once per bucket crossing.
    """
    import jax.numpy as jnp

    g2 = applied.graph
    fwd_rows = np.unique(np.concatenate([applied.added_src,
                                         applied.removed_src]))
    rev_rows = np.unique(np.concatenate([applied.added_dst,
                                         applied.removed_dst]))
    fwd_deg = g2.indptr[fwd_rows + 1] - g2.indptr[fwd_rows]
    rev_deg = g2.r_indptr[rev_rows + 1] - g2.r_indptr[rev_rows]
    if ((fwd_deg.size and int(fwd_deg.max()) > dg.ell_cap)
            or (rev_deg.size and int(rev_deg.max()) > dg.r_ell_cap)):
        # the rebuild keeps every bucket monotone too: edge cap and ELL
        # caps only grow, so an overflow after deletion-heavy churn cannot
        # shrink a bucket and re-thrash the next insert wave
        return DeviceGraph.build(
            g2, edge_cap=max(dg.m_cap, pow2_ceil(g2.m)),
            min_ell_caps=(dg.ell_cap, dg.r_ell_cap)), False

    ell_idx, ell_mask = dg.ell_idx, dg.ell_mask
    if fwd_rows.size:
        ell_idx, ell_mask = _scatter_rows(g2, ell_idx, ell_mask, fwd_rows,
                                          dg.ell_cap, reverse=False)
    r_ell_idx, r_ell_mask = dg.r_ell_idx, dg.r_ell_mask
    if rev_rows.size:
        r_ell_idx, r_ell_mask = _scatter_rows(g2, r_ell_idx, r_ell_mask,
                                              rev_rows, dg.r_ell_cap,
                                              reverse=True)

    cap = dg.m_cap if g2.m <= dg.m_cap else pow2_ceil(g2.m)
    esrc, edst = pad_edge_list(*g2.edges_by_dst, g2.n, cap)
    r_esrc, r_edst = pad_edge_list(*g2.r_edges_by_dst, g2.n, cap)
    return dataclasses.replace(
        dg, m=g2.m,
        esrc=jnp.asarray(esrc), edst=jnp.asarray(edst),
        ell_idx=ell_idx, ell_mask=ell_mask,
        r_esrc=jnp.asarray(r_esrc), r_edst=jnp.asarray(r_edst),
        r_ell_idx=r_ell_idx, r_ell_mask=r_ell_mask), True


def host_set_dist(g_old: Graph, applied: AppliedDelta, k_max: int,
                  reverse: bool) -> np.ndarray:
    """BFS distances from the touched frontier, host-side over the old CSR.

    ``dist[v] = min over touched x of hops(x -> v)``; ``reverse=True``
    walks G_r (i.e. prices ``hops(v -> x)``). Only the touched balls'
    edges are visited, not ``m``. Returns ``(n+1,) int32`` capped at
    ``k_max`` (unreached = k_max + 1, row n INF), matching
    :func:`~repro.core.msbfs.msbfs_set_dist` — the device backend for
    accelerator-resident graphs — exactly.

    Walking the *old* graph alone suffices for old, new, and union alike:
    both endpoints of every changed edge are seeds, so any path using a
    changed edge has a suffix from a distance-0 vertex over unchanged
    edges only — distances from the touched set agree on all three
    graphs, and one sweep certifies cached state and its fresh
    recomputation.
    """
    ip, ix = (g_old.r_indptr, g_old.r_indices) if reverse \
        else (g_old.indptr, g_old.indices)
    INF = k_max + 1
    dist = np.full(g_old.n + 1, INF, np.int32)
    frontier = applied.touched
    dist[frontier] = 0
    for hop in range(1, k_max + 1):
        if frontier.size == 0:
            break
        deg = (ip[frontier + 1] - ip[frontier]).astype(np.int64)
        nbrs = np.unique(ix[np.repeat(ip[frontier], deg) +
                            _ragged_arange(deg)].astype(np.int64))
        frontier = nbrs[dist[nbrs] == INF]
        dist[frontier] = hop
    return dist


