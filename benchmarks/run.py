"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.5] [--only exp1,exp3]

Prints ``name,us_per_call,derived`` CSV lines (skeleton contract) and
writes results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import (exp1_similarity, exp2_batch_size, exp3_decomposition,
               exp4_gamma, exp5_scalability, exp6_ksp, exp7_path_counts,
               exp8_cross_batch, exp9_query_variants, exp10_dynamic,
               exp11_open_loop, exp12_mixed_routing, kernels_bench,
               obs_bench)
from .common import RESULTS

ALL = {
    "exp1": exp1_similarity.main,
    "exp2": exp2_batch_size.main,
    "exp3": exp3_decomposition.main,
    "exp4": exp4_gamma.main,
    "exp5": exp5_scalability.main,
    "exp5s": exp5_scalability.sharded_main,
    "exp6": exp6_ksp.main,
    "exp7": exp7_path_counts.main,
    "exp8": exp8_cross_batch.main,
    "exp9": exp9_query_variants.main,
    "exp10": exp10_dynamic.main,
    "exp11": exp11_open_loop.main,
    "exp12": exp12_mixed_routing.main,
    "kernels": kernels_bench.main,
    "obs": obs_bench.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="workload scale factor (graph sizes)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. exp1,exp3")
    args = ap.parse_args()
    chosen = (args.only.split(",") if args.only else list(ALL))
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    detail = {}
    failed = []
    for name in chosen:
        try:
            detail[name] = ALL[name](args.scale)
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
            failed.append(name)
    out = Path("results/benchmarks.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"rows": RESULTS, "detail": detail},
                              indent=1, default=str))
    print(f"# total {time.perf_counter() - t0:.1f}s -> {out}")
    if failed:   # CI smoke jobs must see a nonzero exit, not a green FAILED row
        sys.exit(f"failed experiments: {', '.join(failed)}")


if __name__ == "__main__":
    main()
