"""Cross-batch shared HC-s path cache (persistent Ψ-node result store).

Within one batch the engine reuses materialized HC-s path queries via the
sharing graph Ψ; everything is thrown away when the batch ends. Real
serving workloads repeat themselves — consecutive batches from the same
traffic overlap heavily — so this module persists the per-level ``PathSet``
results of every Ψ node *across* batches, keyed by a canonical query
signature. A later batch whose plan contains an identical node skips
materialization entirely and re-uploads the host-pinned copy.

Canonical cache key::

    (direction, source, budget, slack_signature, stop_vertex)

* ``direction``        -- "f" (enumerate on G) or "b" (on G_r).
* ``source, budget``   -- the HC-s path query itself: all simple paths of
                          length <= budget starting at ``source``.
* ``slack_signature``  -- sorted tuple of ``(endpoint, remaining_hops)``
                          pairs over the node's consumers. The engine's
                          slack prune is ``slack[v] = max_c (k_c - off_c -
                          dist(v, endpoint_c))``, which is a pure function
                          of these pairs and the (fixed) graph, so equal
                          signatures imply identical pruned result sets.
* ``stop_vertex``      -- the dedicated-node early-stop target (-2 when
                          disabled); it changes the materialized levels so
                          it must be part of the key.

Keys and entries are deliberately **shape-agnostic**: no component of the
key (and nothing inside an entry) records the device graph's padded edge
bucket ``m_cap``, its ELL capacities, or any other capacity artifact —
entries only pin their *own* PathSet capacity buckets so a re-upload
restores the exact jit shapes of the original materialization. A delta
that grows the edge bucket (retracing the edge kernels once) therefore
still gets exact cache hits for every entry the hop-scoped invalidation
kept; tests/test_cache.py pins this.

Entries are stored host-side (``HostPathSet``) with byte-accurate
accounting; the cache is a bytes-budgeted LRU. It is only valid for one
graph, tracked per entry by an epoch: a wholesale swap must call
:meth:`SharedPathCache.invalidate` (``BatchPathEngine.set_graph`` does
this automatically), while an incremental edge delta goes through
:meth:`SharedPathCache.invalidate_delta` (via
``BatchPathEngine.apply_delta``), which evicts only entries whose hop
radius the changed edges can reach and keeps everything else warm under
the bumped epoch. Not thread-safe; each engine/replica group owns its
cache.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Iterable, Optional

import numpy as np

from .pathset import HostPathSet, PathSet, offload, pathset_nbytes, upload
from .query import midpoint_split
from ..obs import metrics as obsmetrics

__all__ = ["SharedPathCache", "CacheStats", "node_signature",
           "dedicated_keys", "DEFAULT_CACHE_BYTES"]

DEFAULT_CACHE_BYTES = 256 << 20

CacheKey = tuple  # (direction, source, budget, slack_signature, stop_vertex)


def node_signature(direction: str, src: int, budget: int,
                   consumers: Iterable[tuple[int, int]],
                   endpoints: dict[int, tuple[int, int]]) -> tuple:
    """Canonical signature of a Ψ node (without the engine's stop vertex).

    consumers : (query_idx, min_offset) pairs as built by detect.py.
    endpoints : query_idx -> (endpoint_vertex, k) for this direction
                (forward: (q.t, q.k); backward: (q.s, q.k)).
    """
    sig = tuple(sorted({(int(endpoints[qi][0]), int(endpoints[qi][1]) - int(off))
                        for qi, off in consumers}))
    return (direction, int(src), int(budget), sig)


def dedicated_keys(s: int, t: int, k: int) -> tuple[CacheKey, CacheKey]:
    """Full cache keys of the two halves of query (s, t, k) when it runs as
    its own singleton cluster with the default midpoint split. This pins the
    engine's key format (tests assert engine-inserted keys match); admission
    warmth probes use the cheaper :meth:`SharedPathCache.has_root` instead.
    The split comes from :func:`~repro.core.query.midpoint_split` — the
    same helper the engine's cluster splitter uses — so these keys cannot
    drift from what the engine inserts. Only the cost-based "+" planners
    (which pick a per-query split) may deviate."""
    a, b = midpoint_split(k)
    fkey = ("f", int(s), a, ((int(t), int(k)),), int(t))
    bkey = ("b", int(t), b, ((int(s), int(k)),), int(s))
    return fkey, bkey


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    invalidations: int = 0
    oversize_skips: int = 0
    delta_invalidations: int = 0   # invalidate_delta calls
    delta_evictions: int = 0       # entries a delta proved stale
    delta_kept: int = 0            # entries that stayed warm across deltas

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Entry:
    levels: list[HostPathSet]
    nbytes: int
    epoch: int = 0                 # graph epoch this entry is valid for


class SharedPathCache:
    """Bytes-budgeted LRU over host-pinned Ψ-node results."""

    _n_instances = 0   # process-wide ordinal for metric labels

    def __init__(self, budget_bytes: int = DEFAULT_CACHE_BYTES):
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        self.budget_bytes = int(budget_bytes)
        self._entries: "OrderedDict[CacheKey, _Entry]" = OrderedDict()
        self._roots: Counter = Counter()   # (direction, src) -> live entries
        self._nbytes = 0
        self.epoch = 0
        self.stats = CacheStats()
        # CacheStats mirrors into the process metrics registry, labeled
        # per cache instance (replica caches are distinct instances):
        # the scrape view of hit ratio / eviction pressure / residency
        idx = str(SharedPathCache._n_instances)
        SharedPathCache._n_instances += 1
        reg = obsmetrics.registry()
        self._m_hits = reg.counter("cache_hits_total", cache=idx)
        self._m_misses = reg.counter("cache_misses_total", cache=idx)
        self._m_inserts = reg.counter("cache_inserts_total", cache=idx)
        self._m_evictions = reg.counter("cache_evictions_total", cache=idx)
        self._m_bytes = reg.gauge("cache_bytes", cache=idx)

    # -- queries -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def contains(self, key: CacheKey) -> bool:
        """Probe without touching LRU order or hit/miss stats."""
        return key in self._entries

    def has_root(self, direction: str, src: int) -> bool:
        """Is ANY entry enumerated from (direction, src) warm? Cheap probe
        for cache-aware admission: a plan rooting a half-query here has a
        chance to hit regardless of the consumer-set details."""
        return self._roots[(direction, int(src))] > 0

    def get(self, key: CacheKey) -> Optional[list[PathSet]]:
        """Device copies of the cached per-level PathSets, or None on miss.

        Each call re-uploads from the host copy (device memory for cached
        nodes is owned by the batch, not the cache). The per-entry epoch
        guard enforces the invalidation contract: every resident entry
        must carry the current graph epoch (invalidate_delta re-stamps
        survivors), so an entry that somehow missed an invalidation pass
        is served as a miss and dropped rather than as stale data.
        """
        entry = self._entries.get(key)
        if entry is None:
            self.stats.misses += 1
            self._m_misses.inc()
            return None
        if entry.epoch != self.epoch:
            self._entries.pop(key)
            self._nbytes -= entry.nbytes
            self._drop_root(key)
            self.stats.misses += 1
            self.stats.evictions += 1   # anomaly must show up in telemetry
            self._m_misses.inc()
            self._m_evictions.inc()
            self._m_bytes.set(self._nbytes)
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        self._m_hits.inc()
        return [upload(h) for h in entry.levels]

    # -- updates -------------------------------------------------------
    def put(self, key: CacheKey, levels: list[PathSet]) -> None:
        """Insert (or refresh) a materialized node; evicts LRU to fit."""
        # size is known from the device shapes — reject oversize entries
        # before paying the device->host transfer (they recur every batch).
        # Same byte-math as HostPathSet.nbytes (pathset_nbytes), so this
        # pre-transfer check can never diverge from the LRU accounting.
        nbytes = sum(pathset_nbytes(ps.cap, ps.width, ps.verts.dtype.itemsize)
                     for ps in levels)
        if nbytes > self.budget_bytes:
            self.stats.oversize_skips += 1
            return
        host = [offload(ps) for ps in levels]
        nbytes = sum(h.nbytes for h in host)
        old = self._entries.pop(key, None)
        if old is not None:
            self._nbytes -= old.nbytes
            self._drop_root(key)
        while self._nbytes + nbytes > self.budget_bytes and self._entries:
            ekey, evicted = self._entries.popitem(last=False)
            self._nbytes -= evicted.nbytes
            self._drop_root(ekey)
            self.stats.evictions += 1
            self._m_evictions.inc()
        self._entries[key] = _Entry(levels=host, nbytes=nbytes,
                                    epoch=self.epoch)
        self._roots[key[:2]] += 1
        self._nbytes += nbytes
        self.stats.inserts += 1
        self._m_inserts.inc()
        self._m_bytes.set(self._nbytes)

    def _drop_root(self, key: CacheKey) -> None:
        # delete zero counts: root churn must not grow the Counter forever
        root = key[:2]
        self._roots[root] -= 1
        if self._roots[root] <= 0:
            del self._roots[root]

    def invalidate(self) -> None:
        """Graph mutation hook: drop every entry and start a new epoch."""
        self._m_evictions.inc(len(self._entries))
        self._entries.clear()
        self._roots.clear()
        self._nbytes = 0
        self.epoch += 1
        self.stats.invalidations += 1
        self._m_bytes.set(0)

    def max_radius(self) -> int:
        """Largest hop radius any live entry's validity depends on: its
        enumeration budget or a consumer's remaining-hop prune radius —
        the ``k_max`` the invalidation MS-BFS from the touched frontier
        must cover."""
        r = 0
        for key in self._entries:
            _, _, budget, sig = key[0], key[1], key[2], key[3]
            r = max(r, int(budget), max((int(rr) for _, rr in sig), default=0))
        return r

    def invalidate_delta(self, touched, dists: dict) -> dict:
        """Hop-scoped eviction after an incremental graph delta.

        touched : the delta's touched vertices (endpoints of every changed
            edge); only used for reporting/no-op detection — the hop
            geometry arrives pre-computed in ``dists``.
        dists : two ``(n+1,)`` arrays of min hop distances **to/from the
            touched frontier** (both endpoints of every changed edge are
            seeds, so these agree on the old, new, and union graphs — one
            BFS pair certifies cached state and its fresh recomputation
            alike; see ``delta.host_set_dist``):

            * ``dists["to"][v]``   -- min hops v -> any touched vertex
                                      along forward edges,
            * ``dists["from"][v]`` -- min hops any touched vertex -> v.

        An entry ``(direction, source, budget, sig, stop)`` is evicted iff
        the damage intersects either radius that defines its result set:

        * its **enumeration ball** — some touched vertex within ``budget``
          hops of ``source`` in the entry's search direction (a cached
          path could traverse, or a fresh enumeration could newly reach,
          a changed edge); or
        * a **consumer prune radius** — some touched vertex within
          ``r = k_c - off_c`` hops of a consumer endpoint in the *prune*
          direction (the slack prune reads ``dist(v, endpoint)``; a
          changed edge inside that radius can loosen the prune and admit
          paths the cached levels never enumerated).

        Everything else provably equals a fresh materialization on the new
        graph and stays warm, re-stamped with the bumped epoch.
        """
        d_to = np.asarray(dists["to"])
        d_from = np.asarray(dists["from"])
        self.epoch += 1
        self.stats.delta_invalidations += 1
        if len(touched) == 0:
            for entry in self._entries.values():
                entry.epoch = self.epoch
            self.stats.delta_kept += len(self._entries)
            return {"evicted": 0, "kept": len(self._entries),
                    "epoch": self.epoch}
        stale = []
        for key in self._entries:
            direction, src, budget, sig = key[0], key[1], key[2], key[3]
            if direction == "f":
                hit = d_to[src] <= budget or any(d_from[e] <= r
                                                 for e, r in sig)
            else:
                hit = d_from[src] <= budget or any(d_to[e] <= r
                                                   for e, r in sig)
            if hit:
                stale.append(key)
        for key in stale:
            entry = self._entries.pop(key)
            self._nbytes -= entry.nbytes
            self._drop_root(key)
        for entry in self._entries.values():
            entry.epoch = self.epoch
        self.stats.delta_evictions += len(stale)
        self.stats.delta_kept += len(self._entries)
        self._m_evictions.inc(len(stale))
        self._m_bytes.set(self._nbytes)
        return {"evicted": len(stale), "kept": len(self._entries),
                "epoch": self.epoch}

    # -- reporting -----------------------------------------------------
    def info(self) -> dict:
        return {"entries": len(self._entries), "nbytes": self._nbytes,
                "budget_bytes": self.budget_bytes, "epoch": self.epoch,
                **self.stats.as_dict()}
