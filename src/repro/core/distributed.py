"""Distributed execution of the engine's device stages.

The engine's heavy stages are pure pjit programs, so distribution is a
placement decision:

  * index (MS-BFS)   -- edges sharded over all mesh axes ("cells"); the
                        frontier gather/segment-reduce runs under GSPMD
                        (validated == single-device in tests/test_distributed).
                        At billion-edge scale the packed-word axis shards over
                        "model" and vertices over "data" (see §Perf cell A:
                        -68% collective vs vertex-only sharding).
  * similarity       -- Γ rows sharded over queries; popcount/matmul local.
  * enumeration      -- whole clusters are the work unit (sharing graphs do
                        not cross clusters): data-parallel replica groups with
                        the work-stealing scheduler (ft/scheduler.py).

This module provides the helpers that make those placements one-liners.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .graph import DeviceGraph, Graph

__all__ = ["shard_edges", "distributed_graph"]


def shard_edges(esrc: jax.Array, edst: jax.Array, mesh,
                axes=("cells",)) -> tuple[jax.Array, jax.Array]:
    """Place an edge list sharded over the mesh, padding to a device
    multiple by repeating the final edge (a no-op in the boolean BFS
    semiring and in segment-sum counts when masked downstream)."""
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    m = esrc.shape[0]
    pad = (-m) % n_dev
    if pad:
        esrc = jnp.concatenate([esrc, jnp.repeat(esrc[-1:], pad)])
        edst = jnp.concatenate([edst, jnp.repeat(edst[-1:], pad)])
    sh = NamedSharding(mesh, P(axes))
    return jax.device_put(esrc, sh), jax.device_put(edst, sh)


def distributed_graph(g: Graph, mesh, axes=("cells",)) -> DeviceGraph:
    """DeviceGraph with edge lists sharded over the mesh (ELL replicated;
    suitable for graphs whose index-pruned ELL fits per device, per
    DESIGN.md §4 — the billion-edge dry-run path keeps ELL vertex-sharded
    instead, see launch/steps._engine_bundle)."""
    dg = DeviceGraph.build(g)
    esrc, edst = shard_edges(dg.esrc, dg.edst, mesh, axes)
    r_esrc, r_edst = shard_edges(dg.r_esrc, dg.r_edst, mesh, axes)
    # m stays the *valid* edge count: the pow2 sentinel pad (and any
    # device-multiple pad added here) is capacity, not edges
    return DeviceGraph(
        n=dg.n, m=dg.m,
        esrc=esrc, edst=edst,
        ell_idx=dg.ell_idx, ell_mask=dg.ell_mask,
        r_esrc=r_esrc, r_edst=r_edst,
        r_ell_idx=dg.r_ell_idx, r_ell_mask=dg.r_ell_mask,
        ell_cap=dg.ell_cap, r_ell_cap=dg.r_ell_cap,
    )
