"""BatchPathEngine: BasicEnum (Alg 1), BatchEnum (Alg 4) and the "+" variants.

The engine is the device-side executor: the host planner (clustering +
detection) emits per-cluster DirectionPlans; this module materializes HC-s
path queries level by level (expand supersteps + splice joins), caches them
(the paper's R), and assembles per-query HC-s-t results with the exact-split
⊕ join. Every stage is static-shape jit with overflow-retry doubling.

Entry point is :meth:`BatchPathEngine.run`, which takes typed
:class:`~repro.core.query.PathQuery` objects (legacy ``(s, t, k)`` tuples
are coerced) and returns a :class:`~repro.core.query.BatchReport` of
:class:`~repro.core.query.QueryResult` objects. Per-query ``output`` kinds
are threaded all the way down: count-only and exists-only queries never
assemble path rows (counting ⊕ joins, mask reductions) and early-terminate,
as do ``limit``-capped queries. The legacy ``process(queries, mode=...)``
API survives as a thin deprecation shim.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import compilelog, distributed
from ..obs import metrics as obsmetrics
from ..obs import trace as obstrace
from .cache import SharedPathCache
from .delta import (AppliedDelta, GraphDelta, apply_delta as _merge_delta,
                    host_set_dist, pow2_ceil as _pow2, update_device_graph)
from .graph import DeviceGraph, Graph
from .index import QueryIndex, build_index, walk_counts, walk_counts_ell
from .msbfs import (K_MAX_INT8, edge_span, msbfs_set_dist,
                    msbfs_set_dist_ell)
from ..kernels.registry import resolve_backend
from .pathset import PathSet, concat, empty, singleton
from .enumerate import (count_ending_at, expand_level, extract_rows,
                        prune_table, select_ending_at)
from .join import cross_join, keyed_join, keyed_join_count, sort_by_last
from .planner import CostRouter, Route, RouterConfig
from .query import (BatchReport, Output, PathQuery, PathsStore, Planner,
                    QueryLike, QueryResult, midpoint_split)
from .similarity import similarity_matrix
from .clustering import cluster_queries
from .detect import DirectionPlan, PlanNode, detect_common_queries

__all__ = ["EngineConfig", "BatchPathEngine", "EngineOverflow", "BatchResult"]

Query = tuple[int, int, int]

# backward levels are produced lazily: basic planners skip the whole
# backward enumeration when a forward level already answers exists-only
Levels = Callable[[], list]


class EngineOverflow(RuntimeError):
    """A query exceeded hard capacity limits (the paper's OT analogue)."""


@dataclasses.dataclass
class EngineConfig:
    gamma: float = 0.5              # clustering threshold (paper default)
    backend: Optional[str] = None   # DEPRECATED alias of kernel_backend
    # (kept one release for old callers; setting it warns at engine init)
    kernel_backend: Optional[str] = None  # "pallas" | "interpret" | "jnp";
    # None resolves via kernels.registry (REPRO_KERNEL_BACKEND env, else
    # auto: pallas on TPU, jnp elsewhere). Unknown names raise ValueError
    # at engine construction.
    min_cap: int = 256
    max_cap: int = 1 << 20          # planned per-level frontier cap clamp
    hard_cap: int = 1 << 22         # absolute limit before EngineOverflow
    join_cap: int = 1 << 21
    min_shared_budget: int = 2      # don't materialize trivially small shares
    plus: bool = False              # cost-based fwd/bwd split (the "+" variants)
    edge_chunk: int = 1 << 22
    plan_caps: bool = True          # DP-based capacity planning
    paper_faithful_shares: bool = False  # min_shared_budget -> 0
    cache_bytes: int = 0            # >0: cross-batch SharedPathCache budget
    delta_max_sources: int = 1024   # touched-frontier cap for hop-scoped
    # invalidation; bigger deltas fall back to a full cache invalidate
    delta_backend: str = "host"     # "host": vectorized CSR BFS over the
    # touched balls (cost ~ ball edges); "msbfs": device set-seeded MS-BFS
    # (for accelerator-resident graphs where m is device-scale)
    log_compiles: bool = False      # compile telemetry: per-kernel retrace
    # counts in run()/apply_delta() stats (core.compilelog recorder)
    mesh: Optional[object] = None   # jax.sharding.Mesh to shard/place on;
    # None + n_devices -> a 1-D "cells" mesh over the first N local devices
    n_devices: Optional[int] = None  # mesh size knob (1 = identity mesh;
    # None/0 = plain single-device). See core.distributed.
    balance_clusters: bool = False  # sharded runs stop cluster merging at
    # n_replicas clusters so the mesh never idles on an over-merged batch
    # (changes the clustering, hence result row order — off by default so
    # sharded == single-device stays bit-identical)
    trace: bool = False             # record hierarchical stage spans into
    # the process-wide repro.obs tracer (Chrome-trace exportable); off =
    # spans still time the t_* stats but nothing is recorded
    trace_fence: bool = False       # block_until_ready fenced device values
    # at span exit so async device work is attributed to the launching
    # span (costs dispatch overlap; measurement mode only)
    trace_annotations: bool = False  # wrap spans in jax.profiler
    # TraceAnnotation so they appear on profiler device timelines
    router: Optional[RouterConfig] = None  # Planner.AUTO routing thresholds
    # and output-kind weights (None = planner.RouterConfig defaults)


@dataclasses.dataclass
class BatchResult:
    """Legacy aggregate (eager host matrices); produced only by the
    deprecated :meth:`BatchPathEngine.process` shim. New code gets a
    :class:`~repro.core.query.BatchReport` from :meth:`BatchPathEngine.run`.
    """

    paths: dict[int, np.ndarray]    # query idx -> (n_paths, k+1) int32 (pad -1)
    stats: dict


def _sync_device_graph(dg: DeviceGraph) -> None:
    """Block until every device view is resident. apply_delta calls this
    before stopping its timer so the reported ``t_apply_s`` charges the
    async uploads/scatters to the mutation, not to the next batch;
    set_graph deliberately does NOT sync (no report to keep honest —
    benchmarks comparing against it must block explicitly)."""
    import jax

    jax.block_until_ready((dg.esrc, dg.edst, dg.ell_idx, dg.ell_mask,
                           dg.r_esrc, dg.r_edst, dg.r_ell_idx, dg.r_ell_mask))


def _bucket(x: int, min_cap: int = 256) -> int:
    """Quantize capacities to powers of four (fewer jit shape buckets)."""
    b = min_cap
    while b < x:
        b *= 4
    return b


class BatchPathEngine:
    def __init__(self, graph: Graph, config: Optional[EngineConfig] = None,
                 cache: Optional[SharedPathCache] = None):
        self.g = graph
        self.cfg = config or EngineConfig()
        kb = self.cfg.kernel_backend
        if self.cfg.backend is not None:
            warnings.warn(
                "EngineConfig.backend is deprecated; use "
                "EngineConfig.kernel_backend", DeprecationWarning,
                stacklevel=2)
            if kb is None:
                kb = self.cfg.backend
        # resolve once at construction: explicit > REPRO_KERNEL_BACKEND env
        # > auto (pallas on TPU, jnp elsewhere); typos raise here, not as a
        # silently different code path mid-batch
        self.kernel_backend = resolve_backend(kb)
        # plain string for jit static args (clean cache keys, no enum repr)
        self._kb = self.kernel_backend.value
        mesh = distributed.resolve_mesh(self.cfg.mesh, self.cfg.n_devices)
        if mesh is None:
            self.dg = DeviceGraph.build(graph)
        else:
            # device-count-aligned edge bucket: sharded and single-device
            # shapes coincide for pow2 device counts, so both stay warm
            n_dev = int(np.prod(list(mesh.shape.values())))
            self.dg = DeviceGraph.build(
                graph, edge_cap=distributed.edge_bucket_for(graph.m, n_dev))
        self._host_dists: Optional[tuple] = None   # (index, (dist_s, dist_t))
        # plan -> place -> gather layer; identity on a single device (the
        # executor IS the cluster-execution loop for every engine)
        self.executor: Optional[distributed.ShardedExecutor] = \
            distributed.ShardedExecutor(self, mesh)
        if cache is None and self.cfg.cache_bytes > 0:
            cache = SharedPathCache(self.cfg.cache_bytes)
        self.cache = cache
        # Planner.AUTO tier routing + per-cluster planner choice
        self.router = CostRouter(self.cfg.router)
        # process-wide recorder (jit caches are process-global); None when
        # telemetry is off — every run()/apply_delta() report then carries
        # n_compiles / n_retraces / compiled_kernels for its window
        self.compile_log = compilelog.enable() if self.cfg.log_compiles \
            else None
        # stage spans: like the jit cache and compile log, the recorder is
        # process-wide — any engine with cfg.trace turns recording on; the
        # handle itself is always present because every t_* stat below is
        # a derived view over a span's duration (recorded or not)
        self.obs = obstrace.enable(
            fence=self.cfg.trace_fence,
            annotate=self.cfg.trace_annotations) if self.cfg.trace \
            else obstrace.tracer()

    def set_graph(self, graph: Graph) -> None:
        """Swap the graph wholesale: rebuild device views and drop every
        piece of graph-derived state (host dists, cross-batch cache). For
        incremental edge churn prefer :meth:`apply_delta`, which keeps the
        warm state whose hop-locality a small delta cannot reach."""
        self.g = graph
        if self.executor is not None and self.executor.mesh is not None:
            n_dev = self.executor.n_replicas
            self.dg = DeviceGraph.build(
                graph, edge_cap=distributed.edge_bucket_for(graph.m, n_dev))
        else:
            self.dg = DeviceGraph.build(graph)
        self._host_dists = None
        # replica caches invalidate BEFORE the replicas are dropped so a
        # swap bumps every epoch in lockstep with the primary
        for cache in self._all_caches():
            cache.invalidate()
        if self.executor is not None:
            self.executor.reset()

    def apply_delta(self, delta: GraphDelta) -> dict:
        """Apply an incremental edge delta; returns an application report.

        The successor graph comes from a CSR merge (``Graph.apply_delta``
        semantics: ``new = (old − remove) ∪ add``), device views are
        patched rather than rebuilt (only touched ELL rows change), and
        the cross-batch cache is invalidated *hop-scoped*: a set-seeded
        BFS from the delta's touched vertices prices each
        entry's distance to the damage, and only entries whose enumeration
        ball or consumer prune radius the damage can reach are evicted
        (``SharedPathCache.invalidate_delta``). A no-op delta (every edge
        already present/absent) leaves all state — including the host
        distance memo — untouched; an effective delta drops only that
        memo, which the next batch's index rebuilds anyway.

        Device views stay in their pow2 shape buckets (sentinel-padded
        edge lists, bucketed ELL capacities), so an in-bucket delta
        triggers no retrace; with ``EngineConfig.log_compiles`` the report
        carries the window's ``n_compiles`` / ``n_retraces``.
        """
        if self.compile_log is None:
            return self._apply_delta_impl(delta)
        snap = self.compile_log.snapshot()
        report = self._apply_delta_impl(delta)
        self.compile_log.annotate(report, snap)
        return report

    def _apply_delta_impl(self, delta: GraphDelta) -> dict:
        with self.obs.span("engine.apply_delta") as sp:
            applied = _merge_delta(self.g, delta)
            sp.set(n_added=int(applied.added_src.size),
                   n_removed=int(applied.removed_src.size))
            report = {
                "n_added": int(applied.added_src.size),
                "n_removed": int(applied.removed_src.size),
                "n_touched": int(applied.touched.size),
                "cache_mode": "none", "device_update": "none",
            }
            if applied.n_changed == 0:
                report["t_apply_s"] = sp.elapsed
                return report
            if self.cache is not None:
                with self.obs.span("cache.invalidate"):
                    report.update(self._invalidate_for(applied))
            self.dg, incremental = update_device_graph(self.dg, applied)
            report["device_update"] = ("incremental" if incremental
                                       else "rebuild")
            self.g = applied.graph
            self._host_dists = None
            if self.executor is not None:
                # replica device views patch in lockstep; their caches were
                # already invalidated above with the same distance sweep
                self.executor.propagate_delta(applied)
            _sync_device_graph(self.dg)   # timer measures completed work
            report["t_apply_s"] = sp.elapsed
        return report

    def _all_caches(self) -> list[SharedPathCache]:
        """Primary cache + every materialized replica's cache. All of
        them receive each invalidation event (same dists, same order), so
        their epochs advance in lockstep; replicas created later sync the
        epoch at birth (see ``distributed.ShardedExecutor._clone``)."""
        caches = [] if self.cache is None else [self.cache]
        if self.executor is not None:
            caches += self.executor.replica_caches()
        return caches

    def _invalidate_for(self, applied: AppliedDelta) -> dict:
        """Cache invalidation for one merged delta (primary cache must
        exist; replica caches, when materialized, invalidate identically)."""
        caches = self._all_caches()
        if all(len(c) == 0 for c in caches):
            empty = {"to": np.empty(0, np.int8),
                     "from": np.empty(0, np.int8)}
            info = {}
            for c in caches:
                info = c.invalidate_delta(applied.touched, empty)
            return {"cache_mode": "delta", "cache_evicted": 0,
                    "cache_kept": 0, "cache_epoch": info["epoch"],
                    "cache_epochs": [c.epoch for c in caches]}
        if applied.touched.size > self.cfg.delta_max_sources:
            dropped = sum(len(c) for c in caches)   # primary + replicas
            for c in caches:
                c.invalidate()   # frontier too wide: hop-scoping won't pay
            return {"cache_mode": "full", "cache_evicted": dropped,
                    "cache_kept": 0, "cache_epoch": self.cache.epoch,
                    "cache_epochs": [c.epoch for c in caches]}
        # one distance sweep prices the damage for every cache: the
        # radius must cover the widest live entry anywhere in the fleet
        k_max = max(max(c.max_radius() for c in caches), 1)
        dists = self._delta_dists(applied, k_max)
        info = {}
        for c in caches:
            got = c.invalidate_delta(applied.touched, dists)
            if c is self.cache:
                info = got
        return {"cache_mode": "delta", "cache_evicted": info["evicted"],
                "cache_kept": info["kept"], "cache_epoch": info["epoch"],
                "cache_epochs": [c.epoch for c in caches]}

    def _delta_dists(self, applied: AppliedDelta, k_max: int) -> dict:
        """Min hop distances to/from the touched frontier.

        Both endpoints of every changed edge are seeds, so these distances
        agree on the old, new, and union graphs (see ``host_set_dist``) —
        the sweep runs on the *old* graph, which for the "msbfs" backend
        means the still-resident old device edge lists (``self.dg`` is
        patched only after invalidation), no transfer or merge needed.
        Backend "host" (default) walks only the touched balls' edges over
        the CSR; "msbfs" is for accelerator-resident graphs. ``k_max`` is
        the widest live radius across every cache (primary + replicas).
        """
        if self.cfg.delta_backend == "host":
            return {"from": host_set_dist(self.g, applied, k_max,
                                          reverse=False),
                    "to": host_set_dist(self.g, applied, k_max,
                                        reverse=True)}
        # distances beyond every live radius are never compared, so the
        # pow2-bucketed (larger) k_max is just slack — stable jit shapes
        # across deltas. Clamping the *bucket* to the int8 sweeps' static
        # ceiling is sound only while the live radius itself fits; a
        # radius beyond K_MAX_INT8 would silently lose distances, so it
        # raises here (the sweeps' _check_k_max guard backstops this).
        if k_max > K_MAX_INT8:
            raise ValueError(
                f"live cache radius k_max={k_max} exceeds the int8 MS-BFS "
                f"ceiling K_MAX_INT8={K_MAX_INT8}; shrink the hop budgets "
                f"or drop delta_backend='msbfs'")
        k_max = min(_pow2(k_max), K_MAX_INT8)
        seed = np.zeros(self.g.n + 1, np.int8)
        seed[applied.touched] = 1
        seed = jnp.asarray(seed)

        # the still-resident old edge lists are already sentinel-padded to
        # their pow2 bucket (DeviceGraph.build / update_device_graph), so
        # the sweep's traced shape is stable across deltas by construction.
        # _kernel_dg: on a sharded engine this sweep runs GSPMD over the
        # mesh (the index view re-shards only after the patch).
        kdg = self._kernel_dg()
        dists = {}
        if self.kernel_backend.uses_kernel:
            # fused bit-packed sweep: "from" distances relax over G's
            # in-neighbors (r_ell), "to" over G_r's (ell) — bit-equal to
            # the segment path below
            for name, ell in (("from", kdg.r_ell_idx), ("to", kdg.ell_idx)):
                d = msbfs_set_dist_ell(ell, seed, n=self.g.n, k_max=k_max,
                                       backend=self._kb)
                dists[name] = np.asarray(d)
            return dists
        m_valid = edge_span(kdg.m, self.cfg.edge_chunk, kdg.m_cap)
        for name, (esrc, edst) in (("from", (kdg.esrc, kdg.edst)),
                                   ("to", (kdg.r_esrc, kdg.r_edst))):
            d = msbfs_set_dist(esrc, edst, seed, n=self.g.n,
                               k_max=k_max, edge_chunk=self.cfg.edge_chunk,
                               m_valid=m_valid)
            dists[name] = np.asarray(d)
        return dists

    def _dists_host(self, index: QueryIndex):
        # memoized per index OBJECT: keep a strong reference so a freed
        # index's id can never be reused to serve stale distances
        if self._host_dists is None or self._host_dists[0] is not index:
            self._host_dists = (index, (np.asarray(index.dist_s),
                                        np.asarray(index.dist_t)))
        return self._host_dists[1]

    @staticmethod
    def _slack_np(dist_cols: np.ndarray, ks: np.ndarray,
                  offs: np.ndarray, INF: int):
        d = dist_cols.astype(np.int32)
        val = ks[None, :] - offs[None, :] - d
        val = np.where(d >= INF, -1, val)
        out = np.clip(val.max(axis=1), -1, 127).astype(np.int8)
        out[-1] = -1
        return jnp.asarray(out)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def run(self, queries: Sequence[QueryLike],
            planner: Planner | str = Planner.BATCH,
            clusters: Optional[list[list[int]]] = None) -> BatchReport:
        """Execute a batch of :class:`PathQuery` (tuples are coerced).

        planner : execution strategy (:class:`Planner` or its string value).
        clusters : optional precomputed partition of query indices (batch
        planners only). The caller — e.g. the streaming server, which
        clusters with a cache-aware bias — keeps its grouping instead of
        this method re-running similarity + clustering over the same
        queries.

        With ``EngineConfig.log_compiles`` the report stats carry this
        run's compile-telemetry window: ``n_compiles`` (trace-cache
        misses), ``n_retraces`` (misses on kernels that were already warm
        — zero on a shape-stable serving path) and ``compiled_kernels``.
        """
        if self.compile_log is None:
            return self._run_impl(queries, planner, clusters)
        snap = self.compile_log.snapshot()
        report = self._run_impl(queries, planner, clusters)
        self.compile_log.annotate(report.stats, snap)
        return report

    def _run_impl(self, queries: Sequence[QueryLike],
                  planner: Planner | str,
                  clusters: Optional[list[list[int]]]) -> BatchReport:
        qs = tuple(PathQuery.coerce(q).check_bounds(self.g.n)
                   for q in queries)
        planner = Planner.coerce(planner)
        plus = planner.plus or self.cfg.plus
        stats: dict = {"planner": planner.value, "mode": planner.value,
                       "kernel_backend": self._kb,
                       "n_queries": len(qs), "n_rows_assembled": 0}
        if not qs:   # degenerate but legal (e.g. a filter left nothing)
            stats["t_build_index"] = stats["t_enumerate"] = 0.0
            return BatchReport(queries=qs, results=(), stats=stats)
        with self.obs.span("engine.run", planner=planner.value,
                           n_queries=len(qs)) as root:
            if planner is Planner.PATHENUM:
                report = self._run_pathenum(qs, stats)
            else:
                with self.obs.span("index.build",
                                   n_queries=len(qs)) as sidx:
                    index = build_index(self._kernel_dg(),
                                        [q.key for q in qs],
                                        self.cfg.edge_chunk,
                                        backend=self._kb)
                    index.dist_s.block_until_ready()
                stats["t_build_index"] = sidx.duration
                if planner is Planner.AUTO:
                    report = self._run_auto(qs, index, plus, stats,
                                            clusters)
                elif planner.batched:
                    report = self._run_batch(qs, index, plus, stats,
                                             clusters)
                else:
                    report = self._run_basic(qs, index, plus, stats)
        stats["t_wall_s"] = root.duration
        reg = obsmetrics.registry()
        reg.histogram("engine_batch_wall_s", planner=planner.value,
                      backend=self._kb).record(root.duration)
        lat = reg.histogram("query_latency_s", planner=planner.value,
                            backend=self._kb)
        for r in report.results:
            if r.time_s is not None:
                lat.record(r.time_s)
        return report

    def process(self, queries: Sequence[Query], mode: str = "batch",
                clusters: Optional[list[list[int]]] = None) -> BatchResult:
        """Deprecated tuple-in / dict-out API; thin shim over :meth:`run`."""
        warnings.warn(
            "BatchPathEngine.process(queries, mode=...) is deprecated; use "
            "run(queries, planner=...) or the PathSession facade",
            DeprecationWarning, stacklevel=2)
        report = self.run(queries, planner=mode, clusters=clusters)
        return BatchResult(paths=report.paths, stats=report.stats)

    # ------------------------------------------------------------------
    # BasicEnum (Alg 1): shared index, per-query bidirectional enumeration
    # ------------------------------------------------------------------
    def _direct_query(self, q: PathQuery, qi: int, index: QueryIndex,
                      plus: bool, stats: dict) -> QueryResult:
        """One query through the Alg-1 direct plan: bidirectional
        enumeration off the shared index, backward half lazy. Shared by
        the basic planners, AUTO's GREEN tier and basic-routed clusters."""
        a, b = self._split(qi, index, plus)
        fs = self._dedicated_slack(index, qi, forward=True)
        fl = self._run_node(False, q.s, a, fs, [], stop_vertex=q.t)

        def bwd(qi=qi, q=q, b=b):
            bs = self._dedicated_slack(index, qi, forward=False)
            return self._run_node(True, q.t, b, bs, [], stop_vertex=q.s)

        return self._wrap(q, self._payload(q, fl, a, bwd, b, stats))

    def _run_basic(self, queries, index: QueryIndex, plus: bool,
                   stats) -> BatchReport:
        with self.obs.span("enumerate.batch",
                           n_queries=len(queries)) as senum:
            results = []
            for qi, q in enumerate(queries):
                with self.obs.span("assemble.query", qi=qi) as sq:
                    r = self._direct_query(q, qi, index, plus, stats)
                r.time_s = sq.duration
                results.append(r)
        stats["t_enumerate"] = senum.duration
        return BatchReport(queries=tuple(queries), results=tuple(results),
                           stats=stats)

    def _cluster_basic(self, queries, index: QueryIndex, plus: bool,
                       min_sb: int, cluster: list[int]):
        """Direct per-query plan for one routed cluster — the executor's
        ``planners=["basic", ...]`` arm (see ``CostRouter.cluster_planner``).
        Same ``({qi: QueryResult}, cstats)`` contract as
        :meth:`_cluster_work`, but no Ψ detection, no sharing, no cache:
        a cluster with nothing to share skips that machinery's overhead.
        """
        del min_sb   # no shares to budget on the direct plan
        cstats = {"n_psi_nodes": 0, "n_materialized": 0,
                  "n_cache_hits": 0, "n_cache_misses": 0,
                  "n_rows_assembled": 0, "n_shared": 0, "n_dedup": 0,
                  "n_share_edges": 0, "t_detect": 0.0}
        with self.obs.span("enumerate.cluster", size=len(cluster),
                           direct=True) as se:
            results: dict[int, QueryResult] = {}
            for qi in cluster:
                q = queries[qi]
                with self.obs.span("assemble.query", qi=qi) as sq:
                    results[qi] = self._direct_query(q, qi, index, plus,
                                                     cstats)
                results[qi].time_s = sq.duration
        cstats["t_enumerate"] = se.duration
        return results, cstats

    def _run_pathenum(self, queries, stats) -> BatchReport:
        """Per-query index construction + enumeration (the PathEnum baseline)."""
        results = []
        t_idx = t_enum = 0.0
        for q in queries:
            with self.obs.span("index.build", pathenum=True) as sidx:
                index = build_index(self._kernel_dg(), [q.key],
                                    self.cfg.edge_chunk, backend=self._kb)
                index.dist_s.block_until_ready()
            t_idx += sidx.duration
            with self.obs.span("assemble.query") as sq:
                a, b = self._split(0, index, False)
                fs = self._dedicated_slack(index, 0, forward=True)
                fl = self._run_node(False, q.s, a, fs, [], stop_vertex=q.t)

                def bwd(q=q, b=b, index=index):
                    bs = self._dedicated_slack(index, 0, forward=False)
                    return self._run_node(True, q.t, b, bs, [],
                                          stop_vertex=q.s)

                r = self._wrap(q, self._payload(q, fl, a, bwd, b, stats))
            t_enum += sq.duration
            r.time_s = sidx.duration + sq.duration
            results.append(r)
        stats["t_build_index"] = t_idx
        stats["t_enumerate"] = t_enum
        return BatchReport(queries=tuple(queries), results=tuple(results),
                           stats=stats)

    # ------------------------------------------------------------------
    # BatchEnum (Alg 4): cluster -> detect -> shared enumeration
    # ------------------------------------------------------------------
    def _run_batch(self, queries, index: QueryIndex, plus: bool, stats,
                   clusters: Optional[list[list[int]]] = None) -> BatchReport:
        results = self._run_clustered(queries, index, plus, stats, clusters)
        return BatchReport(queries=tuple(queries),
                           results=tuple(results[qi]
                                         for qi in range(len(queries))),
                           stats=stats)

    def _run_clustered(self, queries, index: QueryIndex, plus: bool, stats,
                       clusters: Optional[list[list[int]]] = None, *,
                       subset: Optional[list[int]] = None,
                       ests: Optional[dict] = None,
                       routes: Optional[dict] = None) -> dict:
        """Cluster → (route) → execute; returns ``{qi: QueryResult}``.

        The shared body of the batch planners and the AUTO YELLOW/RED
        tier. ``subset`` restricts clustering to those query indices
        (AUTO runs it on the non-GREEN remainder; similarity rows are
        sliced, cluster members stay *global* indices). With ``ests``
        (qi → :class:`~repro.core.planner.CostEstimate`) the router picks
        each cluster's planner (basic vs. batch) and tier — RED clusters
        keep LPT placement priority implicitly through their summed cost;
        ``routes`` entries are upgraded in place for RED members.
        """
        qis = list(range(len(queries))) if subset is None else list(subset)
        with self.obs.span("cluster.queries",
                           precomputed=clusters is not None) as sc:
            if clusters is None:
                mu = similarity_matrix(index, backend=self._kb)
                if subset is None:
                    stats["mu_mean"] = float(
                        (mu.sum() - len(queries)) /
                        max(len(queries) * (len(queries) - 1), 1))
                else:
                    mu = mu[np.ix_(qis, qis)]
                min_clusters = 1
                if self.cfg.balance_clusters and self.executor is not None:
                    min_clusters = self.executor.n_replicas
                local = cluster_queries(mu, self.cfg.gamma,
                                        min_clusters=min_clusters)
                clusters = [[qis[i] for i in cl] for cl in local]
            else:
                seen = [qi for cl in clusters for qi in cl]
                if sorted(seen) != sorted(qis):
                    raise ValueError(
                        "clusters must partition the query indices")
            sc.set(n_clusters=len(clusters))
        stats["t_cluster"] = sc.duration
        stats["n_clusters"] = len(clusters)

        min_sb = 0 if self.cfg.paper_faithful_shares else self.cfg.min_shared_budget
        for key in ("n_psi_nodes", "n_materialized",
                    "n_cache_hits", "n_cache_misses",
                    "t_detect", "t_enumerate",
                    "n_shared", "n_dedup", "n_share_edges"):
            stats.setdefault(key, 0)

        planners = None
        if ests is not None:
            sharded = self.executor is not None and self.executor.sharded
            planners = [self.router.cluster_planner(cl, ests,
                                                    self.cache is not None)
                        for cl in clusters]
            stats["cluster_planners"] = list(planners)
            croutes = [self.router.cluster_route(cl, ests, sharded)
                       for cl in clusters]
            stats["cluster_routes"] = [r.value for r in croutes]
            if routes is not None:
                for cl, r in zip(clusters, croutes):
                    if r is Route.RED:
                        for qi in cl:
                            routes[qi] = Route.RED
        # plan -> place -> gather: the executor runs every cluster —
        # inline here on one device, fanned across per-device replicas on
        # a mesh (distributed.ShardedExecutor.run_clusters)
        return self.executor.run_clusters(queries, index, plus, min_sb,
                                          clusters, stats, planners=planners)

    # ------------------------------------------------------------------
    # AUTO: cost-routed GREEN/YELLOW/RED tiers (core.planner)
    # ------------------------------------------------------------------
    def _run_auto(self, queries, index: QueryIndex, plus: bool, stats,
                  clusters: Optional[list[list[int]]] = None) -> BatchReport:
        """Route each query by its index-derived cost estimate: GREEN
        queries take the direct sweep (no clustering/detection/cache);
        the remainder runs through :meth:`_run_clustered`, which also
        picks each cluster's planner and RED/YELLOW tier. Exactness is
        planner-independent, so routing can only move wall time."""
        with self.obs.span("route.estimate", n_queries=len(queries)) as sr:
            dists = self._dists_host(index)
            ests = self.router.estimate(index, queries, dists)
            routes = {e.qi: e.route for e in ests}
            green = [e.qi for e in ests if e.route is Route.GREEN]
            rest = [e.qi for e in ests if e.route is not Route.GREEN]
            sr.set(n_green=len(green))
        stats["t_route"] = sr.duration

        # AUTO answers may skip whole stages; pre-zero the batch counters
        # so report consumers see one stable schema across routes
        for key in ("n_psi_nodes", "n_materialized",
                    "n_cache_hits", "n_cache_misses",
                    "t_detect", "t_enumerate", "t_cluster",
                    "n_shared", "n_dedup", "n_share_edges"):
            stats[key] = 0
        stats["n_clusters"] = 0

        results: dict[int, QueryResult] = {}
        if green:
            results.update(self._run_green(queries, index, plus, green,
                                           stats))
        if rest:
            if clusters is not None:
                # the caller's grouping covered every query; keep only the
                # non-GREEN members (GREEN ones were just answered)
                keep = set(rest)
                clusters = [[qi for qi in cl if qi in keep]
                            for cl in clusters]
                clusters = [cl for cl in clusters if cl]
            results.update(self._run_clustered(
                queries, index, plus, stats, clusters,
                subset=rest, ests={e.qi: e for e in ests}, routes=routes))

        reg = obsmetrics.registry()
        for route in Route:
            n = sum(1 for r in routes.values() if r is route)
            stats[f"routed_{route.value}"] = n
            if n:
                reg.counter(f"routed_{route.value}").inc(n)
        return BatchReport(
            queries=tuple(queries),
            results=tuple(results[qi] for qi in range(len(queries))),
            stats=stats,
            routes=tuple(routes[qi].value for qi in range(len(queries))))

    def _run_green(self, queries, index: QueryIndex, plus: bool,
                   green: list[int], stats) -> dict:
        """The GREEN tier: answer routed queries straight off the shared
        index. exists-only and index-unreachable queries are decided by
        the MS-BFS distances alone (``dist_G(s,t) <= k`` iff a ≤k-hop
        simple path exists — shortest walks are simple); the rest run the
        direct per-query plan with no detection/clustering/cache."""
        ds, _ = self._dists_host(index)
        results: dict[int, QueryResult] = {}
        with self.obs.span("route.green", n_queries=len(green)) as sg:
            for qi in green:
                q = queries[qi]
                with self.obs.span("assemble.query", qi=qi,
                                   route="green") as sq:
                    if int(ds[q.t, index.src_col[qi]]) > q.k:
                        r = self._empty_result(q)
                    elif q.output is Output.EXISTS:
                        r = QueryResult(q, _exists=True)
                    else:
                        r = self._direct_query(q, qi, index, plus, stats)
                r.time_s = sq.duration
                results[qi] = r
        stats["t_green"] = sg.duration
        return results

    @staticmethod
    def _empty_result(q: PathQuery) -> QueryResult:
        """The (exact) empty answer, shaped like the enumerators': an
        empty ``(0, k+1)`` path matrix / zero count / False."""
        if q.output is Output.PATHS:
            return QueryResult(q, _store=PathsStore(empty(1, q.k + 1)))
        if q.output is Output.EXISTS:
            return QueryResult(q, _exists=False)
        return QueryResult(q, _count=0, _exists=False)

    def _cluster_work(self, queries, index: QueryIndex, plus: bool,
                      min_sb: int, cluster: list[int]):
        """One sharing cluster end-to-end: detect → plan execution →
        per-query ⊕ assembly. Returns ``({qi: QueryResult}, cstats)``.

        This is the executor's unit of placement: it touches only
        replica-local state (``self.dg``, ``self.cache``) plus read-only
        shared inputs (host graph, index host-dist memo), so distinct
        clusters run concurrently on distinct replicas.
        """
        cstats = {"n_psi_nodes": 0, "n_materialized": 0,
                  "n_cache_hits": 0, "n_cache_misses": 0,
                  "n_rows_assembled": 0}
        with self.obs.span("detect.cluster", size=len(cluster)) as sd:
            halves_f = {}
            halves_b = {}
            ends_f = {}
            ends_b = {}
            for qi in cluster:
                s, t, k = queries[qi]
                a, b = self._split(qi, index, plus)
                halves_f[qi] = (s, a)
                halves_b[qi] = (t, b)
                ends_f[qi] = (t, k)
                ends_b[qi] = (s, k)
            hop_f = self._hop_ok(index, cluster, forward=True)
            hop_b = self._hop_ok(index, cluster, forward=False)
            plan_f = detect_common_queries(self.g, cluster, halves_f, hop_f,
                                           reverse=False,
                                           min_shared_budget=min_sb,
                                           endpoints=ends_f)
            plan_b = detect_common_queries(self.g, cluster, halves_b, hop_b,
                                           reverse=True,
                                           min_shared_budget=min_sb,
                                           endpoints=ends_b)
            cstats["n_shared"] = plan_f.n_shared + plan_b.n_shared
            # deduped half-queries: halves mapped onto an existing node,
            # counted per direction (identical queries collapse entirely)
            cstats["n_dedup"] = (
                len(cluster) - len(set(plan_f.half_of_query.values()))
                + len(cluster) - len(set(plan_b.half_of_query.values())))
            cstats["n_share_edges"] = (
                sum(len(n.in_edges) for n in plan_f.nodes)
                + sum(len(n.in_edges) for n in plan_b.nodes))
        cstats["t_detect"] = sd.duration

        with self.obs.span("enumerate.cluster", size=len(cluster)) as se:
            cache_f = self._run_plan(plan_f, index, forward=True,
                                     stats=cstats)
            cache_b = self._run_plan(plan_b, index, forward=False,
                                     stats=cstats)
            # identical (halves, k, output, limit) -> identical payloads
            assembled: dict = {}
            results: dict[int, QueryResult] = {}
            for qi in cluster:
                q = queries[qi]
                with self.obs.span("assemble.query", qi=qi) as sq:
                    a = halves_f[qi][1]
                    b = halves_b[qi][1]
                    fid = plan_f.half_of_query[qi]
                    bid = plan_b.half_of_query[qi]
                    key = (fid, bid, a, b, q.k, q.t, q.output, q.limit)
                    if key not in assembled:
                        fl = cache_f[fid]
                        assembled[key] = self._payload(
                            q, fl, a, lambda bid=bid: cache_b[bid], b,
                            cstats)
                    results[qi] = self._wrap(q, assembled[key])
                results[qi].time_s = sq.duration
        cstats["t_enumerate"] = se.duration
        return results, cstats

    # ------------------------------------------------------------------
    # plan execution: materialize needed Ψ nodes in topological order,
    # consulting the cross-batch SharedPathCache first
    # ------------------------------------------------------------------
    @staticmethod
    def _plan_children(plan: DirectionPlan, node: PlanNode) -> list[int]:
        """Splice children after dedupe (same root vertex: keep max budget)."""
        seen_src: dict[int, int] = {}
        for cid in node.in_edges:
            c = plan.nodes[cid]
            if c.src in seen_src and plan.nodes[seen_src[c.src]].budget >= c.budget:
                continue
            seen_src[c.src] = cid
        return list(seen_src.values())

    def _node_stop(self, plan: DirectionPlan, node: PlanNode,
                   index: QueryIndex, forward: bool) -> int:
        # dedicated-node optimization: a half used by exactly one query
        # and spliced by nobody may stop at its own endpoint (Alg 1)
        if (node.query is not None and len(node.consumers) == 1
                and not node.out_edges):
            qi = node.consumers[0][0]
            s_, t_, _ = index.queries[qi]
            return t_ if forward else s_
        return -2

    def _run_plan(self, plan: DirectionPlan, index: QueryIndex, forward: bool,
                  stats: Optional[dict] = None):
        cache: dict[int, list[PathSet]] = {}
        children_of = {n.nid: self._plan_children(plan, n) for n in plan.nodes}
        stops = {n.nid: self._node_stop(plan, n, index, forward)
                 for n in plan.nodes}
        keys: dict[int, tuple] = {}
        if self.cache is not None:
            keys = {n.nid: n.signature + (stops[n.nid],)
                    for n in plan.nodes if n.signature is not None}
        # a node must be present iff it is a query half or spliced by a
        # materialized (cache-miss) node; children of hits are never touched.
        # Cache fetches all happen here — before any put — so entries taken
        # as device copies stay valid for this plan even if evicted later.
        need: set[int] = set()
        mat: list[int] = []
        stack = sorted(set(plan.half_of_query.values()))
        while stack:
            nid = stack.pop()
            if nid in need:
                continue
            need.add(nid)
            if nid in keys:
                with self.obs.span("cache.get") as sg:
                    got = self.cache.get(keys[nid])
                    sg.set(hit=got is not None)
            else:
                got = None
            if got is not None:
                cache[nid] = got
            else:
                mat.append(nid)
                stack.extend(children_of[nid])
        for nid in plan.topo:
            if nid not in need or nid in cache:
                continue
            node = plan.nodes[nid]
            slack = self._node_slack(index, node.consumers, forward)
            children = [(plan.nodes[cid].src, plan.nodes[cid].budget, cache[cid])
                        for cid in children_of[nid]]
            cache[nid] = self._run_node(not forward, node.src, node.budget,
                                        slack, children, stop_vertex=stops[nid])
            if self.cache is not None and nid in keys:
                with self.obs.span("cache.put"):
                    self.cache.put(keys[nid], cache[nid])
        if stats is not None:
            stats["n_psi_nodes"] += len(plan.nodes)
            stats["n_materialized"] += len(mat)
            if self.cache is not None:
                stats["n_cache_hits"] += len(need) - len(mat)
                stats["n_cache_misses"] += len(mat)
        return cache

    # ------------------------------------------------------------------
    # node enumeration with overflow retry
    # ------------------------------------------------------------------
    def _run_node(self, reverse: bool, source: int, budget: int, slack,
                  children, stop_vertex: int = -2):
        with self.obs.span("enumerate.node", src=source, budget=budget,
                           reverse=reverse):
            caps = self._plan_caps(reverse, source, budget, slack)
            for _ in range(8):
                out = self._run_node_once(reverse, source, budget, slack,
                                          children, stop_vertex, caps)
                if out is not None:
                    return out
                caps = [min(c * 4, self.cfg.hard_cap) for c in caps]
                if all(c >= self.cfg.hard_cap for c in caps[1:]):
                    raise EngineOverflow(
                        f"node (src={source}, budget={budget}) exceeds "
                        f"hard_cap")
            raise EngineOverflow("retry limit reached")

    def _run_node_once(self, reverse, source, budget, slack, children,
                       stop_vertex, caps):
        ell_idx, _ = self.dg.direction(reverse)
        width = budget + 1
        n = self.dg.n
        splice_np = np.full(n + 1, -1, np.int8)
        for (csrc, cb, _) in children:
            splice_np[csrc] = cb
        # slack + splice stacked once per node; every expand level then
        # pays a single fused prune gather (see enumerate.prune_table)
        prune_tbl = prune_table(slack, jnp.asarray(splice_np))
        stop = jnp.int32(stop_vertex)

        pools: list[list[PathSet]] = [[] for _ in range(budget + 1)]
        frontier = singleton(source, width)
        pools[0].append(frontier)
        obs = self.obs
        for lvl in range(budget):
            if int(frontier.count) == 0:
                break
            # per-level MS-BFS superstep: the overflow read is the level's
            # host sync point, so the span charges the level's device work
            # to itself even without fencing
            with obs.span("msbfs.level", level=lvl,
                          reverse=reverse) as sl:
                out = expand_level(frontier.verts, frontier.count, ell_idx,
                                   prune_tbl, stop,
                                   level=lvl, budget=budget,
                                   out_cap=caps[lvl + 1],
                                   backend=self._kb)
                sl.fence(out.frontier.verts)
                overflow = bool(out.frontier.overflow)
            if overflow:
                return None
            for (csrc, cb, clevels) in children:
                with obs.span("join.splice", level=lvl):
                    rmask = (out.splice_hit & (out.nbrs == csrc)).any(axis=1)
                    prefixes = extract_rows(frontier.verts, rmask,
                                            out_cap=frontier.cap)
                    if int(prefixes.count) == 0:
                        continue
                    for lam in range(0, min(cb, budget - lvl - 1) + 1):
                        cl = clevels[lam]
                        if int(cl.count) == 0:
                            continue
                        res = self._retry_join(
                            lambda cap: cross_join(
                                prefixes.verts, prefixes.count,
                                cl.verts, cl.count,
                                p_col=lvl, c_col=lam, out_cap=cap,
                                out_width=width,
                                backend=self._kb),
                            est=int(prefixes.count) * int(cl.count))
                        pools[lvl + 1 + lam].append(res)
            frontier = out.frontier
            pools[lvl + 1].append(out.frontier)
        merged = [concat(p) if p else empty(1, width) for p in pools]
        return [self._shrink(ps) for ps in merged]

    def _shrink(self, ps: PathSet) -> PathSet:
        """Slice a packed PathSet down to a tight capacity bucket — keeps
        the downstream join/sort jit cache to a handful of shapes."""
        tight = _bucket(int(ps.count), self.cfg.min_cap)
        if tight >= ps.cap:
            return ps
        return PathSet(ps.verts[:tight], ps.count, ps.overflow)

    def _retry_capacity(self, fn, est: int):
        """Run ``fn(cap) -> (result, overflow)`` with cap-doubling retry."""
        cap = _bucket(min(max(est, self.cfg.min_cap), self.cfg.join_cap),
                      self.cfg.min_cap)
        while True:
            res, overflow = fn(cap)
            if not bool(overflow):
                return res
            if cap >= self.cfg.hard_cap:
                raise EngineOverflow("join exceeds hard_cap")
            cap = min(cap * 4, self.cfg.hard_cap)

    def _retry_join(self, fn, est: int) -> PathSet:
        def attempt(cap):
            ps = fn(cap)
            return ps, ps.overflow
        return self._retry_capacity(attempt, est)

    # ------------------------------------------------------------------
    # final ⊕ assembly (exact split, each result exactly once), dispatched
    # per query output kind: paths are materialized (lazily host-visible),
    # counts/existence use counting joins and never assemble a path row
    # ------------------------------------------------------------------
    def _payload(self, q: PathQuery, fwd_levels, a: int, bwd: Levels,
                 b: int, stats: dict):
        """The (shareable) answer payload for one query: a PathsStore for
        output=paths (duplicate queries alias it, so the host transfer
        happens once), an int for count/exists. ``bwd`` is a thunk —
        count/exists/limit queries answered by the forward levels alone
        never enumerate the backward half (basic planners)."""
        if q.output is Output.PATHS:
            ps = self._assemble(fwd_levels, a, bwd, b, q.t, q.k,
                                limit=q.limit)
            stats["n_rows_assembled"] += int(ps.count)
            return PathsStore(ps)
        limit = 1 if q.output is Output.EXISTS else q.limit
        return self._assemble_count(fwd_levels, a, bwd, b, q.t, q.k,
                                    limit=limit)

    @staticmethod
    def _wrap(q: PathQuery, payload) -> QueryResult:
        if q.output is Output.PATHS:
            return QueryResult(q, _store=payload)
        if q.output is Output.EXISTS:
            return QueryResult(q, _exists=payload > 0)
        return QueryResult(q, _count=payload, _exists=payload > 0)

    def _assemble(self, fwd_levels, a: int, bwd: Levels, b: int, t: int,
                  k: int, limit: Optional[int] = None):
        """``bwd`` is a thunk, only forced when the bidirectional stage is
        reached — a limit already met by forward completions skips the
        backward enumeration entirely (basic planners)."""
        width = k + 1
        outs = []
        found = 0
        for lvl in range(1, min(a, len(fwd_levels) - 1) + 1):
            if limit is not None and found >= limit:
                break
            ps = fwd_levels[lvl]
            if int(ps.count) == 0:
                continue
            sel = select_ending_at(ps.verts, ps.count, jnp.int32(t),
                                   col=lvl, out_cap=ps.cap)
            if int(sel.count):
                outs.append(_pad_width(sel, width))
                found += int(sel.count)
        if (not (limit is not None and found >= limit) and b >= 1
                and len(fwd_levels) > a and int(fwd_levels[a].count) > 0):
            bwd_levels = bwd()
            fa = fwd_levels[a]
            sa = sort_by_last(fa.verts, fa.count, col=a)
            for lam in range(1, min(b, len(bwd_levels) - 1) + 1):
                if limit is not None and found >= limit:
                    break
                bs = bwd_levels[lam]
                if int(bs.count) == 0:
                    continue
                with self.obs.span("join.keyed", lam=lam):
                    res = self._retry_join(
                        lambda cap: keyed_join(sa, bs.verts, bs.count,
                                               a_col=a, b_col=lam,
                                               out_cap=cap, out_width=width,
                                               backend=self._kb),
                        est=max(int(fa.count), int(bs.count)))
                if int(res.count):
                    outs.append(res)
                    found += int(res.count)
        if not outs:
            return empty(1, width)
        out = concat(outs)
        if limit is not None:
            out = PathSet(out.verts, jnp.minimum(out.count, jnp.int32(limit)),
                          out.overflow)
        return out

    def _assemble_count(self, fwd_levels, a: int, bwd: Levels, b: int,
                        t: int, k: int, limit: Optional[int] = None) -> int:
        """Exact ⊕ count without assembling paths: forward completions are
        mask reductions, the bidirectional part a counting join. ``limit``
        early-terminates (1 for exists-only) and clamps the total."""
        total = 0
        for lvl in range(1, min(a, len(fwd_levels) - 1) + 1):
            ps = fwd_levels[lvl]
            if int(ps.count) == 0:
                continue
            total += int(count_ending_at(ps.verts, ps.count, jnp.int32(t),
                                         col=lvl))
            if limit is not None and total >= limit:
                return limit
        if b >= 1 and len(fwd_levels) > a and int(fwd_levels[a].count) > 0:
            bwd_levels = bwd()
            fa = fwd_levels[a]
            sa = sort_by_last(fa.verts, fa.count, col=a)
            for lam in range(1, min(b, len(bwd_levels) - 1) + 1):
                bs = bwd_levels[lam]
                if int(bs.count) == 0:
                    continue
                with self.obs.span("join.keyed", lam=lam, count=True):
                    total += self._retry_count(
                        lambda cap: keyed_join_count(sa, bs.verts, bs.count,
                                                     a_col=a, b_col=lam,
                                                     pair_cap=cap,
                                                     backend=self._kb),
                        est=max(int(fa.count), int(bs.count)))
                if limit is not None and total >= limit:
                    return limit
        return total if limit is None else min(total, limit)

    def _retry_count(self, fn, est: int) -> int:
        return int(self._retry_capacity(fn, est))

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _split(self, qi: int, index: QueryIndex, plus: bool) -> tuple[int, int]:
        s, t, k = index.queries[qi]
        a, b = midpoint_split(k)   # shared with cache.dedicated_keys
        if not plus or k <= 2:
            return a, b
        # "+" variants: pick the split minimizing estimated search cost
        fs = self._dedicated_slack(index, qi, forward=True)
        bs = self._dedicated_slack(index, qi, forward=False)
        kdg = self._kernel_dg()
        cf = self._walk_counts(kdg, False, s, fs, k - 1)
        cb = self._walk_counts(kdg, True, t, bs, k - 1)
        best, best_cost = a, None
        for cand in range(1, k):
            cost = cf[:cand + 1].sum() + cb[:k - cand + 1].sum()
            if best_cost is None or cost < best_cost:
                best, best_cost = cand, cost
        return best, k - best

    def _dedicated_slack(self, index: QueryIndex, qi: int, forward: bool):
        s, t, k = index.queries[qi]
        ds, dt = self._dists_host(index)
        col = (dt[:, index.tgt_col[qi]] if forward
               else ds[:, index.src_col[qi]])[:, None]
        return self._slack_np(col, np.array([k], np.int32),
                              np.array([0], np.int32), index.INF)

    def _node_slack(self, index: QueryIndex, consumers, forward: bool):
        qs = [qi for qi, _ in consumers]
        offs = np.array([off for _, off in consumers], np.int32)
        ks = np.array([index.queries[qi][2] for qi in qs], np.int32)
        ds, dt = self._dists_host(index)
        cols = dt[:, index.tgt_col[qs]] if forward else ds[:, index.src_col[qs]]
        return self._slack_np(cols, ks, offs, index.INF)

    def _hop_ok(self, index: QueryIndex, cluster, forward: bool) -> np.ndarray:
        k_max = max(index.queries[qi][2] for qi in cluster)
        # host-dist memo instead of per-cluster device transfers: replica
        # threads share the (read-only) memo, so no gather contention
        ds, dt = self._dists_host(index)
        if forward:
            cols = dt[:-1, index.tgt_col[list(cluster)]]
        else:
            cols = ds[:-1, index.src_col[list(cluster)]]
        return (cols.min(axis=1) <= k_max)

    def _kernel_dg(self) -> DeviceGraph:
        """Edge lists the index/walk kernels sweep: the GSPMD-sharded
        mesh view on a primary engine with an executor, the local device
        view on replicas (``executor is None``) and plain engines. While
        a cluster fan-out is in flight the primary (= replica 0) also
        answers with its local view — a mesh-wide collective launched
        from one replica thread would contend with every other replica's
        per-device work."""
        if self.executor is not None and not self.executor.in_fanout:
            return self.executor.index_dg
        return self.dg

    def _m_valid(self, dg: Optional[DeviceGraph] = None) -> int:
        """Chunk-rounded valid-edge span of the (sentinel-padded) device
        edge lists — the static ``m_valid`` every edge kernel receives."""
        dg = self.dg if dg is None else dg
        return edge_span(dg.m, self.cfg.edge_chunk, dg.m_cap)

    def _walk_counts(self, kdg: DeviceGraph, reverse: bool, source, slack,
                     budget: int) -> np.ndarray:
        """Per-level walk-count DP through the configured kernel backend:
        one ELL gather-reduce dispatch per level (``walk_counts_ell``) on
        the kernel route, the chunked edge-list segment_sum on jnp.
        Totals are integer-valued f32, identical below 2**24."""
        if self.kernel_backend.uses_kernel:
            # in-neighbor table of the swept direction: forward counts on G
            # relax over r_ell (in-nbrs of G), reverse counts over ell
            ell = kdg.ell_idx if reverse else kdg.r_ell_idx
            return np.asarray(walk_counts_ell(ell, source, slack, n=kdg.n,
                                              budget=budget,
                                              backend=self._kb))
        esrc = kdg.r_esrc if reverse else kdg.esrc
        edst = kdg.r_edst if reverse else kdg.edst
        return np.asarray(walk_counts(esrc, edst, source, slack, n=kdg.n,
                                      budget=budget,
                                      edge_chunk=self.cfg.edge_chunk,
                                      m_valid=self._m_valid(kdg)))

    def _plan_caps(self, reverse: bool, source: int, budget: int, slack):
        if not self.cfg.plan_caps:
            return [self.cfg.min_cap] * (budget + 1)
        kdg = self._kernel_dg()
        tot = self._walk_counts(kdg, reverse, source, slack, budget)
        caps = [_bucket(min(int(min(t, 2**31)), self.cfg.max_cap),
                        self.cfg.min_cap) for t in tot]
        return caps


def _pad_width(ps: PathSet, width: int) -> PathSet:
    pad = width - ps.verts.shape[1]
    if pad <= 0:
        return ps
    verts = jnp.pad(ps.verts, ((0, 0), (0, pad)), constant_values=-1)
    return PathSet(verts, ps.count, ps.overflow)
