#!/usr/bin/env python
"""Executable-documentation checker: run the fenced python in the docs.

Every fenced code block in ``README.md`` and ``docs/*.md`` whose info
string starts with ``python`` is checked, so API drift (a renamed method,
a removed kwarg, a stale import) fails CI instead of rotting in prose:

* ```` ```python ```` — **executed** in a fresh namespace pre-seeded with
  the prelude below, inside a temporary working directory (snippets may
  write files like ``trace.json`` freely).
* ```` ```python norun ```` — **compiled only** (syntax check). For
  fragments that illustrate syntax rather than a runnable call sequence
  (GitHub highlights by the first word, so rendering is unchanged).

The prelude stands in for "your graph / your queries" that docs assume
as given: a small community graph ``graph``/``g``, a second graph
``new_graph``, validated ``queries``, endpoint names ``s t k`` /
``s2 t2 k2`` and edge names ``u v x y``, a constructed ``engine``, plus
the public ``repro.core`` names (``PathQuery``, ``PathSession``,
``EngineConfig``, ``BatchPathEngine``, ``GraphDelta``, ``Planner``,
``generators``). Snippets should still show their own imports — the
prelude exists so a fragment that *uses* an engine needn't rebuild one.

Run locally::

    JAX_PLATFORMS=cpu python docs/check_snippets.py          # all files
    python docs/check_snippets.py README.md docs/api.md      # a subset

CI runs this in the ``lint`` job (see ``.github/workflows/ci.yml``);
``docs/benchmarks.md`` documents the convention.
"""
from __future__ import annotations

import contextlib
import io
import os
import re
import sys
import tempfile
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")


def extract_blocks(path: Path):
    """Yield (lineno, info_words, source) for each fenced code block."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1):
            info = [m.group(1)] + m.group(2).split()
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            yield start + 1, info, "\n".join(lines[start:j])
            i = j + 1
        else:
            i += 1


def build_prelude() -> dict:
    from repro.core import (BatchPathEngine, EngineConfig, GraphDelta,
                            PathQuery, PathSession, Planner, generators)

    g = generators.community(150, n_comm=2, avg_deg=4.0, seed=0)
    g2 = generators.community(150, n_comm=3, avg_deg=4.0, seed=1)
    queries = [PathQuery.coerce(q)
               for q in generators.random_queries(g, 4, (3, 3), seed=2)]
    (s, t, k), (s2, t2, k2) = queries[0], queries[1]
    engine = BatchPathEngine(g, EngineConfig(min_cap=32))
    return dict(
        BatchPathEngine=BatchPathEngine, EngineConfig=EngineConfig,
        GraphDelta=GraphDelta, PathQuery=PathQuery, PathSession=PathSession,
        Planner=Planner, generators=generators,
        graph=g, g=g, new_graph=g2, queries=queries, engine=engine,
        s=s, t=t, k=k, s2=s2, t2=t2, k2=k2,
        u=1, v=2, x=3, y=4,
    )


def check_file(path: Path, prelude: dict, tmpdir: str) -> list[str]:
    failures = []
    for lineno, info, src in extract_blocks(path):
        if info[0] != "python":
            continue
        where = f"{path.relative_to(ROOT)}:{lineno}"
        try:
            code = compile(src, where, "exec")
        except SyntaxError:
            failures.append(f"{where}: syntax error\n{traceback.format_exc()}")
            continue
        if "norun" in info[1:]:
            print(f"  {where}: syntax ok (norun)")
            continue
        ns = dict(prelude)
        cwd = os.getcwd()
        try:
            os.chdir(tmpdir)
            with contextlib.redirect_stdout(io.StringIO()):
                exec(code, ns)
            print(f"  {where}: ran ok")
        except Exception:
            failures.append(f"{where}: execution failed\n"
                            f"{traceback.format_exc()}\n--- snippet ---\n"
                            f"{src}\n---------------")
        finally:
            os.chdir(cwd)
    return failures


def main(argv: list[str]) -> int:
    if argv:
        files = [ROOT / a for a in argv]
    else:
        files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    files = [f for f in files if f.exists()]
    prelude = build_prelude()
    failures: list[str] = []
    with tempfile.TemporaryDirectory(prefix="docsnippets.") as tmpdir:
        for f in files:
            print(f"{f.relative_to(ROOT)}:")
            failures += check_file(f, prelude, tmpdir)
    if failures:
        print(f"\n{len(failures)} doc snippet(s) FAILED:\n", file=sys.stderr)
        for msg in failures:
            print(msg, file=sys.stderr)
        return 1
    print("\nall doc snippets ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
