"""Frontier path-enumeration supersteps (TPU form of Alg 1/4 ``Search``).

The recursive DFS of the paper becomes level-synchronous: the level-l
frontier is a PathSet of all simple paths of length exactly l that survive
the slack prune. One superstep expands every frontier path by every
ELL neighbor at once, masks invalid candidates (padding / duplicate vertex /
Lemma-3.1 slack prune / splice triggers), and cumsum-compacts the survivors.

Splice handling (BatchEnum, Alg 4 lines 20-23): vertices that root a
materialized dominating HC-s path query are *not* expanded when the cached
budget covers the remaining budget; the (prefix x cached-suffix) cross join
happens in join.py.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .pathset import PathSet, compact_rows

__all__ = ["ExpandOut", "expand_level", "prune_table", "extract_rows",
           "select_ending_at", "count_ending_at"]


class ExpandOut(NamedTuple):
    frontier: PathSet     # level+1 frontier (spliced candidates excluded)
    nbrs: jax.Array       # (cap, D) raw neighbor matrix (for splice extraction)
    splice_hit: jax.Array  # (cap, D) bool -- candidates redirected to splice


def prune_table(slack: jax.Array, splice_budget: jax.Array) -> jax.Array:
    """Stack the two per-vertex int8 prune vectors into the (n+1, 2)
    table :func:`expand_level` consumes — column 0 = Lemma-3.1 slack,
    column 1 = splice budget (-1 = no dominating query). Built once per
    node run (both vectors are fixed for a node), so every level pays a
    single fused gather instead of one gather per vector."""
    return jnp.stack([slack, splice_budget], axis=1)


@partial(jax.jit, static_argnames=("level", "budget", "out_cap", "backend"))
def expand_level(verts: jax.Array, count: jax.Array,
                 ell_idx: jax.Array, prune_tbl: jax.Array,
                 stop_vertex: jax.Array,
                 *, level: int, budget: int, out_cap: int,
                 backend: str = "jnp") -> ExpandOut:
    """One superstep: expand all level-`level` paths by one hop.

    verts:  (cap, L) int32 frontier paths (cols 0..level used).
    ell_idx: (n, D) or (n+1, D) int32 padded ELL table; pad entries hold
            the sentinel value ``n``. The validity mask is derived as
            ``nbrs != n`` — the EllView/delta-patch invariant
            ``mask == (idx != n)`` holds by construction, so no separate
            mask gather is dispatched.
    prune_tbl: (n+1, 2) int8 from :func:`prune_table` — one gather feeds
            both the slack prune (col 0: keep candidate v at depth d iff
            slack[v] >= d) and the splice trigger (col 1: kappa' of a
            materialized dominating query rooted at v, else -1;
            candidates with splice >= budget-(level+1) splice instead of
            expanding).
    stop_vertex: () int32 -- do not expand *from* this vertex (dedicated
            query optimization; pass -2 to disable).
    backend: static resolved kernel backend; ``pallas``/``interpret`` route
            the duplicate-vertex mask through one kernels/path_join
            membership dispatch instead of the broadcast-compare chain.

    Dispatch accounting (audited: see benchmarks/baselines/
    DISPATCH_BUDGETS.json and ``python -m repro.analysis --audit``):
    fusing the mask gather into the ``nbrs != n`` compare and the
    slack + splice gathers into the single prune-table gather cut the
    traced superstep from 85 to 80 eqns (jnp) / 83 to 78 (interpret) at
    the audit probe shape. The remainder stays unfused deliberately:
    the duplicate mask is one broadcast-compare XLA fuses on its own
    (and is already a single kernel dispatch on the kernel backends),
    and the cumsum compaction is the shared ``compact_rows`` primitive —
    fusing it here would fork the compaction path every PathSet consumer
    relies on for a ~2-eqn saving.
    """
    cap, L = verts.shape
    # the prune table always has n+1 rows (slack/splice carry a sentinel
    # entry), whereas ELL tables come in both (n, D) and (n+1, D) forms —
    # so the pad-sentinel value is derived from prune_tbl, not ell_idx
    n = prune_tbl.shape[0] - 1
    D = ell_idx.shape[1]
    row_valid = jnp.arange(cap) < count
    # rows past `count` gather row 0 (any in-bounds row works: row_valid
    # masks every candidate they produce)
    last = jnp.where(row_valid, verts[:, level], 0)
    nbrs = ell_idx[last]                             # (cap, D)
    valid = (nbrs != n) & row_valid[:, None]
    valid &= (last != stop_vertex)[:, None]
    # duplicate-vertex mask: candidate already on the path
    if backend != "jnp":
        from ..kernels.path_join.ops import path_member
        dup = path_member(verts[:, :level + 1], nbrs, backend=backend)
    else:
        dup = (nbrs[:, :, None] == verts[:, None, :level + 1]).any(-1)
    pruned = prune_tbl[nbrs]                         # (cap, D, 2) one gather
    # Lemma 3.1 prune at depth level+1
    keep = valid & ~dup & (pruned[..., 0] >= level + 1)
    # splice triggers (cached dominating query covers the remaining budget)
    remaining = budget - (level + 1)
    splice_hit = keep & (pruned[..., 1] >= remaining)
    expand_mask = keep & ~splice_hit

    # build candidate rows: prefix + new vertex at column level+1
    flat_mask = expand_mask.reshape(-1)
    rows = jnp.repeat(jnp.arange(cap), D)
    cand = verts[rows]                               # (cap*D, L)
    cand = cand.at[:, level + 1].set(nbrs.reshape(-1))
    out, n_out, ovf = compact_rows(flat_mask, cand, out_cap)
    return ExpandOut(frontier=PathSet(out, n_out, ovf),
                     nbrs=nbrs, splice_hit=splice_hit)


@partial(jax.jit, static_argnames=("out_cap",))
def extract_rows(verts: jax.Array, row_mask: jax.Array, *, out_cap: int) -> PathSet:
    """Compact the rows of `verts` where row_mask is True."""
    out, n_out, ovf = compact_rows(row_mask, verts, out_cap)
    return PathSet(out, n_out, ovf)


@partial(jax.jit, static_argnames=("col",))
def count_ending_at(verts: jax.Array, count: jax.Array, vertex,
                    *, col: int) -> jax.Array:
    """Number of rows ending (column `col`) at `vertex` — a mask reduction,
    no compaction and no output buffer (count-/exists-only fast path)."""
    cap = verts.shape[0]
    mask = (jnp.arange(cap) < count) & (verts[:, col] == vertex)
    return mask.sum(dtype=jnp.int32)


@partial(jax.jit, static_argnames=("col", "out_cap"))
def select_ending_at(verts: jax.Array, count: jax.Array, vertex,
                     *, col: int, out_cap: int) -> PathSet:
    """Rows whose path ends (column `col`) at `vertex` (forward-complete paths)."""
    cap = verts.shape[0]
    mask = (jnp.arange(cap) < count) & (verts[:, col] == vertex)
    out, n_out, ovf = compact_rows(mask, verts, out_cap)
    return PathSet(out, n_out, ovf)
