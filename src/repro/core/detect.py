"""DetectCommonQuery (Algorithm 3): build the query sharing graph Ψ and emit
a static execution plan for the device enumerator.

Host-side "query compiler". Level-synchronous over remaining hop budget
(kappa = k_max .. 0), vectorized with numpy over each level's arrival set:

  * arrivals      -- (node_id, vertex) pairs: node's enumeration frontier
                     reaches vertex with remaining budget kappa.
  * >= 2 distinct nodes arriving at v  ->  new shared HC-s path node
    q_{v,kappa}; Psi edges (shared -> member) mean member *splices* the
    shared node's materialized results (Lemma 4.1).
  * M_Q[v]        -- latest node rooted at v; when a frontier touches such
                     a vertex the planner adds a splice edge instead of an
                     arrival (Alg 3 lines 20-24).

Deviations from the paper's pseudocode (documented in DESIGN.md §2):
  * the `M_Q[v] ⊀ M_Q[v']` guard exists to keep Psi acyclic; lacking
    all-pairs distances we enforce acyclicity directly (reachability check
    on insert; cycle-closing edges are skipped).
  * vertices are processed level-at-once rather than one-by-one (more
    same-level edges may be found; still acyclic by the check).
  * shared nodes with budget < min_shared_budget are not created (splicing
    a 1-hop cache costs more than recomputing it); set to 0 for the
    paper-faithful behaviour.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional, Sequence

import numpy as np

from .cache import node_signature
from .graph import Graph

__all__ = ["PlanNode", "DirectionPlan", "detect_common_queries"]


@dataclasses.dataclass
class PlanNode:
    nid: int
    src: int
    budget: int
    query: Optional[int]            # query idx if this is a query half
    in_edges: list[int] = dataclasses.field(default_factory=list)   # children to splice
    out_edges: list[int] = dataclasses.field(default_factory=list)  # parents splicing us
    consumers: list[tuple[int, int]] = dataclasses.field(default_factory=list)
    # consumers: (query_idx, min_offset) pairs for slack construction
    signature: Optional[tuple] = None
    # canonical HC-s query signature (direction, src, budget, slack-sig);
    # set when endpoints are provided — the cross-batch cache key prefix


@dataclasses.dataclass
class DirectionPlan:
    nodes: list[PlanNode]           # indexed by nid
    topo: list[int]                 # execution order (children before parents)
    half_of_query: dict[int, int]   # query idx -> nid of its half
    n_shared: int


def detect_common_queries(g: Graph, cluster: Sequence[int],
                          halves: dict[int, tuple[int, int]],
                          hop_ok: np.ndarray,
                          *, reverse: bool,
                          min_shared_budget: int = 2,
                          max_frontier: int = 1 << 22,
                          endpoints: Optional[dict[int, tuple[int, int]]] = None,
                          ) -> DirectionPlan:
    """Build the sharing plan for one cluster and one direction.

    halves : query idx -> (source_vertex, budget) for this direction
             (forward: (q.s, a_q) on G; backward: (q.t, b_q) on G_r).
    hop_ok : (n,) bool loose reachability filter ("meets the hop
             constraint", Alg 3 line 20) — vertices that can still reach
             some cluster endpoint.
    endpoints : optional query idx -> (endpoint_vertex, k) for this
             direction (forward: (q.t, q.k); backward: (q.s, q.k)). When
             given, every PlanNode gets a canonical ``signature`` usable
             as a cross-batch cache key prefix.
    """
    indptr = g.r_indptr if reverse else g.indptr
    indices = g.r_indices if reverse else g.indices

    nodes: list[PlanNode] = []
    root_node: dict[tuple[int, int], int] = {}   # (src, budget) -> nid (dedupe)
    half_of_query: dict[int, int] = {}
    by_budget: dict[int, list[int]] = defaultdict(list)
    for qi in cluster:
        src, budget = halves[qi]
        key = (src, budget)
        if key not in root_node:
            nid = len(nodes)
            nodes.append(PlanNode(nid=nid, src=src, budget=budget, query=qi))
            root_node[key] = nid
            by_budget[budget].append(nid)
        else:
            nid = root_node[key]
            if nodes[nid].query is None:
                nodes[nid].query = qi
        half_of_query[qi] = root_node[key]
    # queries sharing a (src, budget) half: extra owners tracked via consumers later
    owners = defaultdict(list)
    for qi in cluster:
        owners[half_of_query[qi]].append(qi)

    k_max = max(b for _, b in halves.values()) if halves else 0
    M_Q = np.full(g.n, -1, dtype=np.int64)       # vertex -> nid
    reach: dict[int, set[int]] = {}              # nid -> set of nids reachable via out_edges

    def add_edge(child: int, parent: int) -> None:
        """child's results spliced by parent; skip if it would close a cycle."""
        if child == parent or parent in _reachable(child):
            return
        if child in nodes[parent].in_edges:
            return
        nodes[parent].in_edges.append(child)
        nodes[child].out_edges.append(parent)

    def _reachable(nid: int) -> set[int]:
        # nodes reachable from nid following in_edges (its splice subtree)
        seen, stack = set(), [nid]
        while stack:
            x = stack.pop()
            for c in nodes[x].in_edges:
                if c not in seen:
                    seen.add(c)
                    stack.append(c)
        return seen

    # arrivals for the current level: per node, vertex array
    arrivals: dict[int, np.ndarray] = {}
    n_shared = 0
    for kappa in range(k_max, -1, -1):
        # inject roots whose budget matches this level
        for nid in by_budget.get(kappa, ()):  # roots start at their own level
            prev = arrivals.get(nid)
            v = np.array([nodes[nid].src], dtype=np.int64)
            arrivals[nid] = np.concatenate([prev, v]) if prev is not None else v

        if not arrivals:
            continue
        nid_arr = np.concatenate([np.full(v.size, nid, np.int64)
                                  for nid, v in arrivals.items()])
        vert_arr = np.concatenate(list(arrivals.values()))
        # dedupe (node, vertex)
        key = nid_arr * g.n + vert_arr
        _, idx = np.unique(key, return_index=True)
        nid_arr, vert_arr = nid_arr[idx], vert_arr[idx]

        # group by vertex; vertices with >= 2 nodes become shared queries
        order = np.argsort(vert_arr, kind="stable")
        vert_arr, nid_arr = vert_arr[order], nid_arr[order]
        uniq_v, starts, counts = np.unique(vert_arr, return_index=True,
                                           return_counts=True)
        cur_of_vertex = np.full(uniq_v.size, -1, np.int64)
        for ui in range(uniq_v.size):
            v = int(uniq_v[ui])
            members = nid_arr[starts[ui]:starts[ui] + counts[ui]]
            if counts[ui] >= 2 and kappa >= min_shared_budget:
                nid = len(nodes)
                nodes.append(PlanNode(nid=nid, src=v, budget=kappa, query=None))
                n_shared += 1
                for m in members:
                    add_edge(nid, int(m))     # members splice the shared node
                cur = nid
            else:
                cur = int(members[0])
            M_Q[v] = cur
            cur_of_vertex[ui] = cur

        if kappa == 0:
            break

        # push to out-neighbors (vectorized CSR expansion over the level)
        deg = (indptr[uniq_v + 1] - indptr[uniq_v]).astype(np.int64)
        flat_owner = np.repeat(cur_of_vertex, deg)
        offs = np.repeat(indptr[uniq_v], deg) + _ragged(deg)
        flat_nbr = indices[offs].astype(np.int64)
        ok = hop_ok[flat_nbr]
        flat_owner, flat_nbr = flat_owner[ok], flat_nbr[ok]
        if flat_nbr.size > max_frontier:  # planner safety valve
            keep = np.random.default_rng(0).choice(flat_nbr.size, max_frontier,
                                                   replace=False)
            flat_owner, flat_nbr = flat_owner[keep], flat_nbr[keep]

        has_mq = M_Q[flat_nbr] >= 0
        # splice edges: owner splices M_Q[v'] (dedup pairs first)
        e_child = M_Q[flat_nbr[has_mq]]
        e_parent = flat_owner[has_mq]
        if e_child.size:
            pair = np.unique(e_child * (len(nodes) + 1) + e_parent)
            for p in pair:
                add_edge(int(p // (len(nodes) + 1)), int(p % (len(nodes) + 1)))
        # arrivals for next level
        a_owner = flat_owner[~has_mq]
        a_vert = flat_nbr[~has_mq]
        arrivals = {}
        if a_owner.size:
            pair = np.unique(a_owner * g.n + a_vert)
            a_owner, a_vert = pair // g.n, pair % g.n
            cut = np.searchsorted(a_owner, np.arange(len(nodes) + 1))
            for nid in np.unique(a_owner):
                arrivals[int(nid)] = a_vert[cut[nid]:cut[nid + 1]]

    # consumers: propagate (query, min_offset) down from parents to children
    topo = _toposort(nodes)
    for nid in reversed(topo):                   # parents before children
        node = nodes[nid]
        if node.query is not None:
            for qi in owners[nid]:
                _, budget = halves[qi]
                node.consumers.append((qi, budget - node.budget))
        for parent in node.out_edges:
            for qi, off in nodes[parent].consumers:
                node.consumers.append((qi, off + nodes[parent].budget - node.budget))
        # dedupe, keep the smallest offset per query (loosest slack)
        best: dict[int, int] = {}
        for qi, off in node.consumers:
            if qi not in best or off < best[qi]:
                best[qi] = off
        node.consumers = sorted(best.items())

    if endpoints is not None:
        direction = "b" if reverse else "f"
        for node in nodes:
            node.signature = node_signature(direction, node.src, node.budget,
                                            node.consumers, endpoints)

    return DirectionPlan(nodes=nodes, topo=topo,
                         half_of_query=half_of_query, n_shared=n_shared)


def _toposort(nodes: list[PlanNode]) -> list[int]:
    """Children (in_edges targets) before parents."""
    indeg = {n.nid: len(n.in_edges) for n in nodes}
    from collections import deque
    q = deque([nid for nid, d in indeg.items() if d == 0])
    out = []
    while q:
        nid = q.popleft()
        out.append(nid)
        for parent in nodes[nid].out_edges:
            indeg[parent] -= 1
            if indeg[parent] == 0:
                q.append(parent)
    if len(out) != len(nodes):
        raise RuntimeError("sharing graph has a cycle (planner bug)")
    return out


def _ragged(counts: np.ndarray) -> np.ndarray:
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    offs = np.repeat(np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    return np.arange(total, dtype=np.int64) - offs
