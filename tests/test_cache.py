"""Cross-batch SharedPathCache: unit behavior (hit/miss/LRU eviction,
invalidation), engine integration (warm batches skip Ψ materialization,
results stay oracle-exact across repeated/overlapping batches and graph
mutation), and the streaming admission loop."""
import numpy as np
import pytest

from repro.core import BatchPathEngine, EngineConfig, SharedPathCache
from repro.core import generators
from repro.core.cache import dedicated_keys, node_signature
from repro.core.clustering import cluster_queries
from repro.core.graph import Graph
from repro.core.oracle import enumerate_paths_bruteforce, path_set
from repro.core.pathset import HostPathSet, PathSet, offload, upload
from repro.launch.serve import (AdmissionPolicy, StreamingServer,
                                warm_cluster_bias)

import jax.numpy as jnp


def _levels(width=4, rows=8, fill=7):
    verts = jnp.full((rows, width), -1, jnp.int32).at[:, 0].set(fill)
    return [PathSet(verts, jnp.int32(rows), jnp.bool_(False))]


def _assert_oracle(g, qs, res):
    for qi, (s, t, k) in enumerate(qs):
        got = [tuple(int(x) for x in row if x >= 0) for row in res[qi].paths]
        assert len(got) == len(set(got)), f"q{qi}: duplicate paths"
        assert set(got) == path_set(enumerate_paths_bruteforce(g, s, t, k)), qi


class TestUnit:
    def test_put_get_roundtrip_and_lru_stats(self):
        c = SharedPathCache(budget_bytes=1 << 20)
        key = ("f", 1, 2, ((3, 4),), 3)
        assert c.get(key) is None and c.stats.misses == 1
        c.put(key, _levels())
        assert c.contains(key) and len(c) == 1 and c.nbytes > 0
        got = c.get(key)
        assert c.stats.hits == 1
        assert int(got[0].count) == 8
        np.testing.assert_array_equal(np.asarray(got[0].verts)[:, 0], 7)

    def test_eviction_is_lru_and_bytes_bounded(self):
        one = sum(h.nbytes for h in map(offload, _levels()))
        c = SharedPathCache(budget_bytes=3 * one)
        keys = [("f", i, 2, ((9, 4),), -2) for i in range(3)]
        for k in keys:
            c.put(k, _levels())
        assert len(c) == 3
        c.get(keys[0])                      # refresh: keys[1] is now LRU
        c.put(("f", 99, 2, ((9, 4),), -2), _levels())
        assert not c.contains(keys[1]) and c.contains(keys[0])
        assert c.stats.evictions == 1 and c.nbytes <= c.budget_bytes

    def test_oversize_entry_skipped(self):
        c = SharedPathCache(budget_bytes=8)
        c.put(("f", 0, 1, ((1, 1),), -2), _levels())
        assert len(c) == 0 and c.stats.oversize_skips == 1

    def test_invalidate_clears_and_bumps_epoch(self):
        c = SharedPathCache()
        c.put(("b", 5, 3, ((0, 3),), 0), _levels())
        assert c.has_root("b", 5)
        c.invalidate()
        assert len(c) == 0 and c.epoch == 1 and not c.has_root("b", 5)

    def test_node_signature_canonical(self):
        ends = {0: (9, 5), 1: (9, 5)}
        a = node_signature("f", 3, 2, [(0, 1), (1, 1)], ends)
        b = node_signature("f", 3, 2, [(1, 1), (0, 1)], ends)
        assert a == b == ("f", 3, 2, ((9, 4),))

    def test_dedicated_keys_match_engine_generated_keys(self):
        """The warm-probe helper must produce exactly the keys the engine
        inserts for a singleton-cluster query."""
        g = generators.erdos(50, 3.0, seed=1)
        (q,) = generators.random_queries(g, 1, (3, 3), seed=2)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64, cache_bytes=1 << 20))
        eng.run([q])
        fkey, bkey = dedicated_keys(*q)
        assert eng.cache.contains(fkey) and eng.cache.contains(bkey)


class TestEngineIntegration:
    def test_warm_repeat_batch_skips_materialization(self):
        g = generators.community(90, n_comm=3, avg_deg=4.0, seed=5)
        qs = generators.similar_queries(g, 8, similarity=0.9,
                                        k_range=(3, 4), seed=6)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        r1 = eng.run(qs)
        r2 = eng.run(qs)
        assert r1.stats["n_materialized"] > 0
        assert r2.stats["n_materialized"] == 0
        assert r2.stats["n_cache_hits"] == r1.stats["n_materialized"]
        _assert_oracle(g, qs, r1)
        _assert_oracle(g, qs, r2)

    def test_overlapping_batches_oracle_exact(self):
        g = generators.community(100, n_comm=3, avg_deg=4.0, seed=7)
        qs1 = generators.similar_queries(g, 6, similarity=0.8,
                                         k_range=(3, 4), seed=8)
        qs2 = qs1[:3] + generators.similar_queries(g, 3, similarity=0.8,
                                                   k_range=(3, 4), seed=9)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        _assert_oracle(g, qs1, eng.run(qs1))
        r2 = eng.run(qs2)
        _assert_oracle(g, qs2, r2)
        # and a cacheless engine agrees exactly
        cold = BatchPathEngine(g, EngineConfig(min_cap=64))
        rc = cold.run(qs2)
        for qi in range(len(qs2)):
            assert path_set(r2[qi].paths) == path_set(rc[qi].paths)

    def test_cacheless_engine_unchanged(self):
        g = generators.erdos(60, 3.0, seed=3)
        qs = generators.random_queries(g, 4, (3, 4), seed=4)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        assert eng.cache is None
        res = eng.run(qs)
        assert res.stats["n_cache_hits"] == 0
        assert res.stats["n_materialized"] > 0
        _assert_oracle(g, qs, res)

    def test_graph_mutation_invalidates(self):
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=10)
        qs = generators.similar_queries(g, 5, similarity=0.8,
                                        k_range=(3, 3), seed=11)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        eng.run(qs)
        assert len(eng.cache) > 0
        # drop a third of the edges: cached paths may no longer exist
        rng = np.random.default_rng(0)
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        keep = rng.random(src.size) > 0.33
        g2 = Graph.from_edges(g.n, src[keep], g.indices[keep])
        eng.set_graph(g2)
        assert len(eng.cache) == 0 and eng.cache.epoch == 1
        res = eng.run(qs)
        assert res.stats["n_cache_hits"] == 0  # nothing stale survived
        _assert_oracle(g2, qs, res)

    def test_tiny_budget_evicts_but_stays_correct(self):
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=12)
        qs = generators.similar_queries(g, 6, similarity=0.8,
                                        k_range=(3, 4), seed=13)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64, cache_bytes=4096))
        _assert_oracle(g, qs, eng.run(qs))
        r2 = eng.run(qs)
        _assert_oracle(g, qs, r2)
        info = eng.cache.info()
        assert info["evictions"] + info["oversize_skips"] > 0
        assert info["nbytes"] <= 4096


class TestHostRoundTrip:
    def test_offload_upload_preserves_everything(self):
        ps = _levels(width=5, rows=3, fill=2)[0]
        h = offload(ps)
        assert isinstance(h, HostPathSet)
        assert h.count == 3 and not h.overflow and h.cap == 3
        assert h.nbytes >= h.verts.nbytes
        back = upload(h)
        np.testing.assert_array_equal(np.asarray(back.verts),
                                      np.asarray(ps.verts))
        assert int(back.count) == 3 and not bool(back.overflow)


class TestStreaming:
    def test_streaming_rounds_and_batch_log(self):
        g = generators.community(100, n_comm=3, avg_deg=4.0, seed=1)
        qs = generators.similar_queries(g, 8, similarity=0.7,
                                        k_range=(3, 4), seed=2)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        srv = StreamingServer(eng, n_groups=2,
                              policy=AdmissionPolicy(max_batch=8,
                                                     max_delay_s=0.0))
        ids1 = [srv.submit(q) for q in qs]
        assert srv.pump()               # batch full -> admitted
        ids2 = [srv.submit(q) for q in qs]
        srv.drain()
        assert len(srv.batch_log) == 2
        cold, warm = srv.batch_log
        assert cold["n_materialized"] > 0
        assert warm["n_materialized"] == 0
        assert warm["n_cache_hits"] > 0
        for qid, (s, t, k) in zip(ids1 + ids2, list(qs) * 2):
            assert path_set(srv.results[qid].paths) == \
                path_set(enumerate_paths_bruteforce(g, s, t, k))

    def test_take_drains_results(self):
        g = generators.erdos(60, 3.0, seed=5)
        qs = generators.random_queries(g, 3, (3, 3), seed=6)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        srv = StreamingServer(eng, n_groups=1)
        qids = [srv.submit(q) for q in qs]
        srv.drain()
        got = srv.take(qids[0])
        assert got.paths.shape[1] == qs[0][2] + 1
        assert qids[0] not in srv.results
        with pytest.raises(KeyError):
            srv.take(qids[0])
        with pytest.raises(KeyError):
            srv.take(12345)            # never submitted

    def test_precomputed_clusters_respected(self):
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=6)
        qs = generators.similar_queries(g, 4, similarity=0.9,
                                        k_range=(3, 3), seed=7)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        res = eng.run(qs, clusters=[[0, 1], [2, 3]])
        assert res.stats["n_clusters"] == 2
        assert "mu_mean" not in res.stats     # similarity pass skipped
        _assert_oracle(g, qs, res)
        with pytest.raises(ValueError):
            eng.run(qs, clusters=[[0, 1]])  # not a partition

    def test_admission_policy_deadline(self):
        pol = AdmissionPolicy(max_batch=32, max_delay_s=0.5, min_batch=1)
        assert not pol.due(3, 0.1)
        assert pol.due(3, 0.6)          # deadline hit
        assert pol.due(32, 0.0)         # size hit
        # min_batch holds back *young* sub-minimum batches only — the
        # deadline overrides it, so a lone old query never starves
        assert not AdmissionPolicy(min_batch=2).due(1, 0.0)
        assert AdmissionPolicy(min_batch=2).due(1, 99.0)

    def test_warm_bias_biases_clustering(self):
        g = generators.community(100, n_comm=3, avg_deg=4.0, seed=3)
        qs = generators.similar_queries(g, 6, similarity=0.8,
                                        k_range=(3, 3), seed=4)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        assert warm_cluster_bias(eng, qs) is None  # cold cache -> no bias
        eng.run(qs)
        bias = warm_cluster_bias(eng, qs)
        assert bias is not None and bias.max() > 0
        assert np.allclose(bias, bias.T) and np.all(np.diag(bias) == 0)
        # the bias can merge clusters a plain threshold would keep apart
        mu = np.eye(2)
        assert cluster_queries(mu, gamma=0.05) == [[0], [1]]
        merged = cluster_queries(mu, gamma=0.05,
                                 bias=np.array([[0, .1], [.1, 0]]))
        assert merged == [[0, 1]]


class TestShapeAgnosticEntries:
    """Stale-shape hazard (pow2 edge buckets): cache keys/entries must not
    capture the device graph's padded shapes, so an entry produced under
    one edge bucket is still an exact hit after the bucket grows."""

    def test_entry_exact_hit_across_edge_bucket_growth(self):
        from repro.core import GraphDelta
        from repro.core.oracle import bfs_dist_from

        # ring-of-chords graph: m = 1200 sits under the 2048 bucket, and
        # everything beyond the query balls is a huge hop-cold pool
        n = 600
        src = np.repeat(np.arange(n, dtype=np.int64), 2)
        dst = (src + np.tile(np.array([1, 2], np.int64), n)) % n
        g = Graph.from_edges(n, src, dst)
        qs = [(0, 3, 3), (10, 13, 3), (20, 23, 3), (5, 8, 3)]
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=32 << 20,
                                              delta_max_sources=4096))
        eng.run(qs)
        n_entries = len(eng.cache)
        assert n_entries > 0

        # grow the edge bucket with inserts far outside every query ball
        # (and every prune radius), so hop-scoped invalidation keeps all
        # entries while m crosses its pow2 boundary
        hot = np.zeros(g.n, bool)
        for s, t, k in qs:
            hot |= bfs_dist_from(g, s, 2 * k) <= 2 * k
            hot |= bfs_dist_from(g, t, 2 * k, reverse=True) <= 2 * k
        cold = np.flatnonzero(~hot)
        need = eng.dg.m_cap - g.m + 1
        assert cold.size * (cold.size - 1) // 2 >= 2 * need
        have = set()
        esrc = np.repeat(np.arange(g.n), np.diff(g.indptr))
        have.update(zip(esrc.tolist(), g.indices.tolist()))
        rng = np.random.default_rng(32)
        adds = []
        while len(adds) < need:
            u, v = (int(x) for x in rng.choice(cold, 2, replace=False))
            if u != v and (u, v) not in have:
                adds.append((u, v))
                have.add((u, v))
        m_cap_before = eng.dg.m_cap
        rep = eng.apply_delta(GraphDelta.from_pairs(add=adds))
        assert eng.dg.m_cap > m_cap_before           # bucket grew
        assert rep["cache_mode"] == "delta"
        assert rep["cache_kept"] == n_entries and rep["cache_evicted"] == 0

        # every entry must be an exact hit under the grown bucket, and the
        # answers must still be oracle-exact on the mutated graph
        r = eng.run(qs)
        assert r.stats["n_materialized"] == 0, r.stats
        assert r.stats["n_cache_misses"] == 0
        assert r.stats["n_cache_hits"] > 0
        for qi, (s, t, k) in enumerate(qs):
            truth = path_set(enumerate_paths_bruteforce(eng.g, s, t, k))
            assert path_set(r[qi].paths) == truth, f"q{qi}"
