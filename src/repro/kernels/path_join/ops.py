"""Public wrapper: join-validity matrices for ⊕ and splice joins."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import resolve_backend
from .kernel import path_overlap_pallas
from .ref import path_overlap_ref

__all__ = ["path_overlap", "keyed_join_valid", "splice_join_valid"]


def path_overlap(a_verts: jax.Array, b_verts: jax.Array,
                 backend: str | None = None) -> jax.Array:
    backend = resolve_backend(backend)
    if backend == "pallas":
        return path_overlap_pallas(a_verts, b_verts)
    if backend == "interpret":
        return path_overlap_pallas(a_verts, b_verts, interpret=True)
    return path_overlap_ref(a_verts, b_verts)


def keyed_join_valid(a_verts: jax.Array, a_col: int, b_verts: jax.Array,
                     b_col: int, backend: str | None = None) -> jax.Array:
    """(NA, NB) bool: last vertices match and it is the only shared vertex."""
    ov = path_overlap(a_verts[:, :a_col + 1], b_verts[:, :b_col + 1], backend)
    key = a_verts[:, a_col][:, None] == b_verts[:, b_col][None, :]
    key &= (a_verts[:, a_col] >= 0)[:, None]
    return key & (ov == 1)


def splice_join_valid(p_verts: jax.Array, p_col: int, c_verts: jax.Array,
                      c_col: int, backend: str | None = None) -> jax.Array:
    """(NP, NC) bool: prefix and cached suffix share no vertex."""
    ov = path_overlap(p_verts[:, :p_col + 1], c_verts[:, :c_col + 1], backend)
    valid_p = (p_verts[:, 0] >= 0)[:, None]
    valid_c = (c_verts[:, 0] >= 0)[None, :]
    return (ov == 0) & valid_p & valid_c
