"""Fraud detection on a transaction network (paper §I application 1).

A burst of transactions (t -> s edges about to be added) arrives; for each
we ask whether paths s ->..-> t of <= k hops exist — each found path closes
a suspicious cycle when the new edge lands. Transactions in a burst hit
overlapping hub accounts, so the batch engine's sharing shines.

    PYTHONPATH=src python examples/fraud_detection.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators

K = 5
N_TX = 24

net = generators.powerlaw(30_000, avg_deg=6.0, seed=7)   # account graph
engine = BatchPathEngine(net, EngineConfig(gamma=0.5))

# synthesize a burst: transactions target a few hub merchants
rng = np.random.default_rng(0)
hubs = rng.integers(0, 200, size=4)                      # popular merchants
tx = []
while len(tx) < N_TX:
    payer = int(rng.integers(0, net.n))
    merchant = int(hubs[rng.integers(0, len(hubs))])
    if payer != merchant:
        # new edge payer->merchant closes a cycle for each merchant->payer path
        tx.append((merchant, payer, K))

res = engine.process(tx, mode="batch")
flagged = {i: res.paths[i] for i in range(len(tx)) if res.paths[i].shape[0]}
print(f"burst of {len(tx)} transactions, k={K}")
print(f"flagged {len(flagged)} transactions with cycle-closing paths")
for i, paths in list(flagged.items())[:5]:
    s, t, k = tx[i]
    cyc = [int(v) for v in paths[0] if v >= 0]
    print(f"  tx {t}->{s}: {paths.shape[0]} paths; "
          f"e.g. cycle {cyc + [cyc[0]]}")
print("sharing:", res.stats["n_shared"], "shared HC-s path queries across",
      res.stats["n_clusters"], "clusters")
