"""Graph containers for the batch HC-s-t path engine.

The host-side ``Graph`` is built with numpy (CSR both directions, padded-ELL
views, destination-sorted edge lists). Device views are materialized lazily
as jnp arrays. All layouts are static-shape so every downstream stage is
jit-compilable:

  * CSR            -- indptr/indices, canonical storage.
  * edge list      -- (src, dst) sorted by dst; drives segment-reduce hops.
  * padded ELL     -- (V, max_deg_cap) neighbor matrix padded with the
                      sentinel row ``V`` (frontier tables carry one extra
                      zero row); drives the Pallas kernels and the
                      enumeration gather. Vertices with deg > cap spill to a
                      COO remainder (power-law safety valve).
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Optional

import numpy as np

__all__ = ["Graph", "DeviceGraph", "EllView"]

SENTINEL = -1


@dataclasses.dataclass(frozen=True)
class EllView:
    """Padded ELL adjacency: idx[v, d] = d-th out-neighbor or n (sentinel)."""

    idx: np.ndarray          # (n, cap) int32, padded with n
    mask: np.ndarray         # (n, cap) bool
    spill_src: np.ndarray    # (n_spill,) int32 COO remainder
    spill_dst: np.ndarray    # (n_spill,) int32
    cap: int


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph, CSR in both directions. Vertices are 0..n-1."""

    n: int
    indptr: np.ndarray       # (n+1,) int64 — out-edges CSR
    indices: np.ndarray      # (m,) int32, sorted within row
    r_indptr: np.ndarray     # (n+1,) int64 — in-edges CSR (reverse graph)
    r_indices: np.ndarray    # (m,) int32

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(n: int, src, dst, dedup: bool = True) -> "Graph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size:
            keep = src != dst  # drop self loops: never on a simple path twice
            src, dst = src[keep], dst[keep]
        if dedup and src.size:
            key = src * n + dst
            _, uniq = np.unique(key, return_index=True)
            src, dst = src[uniq], dst[uniq]
        indptr, indices = _csr(n, src, dst)
        r_indptr, r_indices = _csr(n, dst, src)
        return Graph(n=n, indptr=indptr, indices=indices,
                     r_indptr=r_indptr, r_indices=r_indices)

    # ------------------------------------------------------------------
    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def in_degree(self) -> np.ndarray:
        return np.diff(self.r_indptr)

    def neighbors(self, v: int, reverse: bool = False) -> np.ndarray:
        ip, ix = (self.r_indptr, self.r_indices) if reverse else (self.indptr, self.indices)
        return ix[ip[v]:ip[v + 1]]

    # -- edge lists sorted by destination (segment-reduce friendly) ----
    @cached_property
    def edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of G with dst non-decreasing."""
        dst = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.r_indptr))
        src = self.r_indices
        return src.astype(np.int32), dst

    @cached_property
    def r_edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of G_r with dst non-decreasing (i.e. edges of G keyed by src)."""
        dst = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        src = self.indices
        return src.astype(np.int32), dst

    # -- padded ELL views ----------------------------------------------
    def ell(self, cap: Optional[int] = None, reverse: bool = False) -> EllView:
        ip, ix = (self.r_indptr, self.r_indices) if reverse else (self.indptr, self.indices)
        deg = np.diff(ip).astype(np.int64)
        if cap is None:
            cap = int(deg.max()) if self.n else 1
        cap = max(int(cap), 1)
        idx = np.full((self.n, cap), self.n, dtype=np.int32)
        # vectorized fill of the first `cap` neighbors per row
        take = np.minimum(deg, cap)
        rows = np.repeat(np.arange(self.n), take)
        cols = _ragged_arange(take)
        flat = np.repeat(ip[:-1], take) + cols
        idx[rows, cols] = ix[flat]
        mask = idx != self.n
        # spill: neighbors beyond cap
        extra = deg - take
        s_rows = np.repeat(np.arange(self.n, dtype=np.int32), extra)
        s_cols = _ragged_arange(extra) + np.repeat(take, extra)
        s_flat = np.repeat(ip[:-1], extra) + s_cols
        return EllView(idx=idx, mask=mask,
                       spill_src=s_rows, spill_dst=ix[s_flat].astype(np.int32),
                       cap=cap)

    def reverse(self) -> "Graph":
        return Graph(n=self.n, indptr=self.r_indptr, indices=self.r_indices,
                     r_indptr=self.indptr, r_indices=self.indices)

    # -- incremental mutation ------------------------------------------
    def apply_delta(self, delta) -> tuple["Graph", np.ndarray]:
        """Successor graph after a :class:`~repro.core.delta.GraphDelta`.

        Merges the (deduplicated, self-loop-free) edge mutations into both
        CSR directions without re-sorting the kept edges — equivalent to a
        ``from_edges`` rebuild on the edited edge list, in time
        proportional to ``m + |delta| log m``. Returns ``(new_graph,
        touched)`` where ``touched`` holds the unique endpoints of every
        *effective* change (no-op inserts/deletes excluded); an empty
        ``touched`` means ``new_graph is self``.
        """
        from .delta import apply_delta as _apply_delta
        applied = _apply_delta(self, delta)
        return applied.graph, applied.touched


def _csr(n: int, src: np.ndarray, dst: np.ndarray):
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst.astype(np.int32)


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated."""
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offs = np.repeat(np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    return np.arange(total, dtype=np.int64) - offs


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """jnp views of a Graph (built once per engine instance)."""

    n: int
    m: int
    # forward direction
    esrc: "jax.Array"        # (m,) int32 sorted by dst
    edst: "jax.Array"
    ell_idx: "jax.Array"     # (n, cap) int32, pad = n
    ell_mask: "jax.Array"
    # reverse direction
    r_esrc: "jax.Array"
    r_edst: "jax.Array"
    r_ell_idx: "jax.Array"
    r_ell_mask: "jax.Array"
    ell_cap: int
    r_ell_cap: int

    @staticmethod
    def build(g: Graph, ell_cap: Optional[int] = None) -> "DeviceGraph":
        import jax.numpy as jnp

        ell = g.ell(cap=ell_cap)
        rell = g.reverse().ell(cap=ell_cap)
        if ell.spill_src.size or rell.spill_src.size:
            raise ValueError(
                "ell_cap too small: spill present; enumeration requires the "
                "full ELL (pass ell_cap=None or >= max degree)")
        esrc, edst = g.edges_by_dst
        r_esrc, r_edst = g.r_edges_by_dst
        return DeviceGraph(
            n=g.n, m=g.m,
            esrc=jnp.asarray(esrc), edst=jnp.asarray(edst),
            ell_idx=jnp.asarray(ell.idx), ell_mask=jnp.asarray(ell.mask),
            r_esrc=jnp.asarray(r_esrc), r_edst=jnp.asarray(r_edst),
            r_ell_idx=jnp.asarray(rell.idx), r_ell_mask=jnp.asarray(rell.mask),
            ell_cap=ell.cap, r_ell_cap=rell.cap,
        )

    def direction(self, reverse: bool):
        """(ell_idx, ell_mask) for a search direction."""
        if reverse:
            return self.r_ell_idx, self.r_ell_mask
        return self.ell_idx, self.ell_mask
