"""Pallas TPU kernels for the engine's compute hot spots.

Each kernel package ships three modules:
  kernel.py -- pl.pallas_call body + BlockSpec tiling (TPU target)
  ops.py    -- jit'd public wrapper with backend switch ("pallas" |
               "interpret" | "jnp"); models/engine call these
  ref.py    -- pure-jnp oracle used for validation and as the jnp backend

This container is CPU-only: tests validate kernel bodies with
interpret=True against ref.py across shape/dtype sweeps; the dry-run
lowers the jnp backend (kernels cannot lower for the CPU backend), and the
BlockSpecs document the VMEM tiling used on real TPU.
"""
DEFAULT_BACKEND = "jnp"


def resolve_backend(backend):
    import jax
    if backend is not None:
        return backend
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else DEFAULT_BACKEND
