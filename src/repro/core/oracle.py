"""Brute-force references for tests: pure-Python DFS enumeration + host BFS.

These are the ground truth every engine variant (BasicEnum, BasicEnum+,
BatchEnum, BatchEnum+) is validated against. Deliberately simple and slow.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable

import numpy as np

from .graph import Graph

__all__ = ["enumerate_paths_bruteforce", "bfs_dist_from", "path_set"]


def bfs_dist_from(g: Graph, s: int, k_max: int, reverse: bool = False) -> np.ndarray:
    """Host BFS distances from s, capped at k_max (unreached = k_max+1)."""
    INF = k_max + 1
    dist = np.full(g.n, INF, dtype=np.int32)
    dist[s] = 0
    q = deque([s])
    while q:
        u = q.popleft()
        if dist[u] >= k_max:
            continue
        for v in g.neighbors(u, reverse=reverse):
            if dist[v] > dist[u] + 1:
                dist[v] = dist[u] + 1
                q.append(int(v))
    return dist


def enumerate_paths_bruteforce(g: Graph, s: int, t: int, k: int) -> list[tuple[int, ...]]:
    """All simple paths s->t with <= k hops, via recursive DFS."""
    out: list[tuple[int, ...]] = []
    if s == t or k <= 0:
        return out
    # prune with reverse BFS to keep the oracle usable on medium graphs
    dist_t = bfs_dist_from(g, t, k, reverse=True)
    path = [s]
    on_path = {s}

    def dfs(u: int):
        depth = len(path) - 1
        if u == t and depth >= 1:
            out.append(tuple(path))
            return  # extensions of a path through t would revisit t
        if depth == k:
            return
        for v in g.neighbors(u):
            v = int(v)
            if v in on_path:
                continue
            if depth + 1 + dist_t[v] > k:
                continue
            path.append(v)
            on_path.add(v)
            dfs(v)
            path.pop()
            on_path.remove(v)

    dfs(s)
    return out


def path_set(paths: Iterable) -> set[tuple[int, ...]]:
    """Normalize any iterable of paths (lists/arrays) to a set of tuples."""
    out = set()
    for p in paths:
        p = tuple(int(x) for x in np.asarray(p) if int(x) >= 0)
        out.add(p)
    return out
