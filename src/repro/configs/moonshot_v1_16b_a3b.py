"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from ..config import LMConfig, MoEConfig
from ._shapes import LM_SHAPES as SHAPES  # noqa: F401

CONFIG = LMConfig(name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048,
                  n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
                  qkv_bias=False,
                  moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408))

REDUCED = LMConfig(name="moonshot-reduced", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=96, vocab=256,
                   moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                                 capacity_factor=2.0),
                   dtype="float32")

FAMILY = "lm"
