"""Quickstart: batch HC-s-t path query processing in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators

# 1. a graph (use Graph.from_edges(n, src, dst) for your own edge lists)
g = generators.community(5000, n_comm=4, avg_deg=6.0, seed=0)
print(f"graph: {g.n} vertices, {g.m} edges")

# 2. a batch of hop-constrained s-t path queries (s, t, k)
queries = generators.similar_queries(g, 16, similarity=0.6, k_range=(4, 5),
                                     seed=1)
print(f"queries: {len(queries)}, e.g. {queries[0]}")

# 3. the engine: BatchEnum (Alg 4) — clusters queries, detects shared HC-s
#    path queries, enumerates with computation reuse
engine = BatchPathEngine(g, EngineConfig(gamma=0.5))
result = engine.process(queries, mode="batch")

for qi in range(3):
    s, t, k = queries[qi]
    paths = result.paths[qi]
    show = [tuple(int(v) for v in p if v >= 0) for p in paths[:3]]
    print(f"q{qi} ({s}->{t}, k={k}): {paths.shape[0]} paths, first: {show}")

print("stats:", {k: round(v, 4) if isinstance(v, float) else v
                 for k, v in result.stats.items()})

# 4. compare against per-query processing (BasicEnum, Alg 1).
#    The first call of each mode pays jit compilation; compare warm runs.
engine.process(queries, mode="basic")
basic = engine.process(queries, mode="basic")
warm = engine.process(queries, mode="batch")
t_b = basic.stats["t_enumerate"]
t_s = warm.stats["t_enumerate"]
print(f"enumeration (warm): basic {t_b:.3f}s vs batch {t_s:.3f}s "
      f"(speedup {t_b / max(t_s, 1e-9):.2f}x; "
      f"{warm.stats['n_dedup']} deduped half-queries, "
      f"{warm.stats['n_share_edges']} sharing edges)")
