"""Exp-4 (Fig 10): impact of the clustering threshold gamma.

Paper claim: as gamma decreases the time first drops (more sharing), then
rises past a turning point (over-merged clusters share too little).
"""
from __future__ import annotations

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, record, time_planner


def main(scale: float = 1.0) -> list[dict]:
    g = default_graph(scale, seed=4)
    qs = generators.similar_queries(g, 32, similarity=0.8, k_range=(5, 5),
                                    seed=5)
    rows = []
    for gamma in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0]:
        eng = BatchPathEngine(g, EngineConfig(min_cap=128, gamma=gamma))
        t, st = time_planner(eng, qs, "batch")
        rows.append(dict(gamma=gamma, t=t, n_clusters=st["n_clusters"],
                         n_shared=st.get("n_shared", 0)))
        record(f"exp4_gamma{gamma:.1f}", t * 1e6,
               f"clusters={st['n_clusters']};shared={st.get('n_shared', 0)}")
    return rows


if __name__ == "__main__":
    main()
