"""Public wrappers: join-validity matrices for ⊕ and splice joins."""
from __future__ import annotations

import jax

from ..registry import BackendLike, dispatch, register_op
from .kernel import (path_member_pallas, path_overlap_pallas,
                     rowwise_overlap_pallas)
from .ref import path_member_ref, path_overlap_ref, rowwise_overlap_ref

__all__ = ["path_overlap", "rowwise_overlap", "path_member",
           "keyed_join_valid", "splice_join_valid"]


register_op(
    "path_overlap",
    pallas=path_overlap_pallas,
    interpret=lambda a, b: path_overlap_pallas(a, b, interpret=True),
    jnp=path_overlap_ref,
)

register_op(
    "rowwise_overlap",
    pallas=lambda a, b: rowwise_overlap_pallas(a, b)[:, 0],
    interpret=lambda a, b: rowwise_overlap_pallas(a, b, interpret=True)[:, 0],
    jnp=rowwise_overlap_ref,
)

register_op(
    "path_member",
    pallas=path_member_pallas,
    interpret=lambda v, c: path_member_pallas(v, c, interpret=True),
    jnp=path_member_ref,
)


def path_overlap(a_verts: jax.Array, b_verts: jax.Array,
                 backend: BackendLike = None) -> jax.Array:
    """All-pairs shared-vertex counts: (NA, LA) x (NB, LB) -> (NA, NB)."""
    return dispatch("path_overlap", backend)(a_verts, b_verts)


def rowwise_overlap(a_verts: jax.Array, b_verts: jax.Array,
                    backend: BackendLike = None) -> jax.Array:
    """Row-aligned shared-vertex counts: (N, LA) x (N, LB) -> (N,)."""
    return dispatch("rowwise_overlap", backend)(a_verts, b_verts)


def path_member(verts: jax.Array, cand: jax.Array,
                backend: BackendLike = None) -> jax.Array:
    """(N, L) prefixes x (N, D) candidates -> (N, D) bool membership."""
    return dispatch("path_member", backend)(verts, cand) > 0


def keyed_join_valid(a_verts: jax.Array, a_col: int, b_verts: jax.Array,
                     b_col: int, backend: BackendLike = None) -> jax.Array:
    """(NA, NB) bool: last vertices match and it is the only shared vertex."""
    ov = path_overlap(a_verts[:, :a_col + 1], b_verts[:, :b_col + 1], backend)
    key = a_verts[:, a_col][:, None] == b_verts[:, b_col][None, :]
    key &= (a_verts[:, a_col] >= 0)[:, None]
    return key & (ov == 1)


def splice_join_valid(p_verts: jax.Array, p_col: int, c_verts: jax.Array,
                      c_col: int, backend: BackendLike = None) -> jax.Array:
    """(NP, NC) bool: prefix and cached suffix share no vertex."""
    ov = path_overlap(p_verts[:, :p_col + 1], c_verts[:, :c_col + 1], backend)
    valid_p = (p_verts[:, 0] >= 0)[:, None]
    valid_c = (c_verts[:, 0] >= 0)[None, :]
    return (ov == 0) & valid_p & valid_c
