"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Model code annotates arrays with *logical* axes; the rules below map them
to mesh axes for the active topology. Meshes:

  host      : (1,)            -- CPU tests
  pod       : (16, 16)        ("data", "model")
  multipod  : (2, 16, 16)     ("pod", "data", "model")

Rules (see DESIGN.md §4):
  * "batch"   -> ("pod", "data")   data parallel (+ pods)
  * "fsdp"    -> ("pod", "data")   parameter row sharding (ZeRO-3 style)
  * "tensor"  -> "model"           tensor parallel (heads / ffn / vocab)
  * "expert"  -> "model"           expert parallel (MoE)
  * "cells"   -> all axes flat     GNN nodes/edges, recsys rows, engine rows
  * "seq_kv"  -> "model" (or all axes when batch == 1) for decode KV
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "logical_to_sharding", "tree_shardings"]


class Rules:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        has_pod = "pod" in names
        dp = ("pod", "data") if has_pod else ("data",)
        self.map = {
            "batch": dp,
            "fsdp": dp,
            "tensor": ("model",),
            "expert": ("model",),
            "cells": tuple(names),
            "seq": ("model",),             # sequence-parallel residual stream
            "seq_kv": ("model",),
            "seq_kv_wide": tuple(names),   # batch=1 long-context decode
            None: None,
        }
        self.axis_sizes = dict(zip(names, mesh.devices.shape))

    def size(self, logical: str) -> int:
        axes = self.map.get(logical, None)
        if not axes:
            return 1
        out = 1
        for a in axes:
            out *= self.axis_sizes[a]
        return out

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for l in logical:
            m = self.map.get(l, None) if l is not None else None
            if m is None:
                parts.append(None)
            elif len(m) == 1:
                parts.append(m[0])
            else:
                parts.append(m)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def logical_to_sharding(rules: Rules, logical_axes) -> NamedSharding:
    return rules.sharding(*logical_axes)


def tree_shardings(rules: Rules, logical_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: rules.sharding(*axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
