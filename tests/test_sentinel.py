"""Sentinel-padding semantics: pow2-bucketed edge lists padded with
sentinel edges ``(n, n)`` must be *bit-equivalent* to exact-shape
execution for every edge kernel (``msbfs_dist`` / ``msbfs_set_dist`` /
``walk_counts`` / ``build_index``), across random graphs, random
valid-edge prefixes, the empty graph, and the all-sentinel edge case."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core import build_index, generators
from repro.core.graph import DeviceGraph, Graph, pad_edge_list, pow2_ceil
from repro.core.index import walk_counts
from repro.core.msbfs import INF_FOR, edge_span, msbfs_dist, msbfs_set_dist
from repro.core.oracle import enumerate_paths_bruteforce, path_set


def _random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    return Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))


def _padded(g: Graph, cap: int, reverse: bool = False):
    esrc, edst = g.r_edges_by_dst if reverse else g.edges_by_dst
    ps, pd = pad_edge_list(esrc, edst, g.n, cap)
    return jnp.asarray(ps), jnp.asarray(pd)


def _exact(g: Graph, reverse: bool = False):
    esrc, edst = g.r_edges_by_dst if reverse else g.edges_by_dst
    return jnp.asarray(esrc), jnp.asarray(edst)


class TestEdgeSpan:
    def test_rounds_up_to_chunk_and_clamps_to_cap(self):
        assert edge_span(0, 16, 64) == 0
        assert edge_span(1, 16, 64) == 16
        assert edge_span(16, 16, 64) == 16
        assert edge_span(17, 16, 64) == 32
        assert edge_span(63, 16, 64) == 64
        assert edge_span(64, 16, 64) == 64
        assert edge_span(100, 16, 64) == 64       # clamped
        assert edge_span(5, 1 << 22, 8) == 8      # chunk larger than cap

    def test_in_bucket_churn_is_one_static_value(self):
        # every valid count inside one chunk granule maps to the same
        # span: the invariant that makes m_valid safe as a static jit arg
        spans = {edge_span(m, 16, 256) for m in range(17, 33)}
        assert spans == {32}


class TestMsbfsSentinelParity:
    @given(st.integers(4, 60), st.integers(0, 200), st.integers(1, 5),
           st.integers(0, 31))
    @settings(max_examples=25, deadline=None)
    def test_msbfs_dist_bit_equal(self, n, m, k_max, seed):
        g = _random_graph(n, m, seed)
        r = np.random.default_rng(seed)
        srcs = jnp.asarray(r.integers(0, n, 4).astype(np.int32))
        cap = pow2_ceil(g.m) * int(r.integers(1, 3))   # this or next bucket
        want = np.asarray(msbfs_dist(*_exact(g), srcs, n=n, k_max=k_max))
        got = np.asarray(msbfs_dist(*_padded(g, cap), srcs, n=n, k_max=k_max))
        np.testing.assert_array_equal(got, want)
        # the chunk-rounded m_valid span must not change the answer either
        mv = edge_span(g.m, 16, cap)
        got_mv = np.asarray(msbfs_dist(*_padded(g, cap), srcs, n=n,
                                       k_max=k_max, edge_chunk=16,
                                       m_valid=mv))
        np.testing.assert_array_equal(got_mv, want)

    @given(st.integers(4, 60), st.integers(0, 200), st.integers(1, 5),
           st.integers(0, 31))
    @settings(max_examples=25, deadline=None)
    def test_msbfs_set_dist_bit_equal(self, n, m, k_max, seed):
        g = _random_graph(n, m, seed)
        r = np.random.default_rng(seed + 1)
        mask = np.zeros(n + 1, np.int8)
        mask[r.integers(0, n, 3)] = 1
        mask = jnp.asarray(mask)
        cap = pow2_ceil(max(g.m, 2))
        for reverse in (False, True):
            want = np.asarray(msbfs_set_dist(*_exact(g, reverse), mask,
                                             n=n, k_max=k_max))
            got = np.asarray(msbfs_set_dist(*_padded(g, cap, reverse), mask,
                                            n=n, k_max=k_max,
                                            m_valid=edge_span(g.m, 1 << 22,
                                                              cap)))
            np.testing.assert_array_equal(got, want)

    @given(st.integers(4, 50), st.integers(0, 150), st.integers(1, 4),
           st.integers(0, 31))
    @settings(max_examples=25, deadline=None)
    def test_walk_counts_bit_equal(self, n, m, budget, seed):
        g = _random_graph(n, m, seed)
        r = np.random.default_rng(seed + 2)
        slack = r.integers(-1, budget + 1, n + 1).astype(np.int8)
        slack[-1] = -1
        slack = jnp.asarray(slack)
        source = int(r.integers(0, n))
        cap = pow2_ceil(max(g.m, 2)) * 2
        want = np.asarray(walk_counts(*_exact(g), source, slack,
                                      n=n, budget=budget))
        got = np.asarray(walk_counts(*_padded(g, cap), source, slack,
                                     n=n, budget=budget,
                                     m_valid=edge_span(g.m, 32, cap),
                                     edge_chunk=32))
        np.testing.assert_array_equal(got, want)

    def test_empty_graph(self):
        g = Graph.from_edges(5, [], [])
        dg = DeviceGraph.build(g)            # pads to one sentinel edge
        dist = np.asarray(msbfs_dist(dg.esrc, dg.edst,
                                     jnp.asarray(np.array([2], np.int32)),
                                     n=g.n, k_max=3))
        INF = INF_FOR(3)
        want = np.full((g.n + 1, 1), INF, np.int8)
        want[2, 0] = 0
        np.testing.assert_array_equal(dist, want)
        tot = np.asarray(walk_counts(
            dg.esrc, dg.edst, 2, jnp.asarray(np.full(g.n + 1, 3, np.int8)),
            n=g.n, budget=2))
        np.testing.assert_array_equal(tot, [1.0, 0.0, 0.0])

    def test_all_sentinel_prefix(self):
        """m_valid = 0 over a non-empty padded buffer: every edge is
        sentinel, the sweep must behave exactly like the empty graph."""
        g = _random_graph(12, 40, 3)
        esrc, edst = _padded(g, pow2_ceil(g.m))
        srcs = jnp.asarray(np.array([0, 5], np.int32))
        got = np.asarray(msbfs_dist(esrc, edst, srcs, n=g.n, k_max=3,
                                    m_valid=0))
        empty = Graph.from_edges(g.n, [], [])
        want = np.asarray(msbfs_dist(*_exact(empty), srcs, n=g.n, k_max=3))
        np.testing.assert_array_equal(got, want)


class TestIndexAndEngineParity:
    def test_build_index_padded_vs_exact(self):
        g = generators.community(150, n_comm=3, avg_deg=4.0, seed=5)
        qs = generators.similar_queries(g, 6, similarity=0.7,
                                        k_range=(3, 4), seed=6)
        keys = [tuple(q) for q in qs]
        ix_pad = build_index(DeviceGraph.build(g), keys)
        ix_exact = build_index(DeviceGraph.build(g, pad=False), keys)
        np.testing.assert_array_equal(np.asarray(ix_pad.dist_s),
                                      np.asarray(ix_exact.dist_s))
        np.testing.assert_array_equal(np.asarray(ix_pad.dist_t),
                                      np.asarray(ix_exact.dist_t))

    def test_engine_results_padded_vs_unpadded(self):
        """End-to-end parity: the default (sentinel-padded) engine and one
        forced onto exact-shape device views enumerate identical path
        sets, both oracle-exact."""
        from repro.core import BatchPathEngine, EngineConfig
        g = generators.community(150, n_comm=3, avg_deg=4.0, seed=7)
        qs = generators.similar_queries(g, 5, similarity=0.7,
                                        k_range=(3, 3), seed=8)
        eng_pad = BatchPathEngine(g, EngineConfig(min_cap=64))
        assert eng_pad.dg.m_cap == pow2_ceil(g.m)
        eng_exact = BatchPathEngine(g, EngineConfig(min_cap=64))
        eng_exact.dg = DeviceGraph.build(g, pad=False)
        r_pad = eng_pad.run(qs)
        r_exact = eng_exact.run(qs)
        for qi, (s, t, k) in enumerate(qs):
            truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
            assert path_set(r_pad[qi].paths) == truth, f"padded q{qi}"
            assert path_set(r_exact[qi].paths) == truth, f"exact q{qi}"
