"""SLO-aware admission + failover serving edge cases (launch.serve).

Covers: the typed SHED QueryResult contract, deadline-vs-shed interaction
(expired waiters shed at admission, urgent slack cuts batches early),
overload shedding with the exists/count pressure fast path, weighted-fair
tenant ordering, the VirtualClock / advance_batch charging protocol, and
mid-batch replica-group failure (requeue on survivors, results exactly
once per query id, cache survival, revive).
"""
import math

import pytest

from repro.core import (BatchPathEngine, EngineConfig, PathQuery,
                        generators)
from repro.core.query import QueryResult, ResultStatus
from repro.launch.serve import (AdmissionPolicy, GroupFailure,
                                StreamingServer, VirtualClock)


def _graph(n=300):
    return generators.community(n, n_comm=3, avg_deg=5.0, seed=0)


def _engine(g=None, **kw):
    return BatchPathEngine(g or _graph(), EngineConfig(min_cap=64, **kw))


def _queries(g, n, seed=1, k=(3, 4)):
    return [PathQuery.coerce(q)
            for q in generators.random_queries(g, n, k, seed=seed)]


# -- PathQuery SLO fields ------------------------------------------------

def test_deadline_and_tenant_fields_validate():
    q = PathQuery(0, 1, 3, deadline_s=0.5, tenant="gold")
    assert q.deadline_s == 0.5 and q.tenant == "gold"
    with pytest.raises(ValueError):
        PathQuery(0, 1, 3, deadline_s=0.0)
    with pytest.raises(ValueError):
        PathQuery(0, 1, 3, deadline_s=-1.0)


# -- shed result contract ------------------------------------------------

def test_shed_result_contract():
    q = PathQuery(0, 1, 3)
    r = QueryResult.shed(q, "overload")
    assert r.status is ResultStatus.SHED
    assert not r.ok
    assert r.shed_reason == "overload"
    # data accessors must fail loudly, naming the query and reason
    for accessor in ("paths", "count", "exists"):
        with pytest.raises(ValueError, match="overload"):
            getattr(r, accessor)
    assert "SHED" in repr(r)


def test_ok_result_is_ok():
    g = _graph()
    eng = _engine(g)
    r = eng.run(_queries(g, 1))[0]
    assert r.ok and r.status is ResultStatus.OK and r.shed_reason is None


# -- overload shedding + pressure fast path ------------------------------

def test_overload_sheds_paths_and_fast_paths_cheap_outputs():
    g = _graph()
    srv = StreamingServer(_engine(g), policy=AdmissionPolicy(
        max_batch=8, max_delay_s=math.inf, min_batch=64, max_queue=2))
    qs = _queries(g, 5)
    srv.submit(qs[0])
    srv.submit(qs[1])
    # queue is at max_queue: a paths query is shed with a typed result...
    qid_shed = srv.submit(qs[2])
    assert srv.results[qid_shed].status is ResultStatus.SHED
    assert srv.results[qid_shed].shed_reason == "overload"
    # ...but exists/count answer immediately through the fast path
    q3, q4 = qs[3], qs[4]
    qid_e = srv.submit(PathQuery(q3.s, q3.t, q3.k, output="exists"))
    qid_c = srv.submit(PathQuery(q4.s, q4.t, q4.k, output="count"))
    for qid in (qid_e, qid_c):
        assert qid in srv.results and srv.results[qid].ok
    assert srv.n_shed == 1
    srv.drain()  # the two waiting queries still complete
    assert len(srv.results) == 5


def test_take_returns_shed_result_once():
    g = _graph()
    srv = StreamingServer(_engine(g), policy=AdmissionPolicy(
        max_batch=4, min_batch=4, max_delay_s=math.inf, max_queue=0))
    qid = srv.submit(_queries(g, 1)[0])
    r = srv.take(qid)
    assert r.status is ResultStatus.SHED
    with pytest.raises(KeyError):
        srv.take(qid)


# -- deadlines -----------------------------------------------------------

def test_expired_deadline_sheds_at_admission():
    g = _graph()
    clock = VirtualClock()
    srv = StreamingServer(_engine(g), clock=clock, policy=AdmissionPolicy(
        max_batch=8, min_batch=1, max_delay_s=10.0))
    q = _queries(g, 2)
    qid_dead = srv.submit(PathQuery(q[0].s, q[0].t, q[0].k, deadline_s=1.0))
    qid_live = srv.submit(q[1])
    clock.advance(5.0)          # deadline long gone before any admission
    srv.drain()
    assert srv.results[qid_dead].shed_reason == "deadline"
    assert srv.results[qid_live].ok
    assert srv.n_shed == 1


def test_shed_expired_false_executes_late_queries():
    g = _graph()
    clock = VirtualClock()
    srv = StreamingServer(_engine(g), clock=clock, policy=AdmissionPolicy(
        max_batch=8, min_batch=1, max_delay_s=10.0, shed_expired=False))
    q = _queries(g, 1)[0]
    qid = srv.submit(PathQuery(q.s, q.t, q.k, deadline_s=1.0))
    clock.advance(5.0)
    srv.drain()
    assert srv.results[qid].ok          # executed anyway...
    assert srv.n_deadline_miss >= 1     # ...but counted as an SLO miss


def test_spent_slack_cuts_batch_before_min_batch():
    g = _graph()
    clock = VirtualClock()
    # min_batch=64 and a huge max_delay would coalesce forever; the spent
    # deadline slack must override both and admit the lone waiter
    srv = StreamingServer(_engine(g), clock=clock, policy=AdmissionPolicy(
        max_batch=64, min_batch=64, max_delay_s=math.inf))
    srv._service_ewma = 1.5     # as if recent batches took 1.5s each
    q = _queries(g, 1)[0]
    srv.submit(PathQuery(q.s, q.t, q.k, deadline_s=2.0))
    assert not srv.pump()       # slack 0.5s remains: still coalescing
    clock.advance(0.7)
    # 1.3s to the deadline < 1.5s expected service: slack is spent, the
    # batch is cut before min_batch/max_delay — and before expiry, so the
    # query executes (it is not shed)
    assert srv.pump()
    assert len(srv.batch_log) == 1
    assert all(r.ok for r in srv.results.values())


def test_due_deadline_overrides_min_batch():
    pol = AdmissionPolicy(max_batch=32, min_batch=8, max_delay_s=0.1)
    assert not pol.due(3, 0.05)                  # under min_batch, young
    assert pol.due(3, 0.2)                       # max_delay exceeded
    assert pol.due(3, 0.0, min_slack_s=-0.01)    # SLO slack spent
    assert not pol.due(3, 0.0, min_slack_s=0.5)  # slack remains: coalesce
    assert not pol.due(0, 99.0)


# -- tenant fairness -----------------------------------------------------

def test_order_key_weighted_fairness_and_edf():
    pol = AdmissionPolicy(tenant_weights={"gold": 4.0})
    gold = PathQuery(0, 1, 3, tenant="gold")
    bronze = PathQuery(0, 1, 3, tenant="bronze")
    dl = PathQuery(0, 1, 3, tenant="bronze")
    # deadline queries sort ahead of all no-deadline queries (EDF)
    assert pol.order_key(dl, 0.1, 5.0) < pol.order_key(gold, 99.0, None)
    # same wait: the weighted tenant wins
    assert pol.order_key(gold, 1.0, None) < pol.order_key(bronze, 1.0, None)
    # ...but a bronze that waited > weight-ratio longer wins (no starving)
    assert pol.order_key(bronze, 5.0, None) < pol.order_key(gold, 1.0, None)


def test_weighted_tenant_admitted_first_under_contention():
    g = _graph()
    clock = VirtualClock()
    srv = StreamingServer(_engine(g), clock=clock, policy=AdmissionPolicy(
        max_batch=4, min_batch=1, max_delay_s=0.1,
        tenant_weights={"gold": 8.0}))
    qs = _queries(g, 8, seed=3)
    for i, q in enumerate(qs):      # same arrival time, alternating tenant
        srv.submit(PathQuery(q.s, q.t, q.k,
                             tenant="gold" if i % 2 else "bronze"))
    clock.advance(0.2)
    srv.pump()                      # one max_batch=4 admission is due
    first = srv.batch_log[0]["tenants"]
    assert first.get("gold", 0) == 4, f"gold not prioritized: {first}"
    srv.drain()
    assert len(srv.results) == 8    # bronze still served (no starvation)


# -- clock protocol ------------------------------------------------------

def test_virtual_clock_charges_real_wall():
    clock = VirtualClock(5.0)
    assert clock() == 5.0
    clock.advance(0.25)
    assert clock() == 5.25


def test_advance_batch_protocol_preferred():
    charges = []

    class ModelClock(VirtualClock):
        def advance_batch(self, dt, n_queries):
            charges.append(n_queries)
            self.t += 1.0

    g = _graph()
    clock = ModelClock()
    srv = StreamingServer(_engine(g), clock=clock, policy=AdmissionPolicy(
        max_batch=4, min_batch=1, max_delay_s=0.0))
    for q in _queries(g, 3, seed=4):
        srv.submit(q)
    srv.drain()
    assert sum(charges) == 3 and clock() >= 1.0
    # e2e on the virtual timeline: wait + charged service, never real wall
    assert srv.batch_log[-1]["e2e_p50_s"] >= 1.0


# -- failover ------------------------------------------------------------

def test_group_failure_requeues_and_results_land_exactly_once():
    g = _graph()
    eng = _engine(g, cache_bytes=32 << 20)
    srv = StreamingServer(eng, n_groups=3, gamma=0.9,
                          policy=AdmissionPolicy(max_batch=16, min_batch=1,
                                                 max_delay_s=0.0))
    state = {"n": 0}

    def injector(grp, item):
        if grp == 0:
            state["n"] += 1
            if state["n"] == 2:     # die executing the second item
                raise GroupFailure(grp)

    srv.fail_injector = injector
    qids = [srv.submit(q) for q in _queries(g, 16, seed=5)]
    srv.drain()
    assert srv.n_failovers == 1 and 0 in srv.dead_groups
    assert srv.sched.requeued >= 1
    # exactly once per query id: every qid resolved, none lost, and the
    # requeued cluster's answers are real results (idempotent re-run)
    assert sorted(srv.results) == sorted(qids)
    assert all(srv.results[qid].ok for qid in qids)
    log = srv.batch_log[-1]
    assert log["failovers"] == 1 and log["requeued"] >= 1
    # the shared cache survived the group death
    assert eng.cache is not None and eng.cache.info()["entries"] > 0


def test_all_groups_dead_raises():
    g = _graph()
    srv = StreamingServer(_engine(g), n_groups=2,
                          policy=AdmissionPolicy(max_batch=4, min_batch=1,
                                                 max_delay_s=0.0))

    def injector(grp, item):
        raise GroupFailure(grp)

    srv.fail_injector = injector
    srv.submit(_queries(g, 1, seed=6)[0])
    with pytest.raises(RuntimeError, match="dead"):
        srv.drain()


def test_revive_group_serves_again():
    g = _graph()
    srv = StreamingServer(_engine(g), n_groups=2,
                          policy=AdmissionPolicy(max_batch=4, min_batch=1,
                                                 max_delay_s=0.0))
    srv.kill_group(0)
    assert srv.n_failovers == 1
    qs = _queries(g, 2, seed=7)
    srv.submit(qs[0])
    srv.drain()                     # group 1 carries the batch alone
    srv.revive_group(0)
    srv.submit(qs[1])
    srv.drain()
    assert len(srv.results) == 2
    assert all(r.ok for r in srv.results.values())
