"""Kernel micro-benchmarks + dispatch-shape accounting for the fused path.

Three sections, all returned as a dict (and written to
``results/BENCH_kernels.json``) so ``check_regression.py --kernels`` can
gate them:

  * **timing / bandwidth** — the jnp reference twins the engine actually
    executes off-TPU, timed warm (best-of-N), with an analytic per-call
    HBM-traffic model per op. ``achieved_gbps`` is this machine's
    effective bandwidth; ``roofline_frac`` relates it to the TPU-v5e HBM
    roof from ``launch.roofline.HW`` (the deploy target the Pallas path
    is tiled for). Interpret mode is a correctness backend, not a
    performance proxy, so it is never timed here.
  * **dispatch counts** — the point of the fused ``msbfs_step`` kernel is
    collapsing the per-level expand → dedup → distance-write chain into
    ONE device dispatch. Both arms of one MS-BFS level are traced and
    their jaxpr equations counted (pallas_call bodies count as one);
    the jnp arm is additionally compiled and its HLO entry-computation
    op count recorded (``launch.hlo_analysis.count_entry_ops``). These
    are deterministic, hardware-independent integers — gateable in CI.
  * **warm retraces** — the packed sweeps run twice on identical shapes
    under the compile recorder; the second pass must add zero compiles
    (the zero-warm-retrace guarantee must survive the kernel route).

The VMEM tile plan for ``msbfs_step`` is derived from the roofline
constants: the (block_v, block_w) defaults must keep a tile's working set
(ELL rows + full frontier column panel + dist tile) comfortably inside a
v5e core's ~128 MiB/8 VMEM share.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import count_entry_ops, count_eqns
from repro.launch.roofline import HW

from .common import record


def _bench(fn, *args, repeats=5):
    jax.tree.leaves(fn(*args))[0].block_until_ready()
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def _op_row(name: str, dt: float, nbytes: float, derived: str = "") -> dict:
    gbps = nbytes / dt / 1e9
    frac = gbps * 1e9 / HW["hbm_bw"]
    record(f"kernel_{name}", dt * 1e6,
           f"{derived}{';' if derived else ''}GBps={gbps:.2f};"
           f"roofline_frac={frac:.4f}")
    return {"us": dt * 1e6, "bytes": nbytes, "achieved_gbps": gbps,
            "roofline_frac": frac}


# eqn accounting lives in launch.hlo_analysis.count_eqns, shared with the
# repro.analysis jaxpr audit so bench numbers and budget gates agree


def _dispatch_counts(n: int, D: int, S: int, seed: int = 0) -> dict:
    """Per-level op footprint of the two MS-BFS arms on identical shapes.

    The jnp arm is one level of :func:`repro.core.msbfs.msbfs_dist`
    (expand + dedup + distance write as separate segment/mask ops); the
    fused arm is the same level through ``msbfs_step`` (interpret mode —
    the dispatch shape is identical to the compiled TPU kernel, only the
    body execution differs). Both jaxpr-eqn counts come from the same
    tracer, so the comparison is apples-to-apples and deterministic.
    """
    from repro.core.msbfs import msbfs_hop
    from repro.kernels.msbfs_expand.ops import msbfs_step

    rng = np.random.default_rng(seed)
    m = n * 4
    esrc = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
    edst = jnp.asarray(np.sort(rng.integers(0, n, m).astype(np.int32)))
    ell = jnp.asarray(rng.integers(0, n + 1, (n + 1, D)).astype(np.int32)
                      ).at[n].set(n)
    W = -(-S // 32)
    frontier8 = jnp.asarray((rng.random((n + 1, S)) < 0.05).astype(np.int8))
    dist8 = jnp.asarray(rng.integers(0, 9, (n + 1, S)).astype(np.int8))
    fr_w = jnp.asarray(rng.integers(0, 2**32, (n + 1, W), dtype=np.uint64)
                       .astype(np.uint32))
    vis_w = fr_w[:n]
    dist_w = jnp.asarray(rng.integers(0, 9, (n, W * 32)).astype(np.int8))

    def level_jnp(frontier, dist):
        reached = (dist < jnp.int8(9)).astype(jnp.int8)
        nxt = msbfs_hop(frontier, esrc, edst, n)
        new = nxt * (1 - reached)
        dist = jnp.where(new.astype(bool), jnp.int8(3), dist)
        return new.at[n].set(0), dist

    def level_fused(frontier, visited, dist):
        f, v, d = msbfs_step(ell[:n], frontier, visited, dist, 3,
                             backend="interpret")
        return jnp.concatenate([f, jnp.zeros((1, W), jnp.uint32)]), v, d

    jnp_eqns = count_eqns(jax.make_jaxpr(level_jnp)(frontier8, dist8).jaxpr)
    fused_eqns = count_eqns(
        jax.make_jaxpr(level_fused)(fr_w, vis_w, dist_w).jaxpr)
    # compiled footprint of the jnp arm (the fused arm's Pallas kernel
    # cannot lower off-TPU; its dispatch count IS the jaxpr count)
    hlo = jax.jit(level_jnp).lower(frontier8, dist8).compile().as_text()
    return {"n": n, "ell_width": D, "sources": S,
            "jnp_eqns_per_level": jnp_eqns,
            "fused_eqns_per_level": fused_eqns,
            "jnp_hlo_entry_ops": count_entry_ops(hlo)}


def _warm_retraces(n: int, D: int, S: int) -> dict:
    """Run the packed ELL sweeps twice on identical shapes; the second
    pass must hit only warm jit caches (zero new compiles)."""
    from repro.core import compilelog
    from repro.core.msbfs import msbfs_dist_ell, msbfs_set_dist_ell

    rng = np.random.default_rng(1)
    ell = jnp.asarray(rng.integers(0, n + 1, (n + 1, D)).astype(np.int32)
                      ).at[n].set(n)
    srcs = jnp.asarray(rng.choice(n, size=S, replace=False).astype(np.int32))
    seed = np.zeros(n + 1, np.int8)
    seed[np.asarray(srcs)[:4]] = 1
    seed = jnp.asarray(seed)

    def sweep():
        d = msbfs_dist_ell(ell, srcs, n=n, k_max=4, backend="interpret")
        sd = msbfs_set_dist_ell(ell, seed, n=n, k_max=4, backend="interpret")
        jax.block_until_ready((d, sd))

    rec = compilelog.enable()
    sweep()                      # cold: pays the compiles
    snap = rec.snapshot()
    sweep()                      # warm: must add zero
    return {"warm_retraces": rec.compiles_since(snap),
            "warm_compiles_by_kernel": rec.since(snap)}


def _tile_plan(D: int) -> dict:
    """VMEM working set of one msbfs_step tile at the default BlockSpec
    (block_v x ELL rows, the full (V+1, block_w) frontier panel is
    re-fetched per row tile — the frontier is the reuse-heavy operand, so
    it is the one kept resident)."""
    block_v, block_w = 256, 8
    v_frontier = 200_000           # sizing vertex count for the panel term
    tile = (block_v * D * 4                 # ELL idx rows
            + (v_frontier + 1) * block_w * 4   # frontier panel (u32)
            + block_v * block_w * 4 * 2     # visited in + out (u32)
            + block_v * block_w * 32 * 2)   # dist in + out (i8)
    vmem_share = 128 * 2**20 / 8
    return {"block_v": block_v, "block_w": block_w,
            "tile_bytes": tile, "vmem_share_bytes": int(vmem_share),
            "fits_vmem": bool(tile <= vmem_share)}


def main(scale: float = 1.0) -> dict:
    rng = np.random.default_rng(0)
    out: dict = {"ops": {}}

    # fused MS-BFS level (jnp twin of msbfs_step): 200k vertices, deg-8
    # ELL, 128 packed sources
    from repro.kernels.msbfs_expand.ops import msbfs_step
    n, D, S = max(int(200_000 * scale), 4096), 8, 128
    W = S // 32
    ell = jnp.asarray(rng.integers(0, n + 1, (n + 1, D)).astype(np.int32)
                      ).at[n].set(n)
    fr = jnp.asarray(rng.integers(0, 2**32, (n + 1, W), dtype=np.uint64)
                     .astype(np.uint32))
    vis = fr[:n]
    dist = jnp.asarray(rng.integers(0, 9, (n, W * 32)).astype(np.int8))
    f = jax.jit(lambda a, b, c: msbfs_step(ell[:n], a, b, c, 3,
                                           backend="jnp"))
    dt = _bench(f, fr, vis, dist)
    # traffic: ELL rows + gathered frontier words + visited r/w + dist r/w
    nbytes = (n * D * 4 + n * D * W * 4 + 2 * (2 * n * W * 4) +
              2 * (n * W * 32))
    out["ops"]["msbfs_step_jnp"] = _op_row(
        "msbfs_step_jnp", dt, nbytes,
        f"V={n};D={D};S={S};GTEPS={n * D * S / dt / 1e9:.2f}")

    # edge-list MS-BFS hop (segment-op path the jnp engine runs)
    from repro.core.msbfs import msbfs_hop
    m = int(1_600_000 * scale)
    esrc = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
    edst = jnp.asarray(np.sort(rng.integers(0, n, m).astype(np.int32)))
    frontier = jnp.asarray((rng.random((n + 1, S)) < 0.05).astype(np.int8))
    f = jax.jit(lambda fr_: msbfs_hop(fr_, esrc, edst, n))
    dt = _bench(f, frontier)
    nbytes = m * 4 * 2 + m * S + n * S   # edges + gathered rows + segment out
    out["ops"]["msbfs_hop_jnp"] = _op_row(
        "msbfs_hop_jnp", dt, nbytes,
        f"edges={m};sources={S};GTEPS={m * S / dt / 1e9:.2f}")

    # pairwise popcount (similarity): 128 queries x n vertices
    from repro.kernels.pairwise_popcount.ref import intersections_bool_ref
    g = jnp.asarray(rng.random((128, n)) < 0.1)
    f = jax.jit(intersections_bool_ref)
    dt = _bench(f, g)
    out["ops"]["similarity_jnp"] = _op_row(
        "similarity_jnp", dt, 128 * n * 2 + 128 * 128 * 4, f"Q=128;V={n}")

    # row-aligned join validity (kernel twin the engine joins route):
    # 64k candidate pairs, halves of length 6
    from repro.kernels.path_join.ref import rowwise_overlap_ref
    N = 1 << 16
    A = jnp.asarray(rng.integers(0, 1000, (N, 6)).astype(np.int32))
    B = jnp.asarray(rng.integers(0, 1000, (N, 6)).astype(np.int32))
    f = jax.jit(rowwise_overlap_ref)
    dt = _bench(f, A, B)
    out["ops"]["rowwise_overlap_jnp"] = _op_row(
        "rowwise_overlap_jnp", dt, N * 6 * 4 * 2 + N * 4,
        f"rows={N};Mrows_s={N / dt / 1e6:.1f}")

    # dense path-pair overlap (detect-stage kernel): 4096 x 4096, L=6
    from repro.kernels.path_join.ref import path_overlap_ref
    A = jnp.asarray(rng.integers(0, 1000, (4096, 6)).astype(np.int32))
    B = jnp.asarray(rng.integers(0, 1000, (4096, 6)).astype(np.int32))
    f = jax.jit(path_overlap_ref)
    dt = _bench(f, A, B)
    out["ops"]["path_overlap_jnp"] = _op_row(
        "path_overlap_jnp", dt, 2 * 4096 * 6 * 4 + 4096 * 4096 * 4,
        f"pairs={4096 * 4096};Mpairs_s={4096 * 4096 / dt / 1e6:.1f}")

    # ELL SpMM (index walk-count DP step): 100k x deg16 x 128 feats
    from repro.kernels.ell_spmm.ref import ell_spmm_ref
    V, Dd, F = max(int(100_000 * scale), 4096), 16, 128
    ellv = jnp.asarray(rng.integers(0, V + 1, (V, Dd)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((V + 1, F)).astype(np.float32))
    f = jax.jit(lambda e, xx: ell_spmm_ref(e, xx, "sum"))
    dt = _bench(f, ellv, x)
    out["ops"]["ell_spmm_jnp"] = _op_row(
        "ell_spmm_jnp", dt, V * Dd * 4 + V * Dd * F * 4 + V * F * 4,
        f"gflops={2 * V * Dd * F / dt / 1e9:.1f}")

    # chunked attention (flash twin): B4 S2048 H8 hd64
    from repro.models.transformer import chunked_attention
    q = jnp.asarray(rng.standard_normal((4, 2048, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((4, 2048, 2, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True,
                                                  q_offset=0, chunk=512))
    dt = _bench(f, q, k, k)
    flops = 4 * 4 * 2048 * 2048 * 8 * 64 / 2
    out["ops"]["attention_jnp"] = _op_row(
        "attention_jnp", dt, (4 * 2048 * 8 * 64 * 4) * 4,
        f"gflops={flops / dt / 1e9:.1f}")

    # ---- dispatch-shape accounting (deterministic; CI-gated) ----------
    dn = max(int(50_000 * scale), 2048)
    out["dispatch"] = _dispatch_counts(dn, D, S)
    record("kernel_dispatch_eqns_per_level",
           out["dispatch"]["fused_eqns_per_level"],
           f"jnp={out['dispatch']['jnp_eqns_per_level']};"
           f"jnp_hlo_entry_ops={out['dispatch']['jnp_hlo_entry_ops']}")

    out.update(_warm_retraces(max(int(20_000 * scale), 1024), D, 64))
    record("kernel_warm_retraces", out["warm_retraces"],
           str(out["warm_compiles_by_kernel"]))

    out["tile_plan"] = _tile_plan(D)
    out["hw"] = {"hbm_bw": HW["hbm_bw"], "peak_flops": HW["peak_flops"]}

    dest = Path("results/BENCH_kernels.json")
    dest.parent.mkdir(parents=True, exist_ok=True)
    dest.write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
