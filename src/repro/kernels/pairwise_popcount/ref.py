"""Pure-jnp oracle for pairwise popcount intersections."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pairwise_popcount_ref", "intersections_bool_ref"]


def pairwise_popcount_ref(words: jax.Array) -> jax.Array:
    """(Q, W) uint32 -> (Q, Q) int32 via popcount(AND)."""
    inter = jax.lax.population_count(words[:, None, :] & words[None, :, :])
    return jnp.sum(inter.astype(jnp.int32), axis=-1)


def intersections_bool_ref(bits: jax.Array, chunk: int = 1 << 16) -> jax.Array:
    """(Q, V) bool -> (Q, Q) int32 via chunked MXU matmul."""
    Q, V = bits.shape
    out = jnp.zeros((Q, Q), jnp.float32)
    for lo in range(0, V, chunk):
        g = bits[:, lo:lo + chunk].astype(jnp.float32)
        out = out + g @ g.T
    return out.astype(jnp.int32)
