"""Deterministic synthetic token stream for LM training.

Zipf-distributed tokens with local n-gram structure so the loss actually
falls during the example runs (pure-uniform streams have no learnable
signal). Stateless: batch(step) is a pure function of (seed, step), which
makes checkpoint-resume exact — the restored run consumes the identical
stream (verified in tests/test_ft.py).
"""
from __future__ import annotations

import numpy as np

__all__ = ["TokenStream"]


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.batch, self.seq_len, self.vocab
        # zipf-ish marginals
        base = rng.zipf(1.5, size=(B, S + 1)) % V
        # inject learnable bigram structure: x[t+1] = (x[t]*7+3) % V half the time
        follow = (base * 7 + 3) % V
        use = rng.random((B, S + 1)) < 0.5
        seq = np.where(use, np.roll(follow, 1, axis=1), base)
        seq = seq.astype(np.int32)
        return seq[:, :S], seq[:, 1:S + 1]
