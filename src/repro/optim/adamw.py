"""AdamW + global-norm clipping + cosine schedule (pure jax, pytree-generic).

Optimizer state mirrors the parameter pytree (m, v in f32) so pjit shards it
exactly like the (ZeRO-sharded) parameters.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule"]


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros,
                      v=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def cosine_schedule(step, base_lr=3e-4, warmup=100, total=10_000, min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup, warm, cos)


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state, metrics). All math in f32."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = state.count + 1
    c = count.astype(jnp.float32)
    m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * g * g, state.v, grads)
    mh = jax.tree.map(lambda x: x / (1 - b1 ** c), m)
    vh = jax.tree.map(lambda x: x / (1 - b2 ** c), v)

    def upd(p, mh_, vh_):
        step = lr * (mh_ / (jnp.sqrt(vh_) + eps) + weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mh, vh)
    return new_params, AdamWState(m=m, v=v, count=count), {"grad_norm": gnorm}
