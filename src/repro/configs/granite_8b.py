"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324; hf]."""
from ..config import LMConfig
from ._shapes import LM_SHAPES as SHAPES  # noqa: F401

CONFIG = LMConfig(name="granite-8b", n_layers=36, d_model=4096, n_heads=32,
                  n_kv_heads=8, d_ff=14336, vocab=49152, qkv_bias=False)

REDUCED = LMConfig(name="granite-8b-reduced", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                   qkv_bias=False, dtype="float32")

FAMILY = "lm"
