from . import transformer, moe, gnn, recsys, sharding, sampler  # noqa: F401
