"""Pallas kernel: padded-ELL SpMM (gather-reduce message passing).

    out[v, f] = reduce_d  X[ell_idx[v, d], f]        (sum or max)

The GNN-substrate hot spot (GraphSAGE/MeshGraphNet/GraphCast aggregation)
and the float cousin of the MS-BFS OR-gather: JAX has no CSR SpMM (BCOO
only), so message passing is built from this regular gather-reduce over the
degree-padded ELL adjacency -- MXU-free but perfectly vectorized gathers,
the TPU-native replacement for CUDA scatter-atomics.

Tiling: grid = (row blocks, feature blocks); feature tile of the *full*
source matrix X (V+1, BF) resident in VMEM (launcher shards vertices to
keep (V_shard+1)*BF*4B within budget, e.g. 64k rows x 128 feats = 32 MB ->
shard to 16k rows = 8 MB), ELL tile (BV, D) streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ell_spmm_pallas"]


def _make_kernel(op: str):
    def _kernel(idx_ref, x_ref, out_ref):
        idx = idx_ref[...]                    # (BV, D)
        x = x_ref[...]                        # (V+1, BF); row V is neutral
        D = idx.shape[1]

        def body(d, acc):
            rows = jax.lax.dynamic_index_in_dim(idx, d, axis=1, keepdims=False)
            g = x[rows]
            return acc + g if op == "sum" else jnp.maximum(acc, g)

        if op == "sum":
            init = jnp.zeros(out_ref.shape, x.dtype)
        else:
            init = jnp.full(out_ref.shape, -jnp.inf, x.dtype)
        out_ref[...] = jax.lax.fori_loop(0, D, body, init)
    return _kernel


@functools.partial(jax.jit, static_argnames=("op", "block_v", "block_f", "interpret"))
def ell_spmm_pallas(ell_idx: jax.Array, x: jax.Array, *, op: str = "sum",
                    block_v: int = 256, block_f: int = 128,
                    interpret: bool = False) -> jax.Array:
    """ell_idx: (V, D) int32 pad=V; x: (V+1, F) float (row V = neutral elt).

    Returns (V, F) aggregated features.
    """
    V, D = ell_idx.shape
    F = x.shape[1]
    bv = min(block_v, V)
    bf = min(block_f, F)
    grid = (pl.cdiv(V, bv), pl.cdiv(F, bf))
    return pl.pallas_call(
        _make_kernel(op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, D), lambda i, j: (i, 0)),
            pl.BlockSpec((V + 1, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bv, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((V, F), x.dtype),
        interpret=interpret,
    )(ell_idx, x)
