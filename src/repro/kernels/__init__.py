"""Pallas TPU kernels for the engine's compute hot spots.

Each kernel package ships three modules:
  kernel.py -- pl.pallas_call body + BlockSpec tiling (TPU target)
  ops.py    -- jit'd public wrapper with backend switch ("pallas" |
               "interpret" | "jnp"); models/engine call these
  ref.py    -- pure-jnp oracle used for validation and as the jnp backend

Backend selection is centralized in :mod:`repro.kernels.registry`: a typed
:class:`~repro.kernels.registry.KernelBackend` enum, auto-resolution
(``pallas`` on TPU, ``jnp`` elsewhere, ``REPRO_KERNEL_BACKEND`` env
override) and a per-op dispatch table the ops wrappers register into.
``resolve_backend`` raises ``ValueError`` on unknown names — there is no
silent fallback.

This container is CPU-only: tests validate kernel bodies with
interpret=True against ref.py across shape/dtype sweeps; the dry-run
lowers the jnp backend (kernels cannot lower for the CPU backend), and the
BlockSpecs document the VMEM tiling used on real TPU.
"""
from .registry import (KernelBackend, dispatch, register_op,  # noqa: F401
                       registered_ops, resolve_backend)

DEFAULT_BACKEND = KernelBackend.JNP.value

__all__ = ["KernelBackend", "resolve_backend", "register_op", "dispatch",
           "registered_ops", "DEFAULT_BACKEND"]
