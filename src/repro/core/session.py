"""PathSession: one facade over batch and streaming execution.

The session owns a :class:`BatchPathEngine` (and, lazily, a
:class:`~repro.launch.serve.StreamingServer`) so callers deal with exactly
one object and exactly one result type — :class:`QueryResult` — whether
they run a one-shot batch or stream queries through micro-batch admission:

    session = PathSession(graph, EngineConfig(cache_bytes=256 << 20))

    # one-shot batch
    report = session.run([PathQuery(s, t, k), (s2, t2, k2)])
    report[0].paths            # lazy host matrix
    report[1].count            # no matrix transfer

    # streaming (micro-batch admission over the same engine + cache)
    qid = session.submit(PathQuery(s, t, k, output="exists"))
    for qid, result in session.results().items():
        ...                    # the same QueryResult type as session.run

    # graph mutation: full swap (drops all graph-derived state) ...
    session.update_graph(new_graph)
    # ... or incremental edge deltas (CSR merge + hop-scoped cache
    # invalidation; queued to the next micro-batch boundary when streaming)
    session.apply_delta(GraphDelta.from_pairs(add=[(u, v)], remove=[(x, y)]))

The streaming machinery is imported lazily so `repro.core` never depends
on `repro.launch` at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .cache import SharedPathCache
from .engine import BatchPathEngine, EngineConfig
from .graph import Graph
from .query import BatchReport, PathQuery, Planner, QueryLike, QueryResult

__all__ = ["PathSession"]


class PathSession:
    """Unified entry point for HC-s-t path query processing.

    Parameters
    ----------
    graph : the graph to query (or an existing :class:`BatchPathEngine`
        to wrap — its config/cache are reused).
    config : engine configuration (ignored when wrapping an engine).
    planner : default execution strategy for :meth:`run`.
    mesh / n_devices : sharded-execution knobs, overriding the matching
        ``EngineConfig`` fields — a ``jax.sharding.Mesh`` (or a local
        device count) the engine shards its index over and places
        sharing clusters on. A mesh of size 1 is the identity; both are
        ignored when wrapping an existing engine.
    kernel_backend : kernel-dispatch override ("pallas" | "interpret" |
        "jnp"), overriding ``EngineConfig.kernel_backend`` — None defers
        to the engine config / ``REPRO_KERNEL_BACKEND`` env / platform
        auto-detection (see :mod:`repro.kernels.registry`). Ignored when
        wrapping an existing engine.
    trace : record hierarchical stage spans into the process-wide
        :mod:`repro.obs` tracer (``EngineConfig.trace`` override; see
        ``docs/observability.md``). ``session.tracer.export(path)`` writes
        the Chrome-trace JSON. Ignored when wrapping an existing engine;
        None defers to the config.
    n_groups / policy / gamma / warm_bias_eps : streaming-server knobs,
        applied when the first query is submitted. ``policy`` is an
        :class:`~repro.launch.serve.AdmissionPolicy` — including the SLO
        layer (per-query deadlines via ``PathQuery.deadline_s``,
        ``max_queue`` load shedding, ``tenant_weights`` fairness; see
        ``docs/serving.md`` § SLO-aware admission).
    clock : the streaming server's notion of "now" (callable returning
        seconds) — defaults to ``time.monotonic``; pass a
        :class:`~repro.launch.serve.VirtualClock` for open-loop replay.
    """

    def __init__(self, graph: Graph | BatchPathEngine,
                 config: Optional[EngineConfig] = None, *,
                 planner: Planner | str = Planner.BATCH,
                 cache: Optional[SharedPathCache] = None,
                 mesh=None, n_devices: Optional[int] = None,
                 kernel_backend: Optional[str] = None,
                 trace: Optional[bool] = None,
                 n_groups: int = 2, policy=None,
                 gamma: Optional[float] = None,
                 warm_bias_eps: float = 0.08,
                 clock=None):
        if isinstance(graph, BatchPathEngine):
            self.engine = graph
        else:
            if mesh is not None or n_devices is not None:
                config = dataclasses.replace(config or EngineConfig(),
                                             mesh=mesh, n_devices=n_devices)
            if kernel_backend is not None:
                config = dataclasses.replace(config or EngineConfig(),
                                             kernel_backend=kernel_backend)
            if trace is not None:
                config = dataclasses.replace(config or EngineConfig(),
                                             trace=trace)
            self.engine = BatchPathEngine(graph, config, cache=cache)
        self.planner = Planner.coerce(planner)
        self._server = None
        self._server_kw = dict(n_groups=n_groups, policy=policy,
                               gamma=gamma, warm_bias_eps=warm_bias_eps,
                               planner=self.planner, clock=clock)

    # -- one-shot batch ------------------------------------------------
    def run(self, queries: Sequence[QueryLike],
            planner: Optional[Planner | str] = None,
            clusters: Optional[list[list[int]]] = None) -> BatchReport:
        """Execute a batch now; returns a :class:`BatchReport`.

        A one-shot batch is a batch boundary: graph deltas still queued
        behind the streaming server are applied first, so batch and
        streaming consumers of one session never observe different graphs.
        """
        if self._server is not None:
            self._server.flush_deltas()
        return self.engine.run(queries,
                               self.planner if planner is None else planner,
                               clusters)

    # -- streaming -----------------------------------------------------
    @property
    def server(self):
        """The lazily created StreamingServer behind submit/results."""
        if self._server is None:
            from ..launch.serve import StreamingServer
            self._server = StreamingServer(self.engine, **self._server_kw)
        return self._server

    def submit(self, query: QueryLike, now: Optional[float] = None) -> int:
        """Enqueue one query (validated now; see StreamingServer.submit)."""
        return self.server.submit(query, now)

    def pump(self, now: Optional[float] = None) -> bool:
        """Admit every micro-batch the admission policy says is due."""
        return self.server.pump(now)

    def results(self, drain: bool = True) -> dict[int, QueryResult]:
        """Pop every finished query as ``{qid: QueryResult}`` — the same
        result type :meth:`run` reports. ``drain=True`` (default) first
        flushes everything still waiting; ``drain=False`` returns only
        what already finished (a non-blocking poll)."""
        if self._server is None:
            return {}
        if drain:
            self._server.drain()
        return {qid: self._server.take(qid)
                for qid in list(self._server.results)}

    def result(self, qid: int) -> QueryResult:
        """Pop one finished query's result (KeyError if not finished)."""
        return self.server.take(qid)

    @property
    def batch_log(self) -> list[dict]:
        """Per-micro-batch latency/sharing/cache stats (streaming only)."""
        return [] if self._server is None else self._server.batch_log

    # -- graph mutation ------------------------------------------------
    def update_graph(self, graph: Graph) -> None:
        """Swap the graph wholesale: rebuilds device views and invalidates
        every piece of graph-derived state (host dists, cross-batch
        cache). Deltas still queued behind the streaming server are
        discarded — they were expressed against the replaced graph. For
        incremental edge churn prefer :meth:`apply_delta`."""
        if self._server is not None:
            self._server.discard_pending_deltas()
        self.engine.set_graph(graph)

    def apply_delta(self, delta) -> Optional[dict]:
        """Apply a :class:`~repro.core.delta.GraphDelta` incrementally.

        Batch mode (no streaming server yet): applied immediately via
        ``BatchPathEngine.apply_delta`` — CSR merge, patched device views,
        hop-scoped cache invalidation — and the application report is
        returned. Streaming mode: the delta is queued and applied at the
        next micro-batch boundary so in-flight admission always sees a
        consistent graph snapshot; returns None (the report lands in
        ``batch_log`` / ``server.delta_log``).
        """
        if self._server is not None:
            self._server.apply_delta(delta)
            return None
        return self.engine.apply_delta(delta)

    @property
    def cache(self) -> Optional[SharedPathCache]:
        return self.engine.cache

    @property
    def kernel_backend(self) -> str:
        """The engine's resolved kernel backend ("pallas"|"interpret"|"jnp")."""
        return self.engine.kernel_backend.value

    @property
    def tracer(self):
        """The engine's span tracer (:class:`repro.obs.trace.Tracer`) —
        recording only when the session/engine was built with tracing on.
        ``session.tracer.export(path)`` writes Chrome-trace JSON."""
        return self.engine.obs
