"""Cost-routed adaptive planning (core.planner + Planner.AUTO).

Covers: the cost model's routing decisions, AUTO-vs-forced result parity
(routing may only move wall time), GREEN direct-sweep oracle exactness
across outputs/limits/delta churn, the serving-path bugfixes (admission
deadline starvation, warm-bias re-coercion, host-dist re-transfers) and
the streaming fast path.
"""
import time

import numpy as np
import pytest

from repro.core import (BatchPathEngine, EngineConfig, GraphDelta,
                        PathQuery, Planner, RouterConfig, build_index,
                        generators)
from repro.core.distributed import cluster_costs
from repro.core.graph import DeviceGraph
from repro.core.oracle import enumerate_paths_bruteforce, path_set
from repro.core.planner import CostRouter, Route, admission_fast_path
from repro.core.query import Output
from repro.launch.serve import (AdmissionPolicy, StreamingServer,
                                warm_cluster_bias)
from repro.obs import metrics as obsmetrics


GREEN_ALL = RouterConfig(green_max_cost=float("inf"))
YELLOW_ALL = RouterConfig(green_max_cost=-1.0)


def _graph():
    return generators.community(300, n_comm=3, avg_deg=5.0, seed=0)


def _mixed_queries(g, n_paths=8):
    qs = [PathQuery.coerce(q) for q in
          generators.similar_queries(g, n_paths, 0.6, (3, 4), seed=1)]
    qs.append(PathQuery(qs[0].s, qs[0].t, 3, output="exists"))
    qs.append(PathQuery(qs[1].s, qs[1].t, 3, output="count", limit=2))
    qs.append(PathQuery(qs[2].s, qs[2].t, 4, output="count"))
    return qs


def _assert_same_results(ra, rb, queries):
    for qi, q in enumerate(queries):
        if q.output is Output.PATHS and q.limit is None:
            assert set(map(tuple, ra[qi].paths)) \
                == set(map(tuple, rb[qi].paths)), qi
        elif q.output is Output.COUNT:
            assert ra[qi].count == rb[qi].count, qi
        assert ra[qi].exists == rb[qi].exists, qi


# ----------------------------------------------------------------------
# cost model / routing decisions
# ----------------------------------------------------------------------

def test_estimates_weight_outputs_and_limits():
    g = _graph()
    s, t = 0, 1
    d = None
    # find a reachable pair with some hop slack
    from repro.core.oracle import bfs_dist_from
    d = bfs_dist_from(g, 0, 6)
    ts = np.flatnonzero((d >= 1) & (d <= 3))
    t = int(ts[0])
    qs = [PathQuery(s, t, 4),                                   # paths
          PathQuery(s, t, 4, output="count"),                   # count
          PathQuery(s, t, 4, output="exists"),                  # exists
          PathQuery(s, t, 4, limit=1)]                          # tiny limit
    dg = DeviceGraph.build(g)
    index = build_index(dg, [q.key for q in qs])
    dists = (np.asarray(index.dist_s), np.asarray(index.dist_t))
    ests = CostRouter().estimate(index, qs, dists)
    paths_e, count_e, exists_e, lim_e = ests
    assert all(e.reachable for e in ests)
    assert paths_e.raw_cost == count_e.raw_cost == exists_e.raw_cost > 0
    # exists is free (the index already holds the answer)
    assert exists_e.cost == 0.0 and exists_e.route is Route.GREEN
    # count weighs below full paths; a limit caps below both
    assert count_e.cost == pytest.approx(paths_e.cost * 0.5)
    assert lim_e.cost <= paths_e.cost


def test_unreachable_routes_green_regardless_of_output():
    g = _graph()
    # s == t is rejected at validation; build an unreachable pair by
    # giving the query less hop budget than the true distance
    from repro.core.oracle import bfs_dist_from
    d = bfs_dist_from(g, 0, 6)
    far = np.flatnonzero(d >= 3)
    assert far.size, "graph too dense for the fixture"
    t = int(far[0])
    qs = [PathQuery(0, t, 2), PathQuery(0, t, 2, output="count")]
    dg = DeviceGraph.build(g)
    index = build_index(dg, [q.key for q in qs])
    dists = (np.asarray(index.dist_s), np.asarray(index.dist_t))
    for e in CostRouter().estimate(index, qs, dists):
        assert not e.reachable
        assert e.cost == 0.0 and e.route is Route.GREEN


def test_cost_monotone_in_hop_budget():
    g = _graph()
    from repro.core.oracle import bfs_dist_from
    d = bfs_dist_from(g, 0, 6)
    t = int(np.flatnonzero((d >= 1) & (d <= 2))[0])
    qs = [PathQuery(0, t, 2), PathQuery(0, t, 5)]
    dg = DeviceGraph.build(g)
    index = build_index(dg, [q.key for q in qs])
    dists = (np.asarray(index.dist_s), np.asarray(index.dist_t))
    small, big = CostRouter().estimate(index, qs, dists)
    assert big.raw_cost >= small.raw_cost > 0


def test_cluster_planner_choice():
    router = CostRouter()
    assert router.cluster_planner([0, 1], {}, has_cache=False) == "batch"
    assert router.cluster_planner([0], {}, has_cache=True) == "batch"
    assert router.cluster_planner([0], {}, has_cache=False) == "basic"


# ----------------------------------------------------------------------
# AUTO parity + GREEN exactness
# ----------------------------------------------------------------------

def test_auto_matches_forced_planners_mixed_outputs():
    g = _graph()
    qs = _mixed_queries(g)
    # a mid threshold so the batch genuinely mixes GREEN and YELLOW
    eng = BatchPathEngine(g, EngineConfig(
        min_cap=128, router=RouterConfig(green_max_cost=150.0)))
    ra = eng.run(qs, planner=Planner.AUTO)
    rb = eng.run(qs, planner=Planner.BATCH)
    rc = eng.run(qs, planner="basic")
    _assert_same_results(ra, rb, qs)
    _assert_same_results(ra, rc, qs)
    # routing metadata: one route per query, counters sum to Q
    assert ra.routes is not None and len(ra.routes) == len(qs)
    assert set(ra.routes) <= {"green", "yellow", "red"}
    assert (ra.stats["routed_green"] + ra.stats["routed_yellow"]
            + ra.stats["routed_red"]) == len(qs)
    assert ra.stats["routed_green"] > 0
    # forced planners make no routing decision
    assert rb.routes is None and rc.routes is None


def test_all_yellow_auto_equals_batch():
    g = _graph()
    qs = _mixed_queries(g, n_paths=6)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, router=YELLOW_ALL))
    ra = eng.run(qs, planner="auto")
    rb = eng.run(qs, planner="batch")
    assert all(r in ("yellow", "red") for r in ra.routes)
    assert ra.stats["routed_green"] == 0
    _assert_same_results(ra, rb, qs)


def test_green_direct_sweep_matches_oracle():
    g = _graph()
    qs = _mixed_queries(g)
    qs.append(PathQuery(qs[0].s, qs[0].t, 4, limit=3))   # limited paths
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, router=GREEN_ALL))
    r = eng.run(qs, planner="auto")
    assert all(route == "green" for route in r.routes)
    for qi, q in enumerate(qs):
        truth = path_set(enumerate_paths_bruteforce(g, q.s, q.t, q.k))
        if q.output is Output.PATHS:
            rows = [tuple(int(x) for x in row if x >= 0)
                    for row in r[qi].paths]
            assert len(rows) == len(set(rows)), f"q{qi}: duplicate paths"
            if q.limit is None:
                assert set(rows) == truth, qi
            else:
                assert set(rows) <= truth, qi
                assert len(rows) == min(q.limit, len(truth)), qi
        elif q.output is Output.COUNT:
            want = len(truth) if q.limit is None else min(q.limit, len(truth))
            assert r[qi].count == want, qi
        assert r[qi].exists == (len(truth) > 0), qi


def test_green_unreachable_shapes_match_forced():
    g = _graph()
    from repro.core.oracle import bfs_dist_from
    d = bfs_dist_from(g, 0, 6)
    t = int(np.flatnonzero(d >= 3)[0])
    qs = [PathQuery(0, t, 2), PathQuery(0, t, 2, output="count"),
          PathQuery(0, t, 2, output="exists")]
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, router=GREEN_ALL))
    ra = eng.run(qs, planner="auto")
    rb = eng.run(qs, planner="batch")
    assert ra.routes == ("green",) * 3
    assert ra[0].paths.shape == rb[0].paths.shape == (0, 3)
    assert ra[1].count == 0 and not ra[2].exists
    _assert_same_results(ra, rb, qs)


def test_green_exact_under_delta_churn():
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, router=GREEN_ALL))
    rng = np.random.default_rng(7)
    qs = _mixed_queries(g, n_paths=4)
    for _ in range(3):
        a_s = rng.integers(0, g.n, 5)
        a_d = rng.integers(0, g.n, 5)
        d_s = rng.integers(0, g.n, 5)
        d_d = rng.integers(0, g.n, 5)
        eng.apply_delta(GraphDelta(a_s, a_d, d_s, d_d))
        r = eng.run(qs, planner="auto")
        for qi, q in enumerate(qs):
            truth = path_set(
                enumerate_paths_bruteforce(eng.g, q.s, q.t, q.k))
            if q.output is Output.PATHS and q.limit is None:
                assert path_set(r[qi].paths) == truth, qi
            elif q.output is Output.COUNT:
                want = len(truth) if q.limit is None \
                    else min(q.limit, len(truth))
                assert r[qi].count == want, qi
            assert r[qi].exists == (len(truth) > 0), qi


def test_precomputed_clusters_with_auto():
    """AUTO must honor a caller's clustering for the non-GREEN remainder
    (GREEN members are answered first and filtered out of the groups)."""
    g = _graph()
    qs = _mixed_queries(g, n_paths=6)
    clusters = [list(range(0, 4)), list(range(4, len(qs)))]
    eng = BatchPathEngine(g, EngineConfig(
        min_cap=128, router=RouterConfig(green_max_cost=150.0)))
    ra = eng.run(qs, planner="auto", clusters=clusters)
    rb = eng.run(qs, planner="batch", clusters=clusters)
    _assert_same_results(ra, rb, qs)


# ----------------------------------------------------------------------
# satellite bugfixes
# ----------------------------------------------------------------------

def test_admission_deadline_overrides_min_batch():
    """A lone query older than max_delay_s must be admitted by pump(),
    not starve until drain() (the deadline overrides min_batch)."""
    pol = AdmissionPolicy(max_batch=32, max_delay_s=0.05, min_batch=4)
    # unit: below min_batch but past deadline -> due
    assert pol.due(1, 0.06)
    assert not pol.due(1, 0.01)      # below min_batch, within deadline
    assert not pol.due(0, 99.0)      # nothing waiting is never due
    assert pol.due(32, 0.0)          # max_batch fires regardless
    assert not pol.due(4, 0.01)      # min_batch met but neither trigger

    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    srv = StreamingServer(eng, policy=pol)
    qs = _mixed_queries(g, n_paths=4)
    qid = srv.submit(qs[0], now=0.0)
    assert not srv.pump(now=0.01)            # neither trigger yet
    assert srv.pump(now=0.06)                # deadline override fires
    assert qid in srv.results
    assert srv.batch_log[-1]["n_queries"] == 1


def test_warm_cluster_bias_skips_coerced_inputs(monkeypatch):
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, cache_bytes=1 << 20))
    qs = [PathQuery.coerce(q) for q in
          generators.similar_queries(g, 4, 0.6, (3, 4), seed=1)]
    calls = {"n": 0}
    orig = PathQuery.coerce.__func__

    def counting(cls, q):
        calls["n"] += 1
        return orig(cls, q)

    monkeypatch.setattr(PathQuery, "coerce", classmethod(counting))
    warm_cluster_bias(eng, qs)               # already PathQuery: no coercion
    assert calls["n"] == 0
    warm_cluster_bias(eng, [q.key for q in qs])   # legacy tuples: coerced
    assert calls["n"] == len(qs)


def test_cluster_costs_transfer_counter():
    """The dists=None fallback is the only site that re-transfers the
    distance matrices; hot paths threading the engine memo stay at zero."""
    g = _graph()
    qs = _mixed_queries(g, n_paths=4)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    reg = obsmetrics.registry()
    ctr = reg.counter("host_dist_transfers_total", site="cluster_costs")

    dg = DeviceGraph.build(g)
    index = build_index(dg, [q.key for q in qs])
    before = ctr.value
    cluster_costs(index, [[0], [1]])                   # fallback: transfers
    assert ctr.value == before + 1
    dists = (np.asarray(index.dist_s), np.asarray(index.dist_t))
    cluster_costs(index, [[0], [1]], dists=dists)      # memo: no transfer
    assert ctr.value == before + 1
    # a full AUTO run threads the engine memo everywhere
    before = ctr.value
    eng.run(qs, planner="auto")
    assert ctr.value == before


# ----------------------------------------------------------------------
# streaming fast path
# ----------------------------------------------------------------------

def test_streaming_fast_path_answers_exists_at_submit():
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    srv = StreamingServer(eng, planner="auto",
                          policy=AdmissionPolicy(min_batch=8, max_batch=32,
                                                 max_delay_s=10.0))
    q = _mixed_queries(g, n_paths=1)[0]
    truth = path_set(enumerate_paths_bruteforce(g, q.s, q.t, q.k))
    qid = srv.submit(PathQuery(q.s, q.t, q.k, output="exists"))
    # answered at submit: no pump, no waiting entry
    assert qid in srv.results and srv.n_fast_path == 1
    assert not srv._waiting
    assert srv.results[qid].exists == (len(truth) > 0)
    assert admission_fast_path(PathQuery(q.s, q.t, q.k, output="exists"))
    assert not admission_fast_path(PathQuery(q.s, q.t, q.k))


def test_streaming_fast_path_off_for_forced_planners():
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    srv = StreamingServer(eng)       # default planner=BATCH
    q = _mixed_queries(g, n_paths=1)[0]
    qid = srv.submit(PathQuery(q.s, q.t, q.k, output="exists"))
    assert qid not in srv.results and srv.n_fast_path == 0
    srv.drain()
    assert qid in srv.results
    assert srv.batch_log[-1]["routed_green"] == 0     # BATCH routes nothing


def test_streaming_auto_batches_carry_routes():
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    srv = StreamingServer(eng, planner="auto")
    for q in _mixed_queries(g, n_paths=4):
        srv.submit(q)
    srv.drain()
    routed = sum(srv.batch_log[-1][f"routed_{r}"]
                 for r in ("green", "yellow", "red"))
    assert routed == srv.batch_log[-1]["n_queries"]


def test_deadline_bound_wait_under_auto():
    """With the starvation fix, worst-case admission wait is bounded by
    max_delay_s + one pump interval even for a lone sub-min_batch query."""
    g = _graph()
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    srv = StreamingServer(eng, planner="auto",
                          policy=AdmissionPolicy(min_batch=8, max_batch=32,
                                                 max_delay_s=0.05))
    q = _mixed_queries(g, n_paths=1)[0]
    srv.submit(q)
    pump_interval = 0.02
    deadline = time.monotonic() + 5.0
    while not srv.batch_log and time.monotonic() < deadline:
        srv.pump()
        time.sleep(pump_interval)
    assert srv.batch_log, "lone query starved past the deadline"
    assert srv.batch_log[-1]["admission_wait_max_s"] \
        <= 0.05 + pump_interval + 0.25   # generous scheduling slack
