"""End-to-end framework driver (deliverable b): fault-tolerant training of a
reduced LM with checkpoint/restart, then streaming path-query serving
through the PathSession facade.

    pip install -e .            # once (or: export PYTHONPATH=src)
    python examples/train_and_serve.py
"""
import tempfile

from repro.launch.train import run_training
from repro.core import PathSession, EngineConfig, generators

# --- 1. train a reduced granite-8b for a few hundred steps, with a crash
with tempfile.TemporaryDirectory() as ckpt:
    print("== training (with injected failure at step 60 + auto-resume) ==")
    try:
        run_training("granite-8b", "train_4k", steps=120, ckpt_dir=ckpt,
                     reduced=True, overrides={"seq_len": 64, "global_batch": 8},
                     fail_at=60, ckpt_every=25)
    except RuntimeError as e:
        print(f"  crash: {e} -> restarting from latest checkpoint")
    out = run_training("granite-8b", "train_4k", steps=120, ckpt_dir=ckpt,
                       reduced=True,
                       overrides={"seq_len": 64, "global_batch": 8},
                       ckpt_every=25)
    h = out["history"]
    print(f"  resumed at step {h[0]['step']}; "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")

# --- 2. stream a batch of path queries through the session facade
print("== serving ==")
g = generators.community(10_000, n_comm=4, avg_deg=6.0, seed=0)
session = PathSession(g, EngineConfig(), n_groups=2)
queries = generators.similar_queries(g, 32, similarity=0.6, k_range=(4, 5),
                                     seed=1)
qids = [session.submit(q) for q in queries]
results = session.results()          # drains the admission queue
info = session.batch_log[-1]
print(f"  {len(queries)} queries -> "
      f"{sum(results[qid].count for qid in qids)} paths "
      f"in {info['wall_s']:.2f}s; {info['steals']} steals")
