"""Typed query/result contract: PathQuery in, QueryResult out.

The paper is query-centric — HC-s-t path queries whose shared HC-s path
computation the engine exploits — and this module makes that contract
first-class instead of bare ``(s, t, k)`` tuples and stringly-typed modes:

  * ``PathQuery``   -- (s, t, k) plus a per-query ``output`` kind
                       (paths | count | exists) and an optional ``limit``;
                       coerces from legacy tuples and validates eagerly.
  * ``Planner``     -- the execution strategy enum replacing the
                       'basic' | 'basic+' | 'batch' | 'batch+' | 'pathenum'
                       mode strings.
  * ``QueryResult`` -- per-query answer with *lazy* host transfer:
                       ``.count`` / ``.exists`` answer from the device
                       scalar; ``.paths`` materializes the matrix on demand.
  * ``BatchReport`` -- the aggregate the engine returns (one QueryResult
                       per query, ordered like the input, plus run stats).

count-only and exists-only queries are not a presentation veneer: the
engine skips the ⊕-join path materialization for them entirely (see
``join.keyed_join_count``) and early-terminates exists/limited queries.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Optional, Sequence, Union

import numpy as np

from .pathset import PathSet
from ..obs import trace as obstrace

__all__ = ["Output", "Planner", "PathQuery", "QueryResult", "BatchReport",
           "PathsStore", "QueryLike", "ResultStatus", "midpoint_split"]


def midpoint_split(k: int) -> tuple[int, int]:
    """Default forward/backward hop split of a k-hop query: ``a = (k+1)//2``
    forward hops on G, ``b = k - a`` backward hops on G_r.

    The single source of truth for the split — the engine's cluster
    splitter and the cache-key builder (``cache.dedicated_keys``) both call
    this, so the cache's notion of a singleton query's half-keys can never
    drift from what the engine actually enumerates. The cost-based "+"
    planners may override the split per query; keys derived from this
    helper only describe the default.
    """
    a = (k + 1) // 2
    return a, k - a


class Output(enum.Enum):
    """What a query wants back: full paths, an exact count, or existence."""

    PATHS = "paths"
    COUNT = "count"
    EXISTS = "exists"

    @classmethod
    def coerce(cls, value: Union["Output", str]) -> "Output":
        if isinstance(value, cls):
            return value
        try:
            return cls(str(value).lower())
        except ValueError:
            raise ValueError(
                f"unknown output kind {value!r}; expected one of "
                f"{[o.value for o in cls]}") from None


class Planner(enum.Enum):
    """Execution strategy (replaces the legacy ``mode`` strings)."""

    BASIC = "basic"            # Alg 1: shared index, per-query enumeration
    BASIC_PLUS = "basic+"      # ... with cost-based fwd/bwd split
    BATCH = "batch"            # Alg 4: cluster -> detect -> shared enumeration
    BATCH_PLUS = "batch+"      # ... with cost-based fwd/bwd split
    PATHENUM = "pathenum"      # per-query index + enumeration (baseline)
    AUTO = "auto"              # cost-routed: GREEN/YELLOW/RED per query +
    #                            per-cluster basic/batch (core/planner.py)

    @classmethod
    def coerce(cls, value: Union["Planner", str]) -> "Planner":
        if isinstance(value, cls):
            return value
        try:
            return cls(str(value))
        except ValueError:
            raise ValueError(
                f"unknown planner {value!r}; expected one of "
                f"{[p.value for p in cls]}") from None

    @property
    def plus(self) -> bool:
        return self.value.endswith("+")

    @property
    def batched(self) -> bool:
        return self.value.startswith("batch")


@dataclasses.dataclass(frozen=True)
class PathQuery:
    """One hop-constrained s-t simple path query.

    ``output`` selects what the engine must produce; ``limit`` caps the
    number of paths (output=paths) or the counted total (output=count) —
    either way the engine stops working once the cap is reached.
    Iterating a PathQuery yields ``(s, t, k)``, so legacy unpacking code
    keeps working.

    ``deadline_s`` and ``tenant`` are the serving-side SLO contract
    (ignored by one-shot batch runs): ``deadline_s`` is the per-query
    latency budget in seconds *from submission* — the streaming admission
    loop admits a micro-batch early when the oldest waiter's slack is
    spent, sheds queries whose deadline already passed, and counts misses
    in ``serve_deadline_miss_total``. ``tenant`` names the submitting
    tenant for weighted-fair admission ordering and per-tenant wait
    histograms (see ``docs/serving.md`` § SLO-aware admission).
    """

    s: int
    t: int
    k: int
    limit: Optional[int] = None
    output: Output = Output.PATHS
    deadline_s: Optional[float] = None
    tenant: str = "default"

    def __post_init__(self):
        object.__setattr__(self, "s", int(self.s))
        object.__setattr__(self, "t", int(self.t))
        object.__setattr__(self, "k", int(self.k))
        object.__setattr__(self, "output", Output.coerce(self.output))
        if self.limit is not None:
            object.__setattr__(self, "limit", int(self.limit))
        if self.deadline_s is not None:
            object.__setattr__(self, "deadline_s", float(self.deadline_s))
        object.__setattr__(self, "tenant", str(self.tenant))
        if self.s < 0 or self.t < 0:
            raise ValueError("vertex ids must be >= 0")
        if self.s == self.t:
            raise ValueError("s == t queries are cycles, not s-t paths")
        if self.k < 1:
            raise ValueError("hop constraint must be >= 1")
        if self.limit is not None and self.limit < 1:
            raise ValueError("limit must be >= 1 (or None for unlimited)")
        if self.output is Output.EXISTS and self.limit is not None:
            raise ValueError("limit is meaningless for exists-only queries")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None for no SLO)")

    @classmethod
    def coerce(cls, query: "QueryLike") -> "PathQuery":
        """Accept a PathQuery or any legacy ``(s, t, k)`` triple."""
        if isinstance(query, cls):
            return query
        try:
            s, t, k = query
        except (TypeError, ValueError):
            raise ValueError(
                f"cannot coerce {query!r} to PathQuery; expected a "
                f"PathQuery or an (s, t, k) triple") from None
        return cls(int(s), int(t), int(k))

    def check_bounds(self, n: int) -> "PathQuery":
        """Validate the endpoints against a graph of ``n`` vertices (the
        one check that needs a graph, shared by engine and server)."""
        if self.s >= n or self.t >= n:
            raise ValueError(f"query {self.key} references vertices "
                             f"outside the graph (n={n})")
        return self

    @property
    def key(self) -> tuple[int, int, int]:
        """The legacy ``(s, t, k)`` triple (index/cache key form)."""
        return (self.s, self.t, self.k)

    def __iter__(self) -> Iterator[int]:
        return iter((self.s, self.t, self.k))


QueryLike = Union[PathQuery, tuple[int, int, int], Sequence[int]]


class ResultStatus(enum.Enum):
    """Terminal outcome of one query: answered, or shed by admission.

    A ``SHED`` result is a first-class answer, not an exception path — a
    continuous server must be able to refuse work under pressure without
    tearing down the stream, and the caller must be able to tell "no
    paths" from "not attempted". Shed results carry no data: ``.paths`` /
    ``.count`` / ``.exists`` raise, ``.shed_reason`` says why
    (``"overload"`` | ``"deadline"``).
    """

    OK = "ok"
    SHED = "shed"


class PathsStore:
    """Device -> host materialization cache for one assembled result.

    Duplicate queries in a batch alias one store, so the host matrix is
    transferred exactly once no matter how many QueryResults share it;
    materializing also releases the (padded, capacity-bucketed) device
    buffer, which is typically much larger than the valid rows.
    """

    __slots__ = ("_pathset", "_host", "_count")

    def __init__(self, pathset: PathSet):
        self._pathset = pathset
        self._host: Optional[np.ndarray] = None
        self._count: Optional[int] = None

    @property
    def count(self) -> int:
        if self._count is None:
            self._count = int(self._pathset.count)
        return self._count

    @property
    def host(self) -> np.ndarray:
        if self._host is None:
            with obstrace.span("transfer.paths", rows=self.count):
                self._host = np.asarray(self._pathset.verts[:self.count])
            self._pathset = None   # release the padded device buffer
        return self._host

    @property
    def materialized(self) -> bool:
        return self._host is not None


@dataclasses.dataclass(repr=False)
class QueryResult:
    """Answer to one PathQuery, with lazy host transfer.

    For output=paths the assembled result stays a device ``PathSet``
    (behind a shared :class:`PathsStore`); ``.count`` / ``.exists`` read
    only its count scalar, and ``.paths`` pulls (and caches) the
    ``(n_paths, k+1)`` int32 matrix on first access. For output=count /
    output=exists no path matrix exists at all — the engine never
    assembled one — and ``.paths`` raises.
    """

    query: PathQuery
    # wall time attributable to this query ALONE: full per-query index +
    # enumeration under basic/pathenum planners, but only the final ⊕
    # assembly under batch planners (shared enumeration/clustering lives
    # in BatchReport.stats, and a deduplicated query reports ~0)
    time_s: float = 0.0
    _store: Optional[PathsStore] = None
    _count: Optional[int] = None
    _exists: Optional[bool] = None
    status: ResultStatus = ResultStatus.OK
    shed_reason: Optional[str] = None   # "overload" | "deadline" when SHED

    @classmethod
    def shed(cls, query: PathQuery, reason: str) -> "QueryResult":
        """A typed rejection: admission refused this query (see
        :class:`ResultStatus`). Accessors raise; ``.ok`` is False."""
        return cls(query=query, status=ResultStatus.SHED,
                   shed_reason=reason)

    @property
    def ok(self) -> bool:
        """False when admission shed the query instead of answering it."""
        return self.status is ResultStatus.OK

    def _check_shed(self) -> None:
        if self.status is ResultStatus.SHED:
            raise ValueError(
                f"query {self.query.key} was shed by admission "
                f"(reason: {self.shed_reason}); no result was computed — "
                f"check .status before reading data")

    @property
    def paths(self) -> np.ndarray:
        """(n_paths, k+1) int32 matrix (pad -1); materialized on demand."""
        self._check_shed()
        if self._store is None:
            raise ValueError(
                f"{self.query.output.value}-only query assembled no "
                f"paths; ask for output=paths")
        return self._store.host

    @property
    def count(self) -> int:
        """Number of result paths — no host matrix transfer needed."""
        self._check_shed()
        if self._count is None:
            if self._store is None:
                raise ValueError(
                    "exists-only query early-terminated without a count; "
                    "ask for output=count")
            self._count = self._store.count
        return self._count

    @property
    def exists(self) -> bool:
        """Whether at least one HC-s-t simple path exists."""
        self._check_shed()
        if self._exists is None:
            self._exists = self.count > 0
        return self._exists

    def offload(self) -> "QueryResult":
        """Materialize the host matrix now and release the device buffer.

        Long-lived results — e.g. a streaming backlog awaiting ``take()``
        — must not pin padded device PathSets; count/exists results hold
        no buffer and are unaffected. Returns self for chaining.
        """
        if self._store is not None:
            self._store.host
        return self

    def __repr__(self) -> str:  # never forces a host matrix transfer
        q = self.query
        if self.status is ResultStatus.SHED:
            return (f"QueryResult({q.s}->{q.t}, k={q.k}, SHED "
                    f"({self.shed_reason}))")
        if self._count is None and self._store is None:
            what = f"exists={self._exists}"
            mat = ""
        else:
            n = self._count if self._count is not None else self._store.count
            what = f"count={n}"
            mat = (", materialized"
                   if self._store is not None and self._store.materialized
                   else "")
        return (f"QueryResult({q.s}->{q.t}, k={q.k}, {q.output.value}, "
                f"{what}{mat})")


@dataclasses.dataclass
class BatchReport:
    """Aggregate result of one engine run: per-query QueryResults + stats.

    Indexable by query position (``report[qi]``), iterable in input order.
    ``routes`` is the per-query tier chosen under ``Planner.AUTO``
    (``"green"`` | ``"yellow"`` | ``"red"``, input order); ``None`` for
    forced planners, where no routing decision was made.
    """

    queries: tuple[PathQuery, ...]
    results: tuple[QueryResult, ...]
    stats: dict
    routes: Optional[tuple[str, ...]] = None

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self) -> Iterator[QueryResult]:
        return iter(self.results)

    def __getitem__(self, qi: int) -> QueryResult:
        return self.results[qi]

    @property
    def paths(self) -> dict[int, np.ndarray]:
        """Legacy-shaped view: query idx -> host path matrix (materializes
        every result; raises if any query was count-/exists-only)."""
        return {qi: r.paths for qi, r in enumerate(self.results)}
