"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler watchdog.

Restart contract: state = (params, opt_state, step). Data is a pure
function of step (data/ pipelines), so resume is bit-exact: kill the
process at any step, relaunch, and the loss trajectory continues as if
uninterrupted (tests/test_ft.py validates equality).

At real scale each host runs this driver under a cluster agent; a node
failure surfaces as a collective error -> the agent relaunches survivors +
replacements and everyone restores from the last published step (the
checkpoint format reshapes elastically to the new device count, see
checkpoint.py). The straggler watchdog flags slow steps; its log feeds the
scheduler's work-stealing for the serving engine (ft/scheduler.py) and
SLO reporting for training.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax

from ..checkpoint import CheckpointManager

__all__ = ["DriverConfig", "TrainDriver", "FailureInjector"]


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True
    straggler_factor: float = 3.0   # step slower than factor x median -> flag
    log_every: int = 10


class FailureInjector:
    """Deterministic crash for FT tests: raises at a chosen step."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


class TrainDriver:
    def __init__(self, cfg: DriverConfig, step_fn: Callable,
                 init_state: Callable[[], tuple],
                 batch_fn: Callable[[int], tuple],
                 injector: Optional[FailureInjector] = None):
        """step_fn(params, opt_state, *batch) -> (params, opt_state, metrics);
        init_state() -> (params, opt_state); batch_fn(step) -> batch tuple."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.batch_fn = batch_fn
        self.injector = injector or FailureInjector()
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                     async_save=cfg.async_save)
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.history: list[dict] = []
        self._stop = False

    def _install_signals(self) -> None:
        def handler(signum, frame):
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self) -> dict:
        self._install_signals()
        params, opt_state = self.init_state()
        start = 0
        last = self.mgr.latest_step()
        if last is not None:
            (params, opt_state), start, extra = self.mgr.restore(
                (params, opt_state))
            start += 1
        t_wall = time.perf_counter()
        for step in range(start, self.cfg.total_steps):
            self.injector.maybe_fail(step)
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      *batch)
            jax.tree.leaves(metrics)[0].block_until_ready()
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            med = sorted(self.step_times)[len(self.step_times) // 2]
            if len(self.step_times) > 5 and dt > self.cfg.straggler_factor * med:
                self.stragglers.append(step)
            self.history.append({"step": step,
                                 **{k: float(v) for k, v in metrics.items()}})
            if (step + 1) % self.cfg.ckpt_every == 0 or self._stop \
                    or step + 1 == self.cfg.total_steps:
                self.mgr.save(step, (params, opt_state),
                              extra={"wall": time.perf_counter() - t_wall})
            if self._stop:
                break
        self.mgr.wait()
        return {"params": params, "opt_state": opt_state,
                "history": self.history, "stragglers": self.stragglers}
