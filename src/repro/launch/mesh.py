"""Production meshes. Functions only — importing never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_cells_mesh",
           "mesh_by_name", "use_mesh"]


def _axis_types_kw(n_axes: int) -> dict:
    # jax >= 0.6 wants explicit axis types; older jax has no such kwarg
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def use_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh:
    ``jax.set_mesh`` on modern jax, the Mesh context manager on older."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices (CPU tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kw(2))


def make_cells_mesh(n_devices: int = 0):
    """1-D "cells" mesh over the first N local devices — the layout the
    sharded path engine expects (edge lists shard over "cells", sharing
    clusters place on the flattened device list; see core.distributed).
    ``n_devices=0`` takes every visible device. Works on both the classic
    ``jax.sharding.Mesh`` constructor and the modern ``jax.make_mesh``
    API (old jax has no make_mesh / axis_types)."""
    import numpy as np

    devs = jax.devices()
    n = len(devs) if not n_devices else int(n_devices)
    if n > len(devs):
        raise ValueError(f"asked for {n} devices, have {len(devs)}")
    if hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh((n,), ("cells",), **_axis_types_kw(1))
        except TypeError:   # older make_mesh without axis_types support
            pass
    return jax.sharding.Mesh(np.array(devs[:n]), ("cells",))


def mesh_by_name(name: str):
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "host":
        return make_host_mesh()
    raise KeyError(name)
