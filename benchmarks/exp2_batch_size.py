"""Exp-2 (Fig 8): processing time vs batch size |Q|.

Paper claim: BatchEnum(+) outperforms the baselines at every |Q| and the
gap widens with |Q| (more sharing opportunities in bigger batches).
"""
from __future__ import annotations

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, record, time_planner


def main(scale: float = 1.0) -> list[dict]:
    g = default_graph(scale, seed=1)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    rows = []
    for nq in [10, 20, 40, 80]:
        qs = generators.similar_queries(g, nq, similarity=0.6,
                                        k_range=(5, 5), seed=nq)
        t_basic, _ = time_planner(eng, qs, "basic")
        t_batch, sb = time_planner(eng, qs, "batch")
        rows.append(dict(n_queries=nq, t_basic=t_basic, t_batch=t_batch,
                         speedup=t_basic / t_batch))
        record(f"exp2_q{nq}_basic", t_basic * 1e6, "")
        record(f"exp2_q{nq}_batch", t_batch * 1e6,
               f"speedup={t_basic / t_batch:.2f}")
    return rows


if __name__ == "__main__":
    main()
