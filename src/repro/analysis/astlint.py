"""Layer 1: repo-specific AST lint over ``src/repro/``.

Implements the RPL rules from :mod:`repro.analysis.rules`. Scope:

* RPL001/RPL004 apply inside *jit-reachable* code of the hot modules
  (``rules.HOT_MODULE_PATTERNS``): under ``kernels/`` every function is
  hot (ref/kernel bodies always execute inside a trace); in the core
  modules reachability is computed as the transitive closure of
  same-module calls from functions carrying a ``jax.jit`` decorator
  (``@jax.jit``, ``@partial(jax.jit, ...)``) or wrapped via
  ``name = jax.jit(fn)``.
* RPL002 applies everywhere: importing a kernel arm module
  (``kernels.<op>.ref`` / ``.kernel``) from outside its own package, or
  calling a ``*_ref``/``*_pallas`` symbol outside ``ref.py``/
  ``kernel.py`` and outside a ``register_op(...)`` registration call.
* RPL003 applies to every jitted function: params named in
  ``rules.STATIC_SHAPE_PARAMS`` must be listed in ``static_argnames``.
* RPL005 applies everywhere except ``core/graph.py`` (the blessed
  definition site of ``pow2_ceil``/``pad_edge_list``).
* RPL006 applies in the timed modules (``rules.TIMED_MODULE_PATTERNS``,
  i.e. the hot modules plus the host-side engine/serving layer):
  calling ``time.perf_counter()`` directly instead of taking stage
  walls from ``repro.obs.trace`` spans. ``obs/`` itself is exempt.

Waivers (``# repro-lint: waive[RULE] reason``) are honoured on the
finding's line or the line directly above.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .report import AnalysisReport, Finding
from .rules import (STATIC_SHAPE_PARAMS, is_hot_module, is_timed_module,
                    parse_waivers)

__all__ = ["lint_source", "lint_tree"]

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"partial", "functools.partial"}
_HOST_SYNC_CALLS = {
    "jax.device_get", "device_get",
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "onp.asarray", "onp.array",
}
_CAST_NAMES = {"int", "float", "bool"}
_PERF_COUNTER_CALLS = {
    "time.perf_counter", "perf_counter",
    "time.perf_counter_ns", "perf_counter_ns",
}
_DEVICE_PRODUCERS = ("jnp.", "jax.numpy.", "lax.", "jax.lax.", "dispatch")


def _dotted(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    if isinstance(node, ast.Name):
        return node.id
    return None


def _const_str_seq(node: ast.expr) -> List[str]:
    """Extract static_argnames values: 'x', ('x','y'), ['x','y']."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


@dataclasses.dataclass
class _JitSite:
    fn: ast.FunctionDef
    static: Set[str]
    lineno: int


def _jit_decorator_info(dec: ast.expr) -> Optional[Set[str]]:
    """Return the declared static_argnames set if ``dec`` is a jit
    decorator, else None. A bare ``@jax.jit`` yields an empty set."""
    if _dotted(dec) in _JIT_NAMES:
        return set()
    if isinstance(dec, ast.Call):
        fn = _dotted(dec.func)
        if fn in _JIT_NAMES:
            pass
        elif fn in _PARTIAL_NAMES and dec.args and \
                _dotted(dec.args[0]) in _JIT_NAMES:
            pass
        else:
            return None
        static: Set[str] = set()
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                static.update(_const_str_seq(kw.value))
        return static
    return None


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


class _ModuleIndex:
    """Function table + jit roots + same-module call graph."""

    def __init__(self, tree: ast.Module):
        self.functions: Dict[str, ast.FunctionDef] = {}
        self.jit_sites: List[_JitSite] = []
        roots: Set[str] = set()

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
                for dec in node.decorator_list:
                    static = _jit_decorator_info(dec)
                    if static is not None:
                        self.jit_sites.append(
                            _JitSite(node, static, node.lineno))
                        roots.add(node.name)
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _dotted(node.value.func) in _JIT_NAMES:
                # name = jax.jit(fn, static_argnames=...)
                args = node.value.args
                if args and isinstance(args[0], ast.Name):
                    roots.add(args[0].id)
                    static: Set[str] = set()
                    for kw in node.value.keywords:
                        if kw.arg in ("static_argnames", "static_argnums"):
                            static.update(_const_str_seq(kw.value))
                    target = self.functions.get(args[0].id)
                    if target is not None:
                        self.jit_sites.append(
                            _JitSite(target, static, node.lineno))

        self.reachable = self._closure(roots)

    def _closure(self, roots: Set[str]) -> Set[str]:
        seen = set()
        frontier = [r for r in roots if r in self.functions]
        while frontier:
            name = frontier.pop()
            if name in seen:
                continue
            seen.add(name)
            for node in ast.walk(self.functions[name]):
                if isinstance(node, ast.Call):
                    callee = None
                    if isinstance(node.func, ast.Name):
                        callee = node.func.id
                    elif isinstance(node.func, ast.Attribute):
                        callee = node.func.attr
                    if callee in self.functions and callee not in seen:
                        frontier.append(callee)
        return seen


def _is_static_scalar(node: ast.expr) -> bool:
    """Heuristic: expression that is a host scalar, not a device array —
    bare names (static args), constants, len(), and shape/ndim/dtype
    attribute chains."""
    if isinstance(node, (ast.Name, ast.Constant)):
        return True
    if isinstance(node, ast.Call) and _dotted(node.func) == "len":
        return True
    if isinstance(node, ast.BinOp):
        return _is_static_scalar(node.left) and _is_static_scalar(node.right)
    d = _dotted(node) or ""
    if any(part in ("shape", "ndim", "dtype", "size")
           for part in d.split(".")):
        return True
    if isinstance(node, ast.Subscript):  # x.shape[0]
        return _is_static_scalar(node.value)
    return False


class _RuleVisitor(ast.NodeVisitor):
    """Single-pass visitor collecting raw (rule, line, message) hits."""

    def __init__(self, relpath: str, index: _ModuleIndex, *,
                 hot: bool, in_kernels: bool, is_ops: bool,
                 is_arm: bool, is_graph: bool, timed: bool = False):
        self.relpath = relpath
        self.index = index
        self.hot = hot                  # RPL001/004 scope
        self.in_kernels = in_kernels    # all fns hot
        self.is_ops = is_ops            # kernels/*/ops.py
        self.is_arm = is_arm            # ref.py / kernel.py (RPL002 exempt)
        self.is_graph = is_graph        # core/graph.py (RPL005 exempt)
        self.timed = timed              # RPL006 scope (obs/ exempt)
        self.hits: List[Tuple[str, int, str]] = []
        self._fn_stack: List[str] = []
        self._register_depth = 0
        self._device_names: List[Set[str]] = []

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.hits.append((rule, getattr(node, "lineno", 0), msg))

    def _in_hot_fn(self) -> bool:
        if not self.hot:
            return False
        if self.in_kernels:
            return bool(self._fn_stack)
        return any(name in self.index.reachable for name in self._fn_stack)

    # -- scope tracking ------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._fn_stack.append(node.name)
        self._device_names.append(set())
        self.generic_visit(node)
        self._device_names.pop()
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- RPL002: arm imports -------------------------------------------
    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if not self.is_arm:
            mod = node.module or ""
            tail = mod.rsplit(".", 1)[-1]
            if tail in ("ref", "kernel"):
                # `from .ref import ...` inside the op's own package
                # (level==1, bare module name) is the registration
                # mechanism; anything deeper crosses package lines.
                same_pkg = node.level == 1 and mod in ("ref", "kernel")
                if not same_pkg:
                    self._flag(
                        "RPL002", node,
                        f"import from kernel arm module '{mod}' bypasses "
                        f"the registry — import the dispatching wrapper "
                        f"from the op's ops.py instead")
        self.generic_visit(node)

    # -- calls: RPL001 + RPL002 + RPL006 -------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func) or ""
        name = dotted.rsplit(".", 1)[-1]

        if self.timed and dotted in _PERF_COUNTER_CALLS:
            self._flag(
                "RPL006", node,
                f"direct {dotted}() timing in a timed module — wrap the "
                f"stage in a repro.obs.trace span and read span.duration "
                f"so the wall lands in the trace/metrics pipeline")

        if not self.is_arm and self._register_depth == 0 and \
                (name.endswith("_ref") or name.endswith("_pallas")):
            self._flag(
                "RPL002", node,
                f"direct call to kernel arm '{name}' — route through "
                f"registry.dispatch (ops.py wrapper)")

        if self._in_hot_fn():
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                self._flag("RPL001", node,
                           ".item() forces a device->host sync")
            elif dotted in _HOST_SYNC_CALLS:
                self._flag("RPL001", node,
                           f"{dotted}() transfers the array to host")
            elif dotted in _CAST_NAMES and len(node.args) == 1 and \
                    not _is_static_scalar(node.args[0]):
                self._flag(
                    "RPL001", node,
                    f"{dotted}() on a computed value may force a host "
                    f"sync on a traced array")

        if name == "register_op":
            self._register_depth += 1
            self.generic_visit(node)
            self._register_depth -= 1
            return
        self.generic_visit(node)

    # -- RPL004: loops over device arrays ------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        if self._device_names and isinstance(node.value, ast.Call):
            d = _dotted(node.value.func) or ""
            if d.startswith(_DEVICE_PRODUCERS) or d == "dispatch":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self._device_names[-1].add(tgt.id)
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._in_hot_fn():
            it = node.iter
            flagged = False
            if isinstance(it, ast.Call):
                d = _dotted(it.func) or ""
                if d.startswith(_DEVICE_PRODUCERS):
                    flagged = True
            elif isinstance(it, ast.Name) and self._device_names and \
                    it.id in self._device_names[-1]:
                flagged = True
            if flagged:
                self._flag(
                    "RPL004", node,
                    "Python for-loop over a device array unrolls into the "
                    "trace (or syncs per element) — use lax.fori_loop/scan "
                    "or vectorize")
        self.generic_visit(node)

    # -- RPL005: raw pow2 / parity shape math --------------------------
    def visit_BinOp(self, node: ast.BinOp) -> None:
        if not self.is_graph:
            def const(n: ast.expr) -> Optional[object]:
                return n.value if isinstance(n, ast.Constant) else None

            if isinstance(node.op, ast.Pow) and const(node.left) == 2 and \
                    const(node.right) is None:
                self._flag("RPL005", node,
                           "raw 2**x shape math — use graph.pow2_ceil")
            elif isinstance(node.op, ast.LShift) and \
                    const(node.left) in (1, 2) and \
                    const(node.right) is None:
                self._flag("RPL005", node,
                           "raw 1<<x pow2 math — use graph.pow2_ceil")
            elif isinstance(node.op, ast.Mod) and const(node.right) == 2 \
                    and const(node.left) is None:
                self._flag("RPL005", node,
                           "raw x%2 parity shape math — use "
                           "graph.pow2_ceil/pad_edge_list helpers")
        self.generic_visit(node)


def _check_jit_static(index: _ModuleIndex) -> List[Tuple[str, int, str]]:
    hits: List[Tuple[str, int, str]] = []
    for site in index.jit_sites:
        params = set(_param_names(site.fn))
        missing = sorted((params & STATIC_SHAPE_PARAMS) - site.static)
        for p in missing:
            hits.append((
                "RPL003", site.lineno,
                f"jitted '{site.fn.name}' takes shape-bearing arg '{p}' "
                f"but does not declare it in static_argnames"))
    return hits


def lint_source(source: str, relpath: str) -> List[Finding]:
    """Lint one module's source. ``relpath`` is posix-style relative to
    the lint root (e.g. ``core/msbfs.py``)."""
    rel = relpath.replace("\\", "/")
    waivers, malformed = parse_waivers(source)
    findings = [Finding("RPL000", rel, line, msg) for line, msg in malformed]

    tree = ast.parse(source)
    index = _ModuleIndex(tree)
    parts = rel.split("/")
    in_kernels = parts[0] == "kernels"
    visitor = _RuleVisitor(
        rel, index,
        hot=is_hot_module(rel),
        in_kernels=in_kernels,
        is_ops=in_kernels and parts[-1] == "ops.py",
        is_arm=in_kernels and parts[-1] in ("ref.py", "kernel.py"),
        is_graph=rel == "core/graph.py",
        timed=is_timed_module(rel),
    )
    visitor.visit(tree)

    for rule, line, msg in visitor.hits + _check_jit_static(index):
        waiver = waivers.get(line)
        if waiver and rule in waiver[0]:
            findings.append(Finding(rule, rel, line, msg,
                                    waived=True, waiver_reason=waiver[1]))
        else:
            findings.append(Finding(rule, rel, line, msg))
    return findings


def lint_tree(root: Path, *,
              exclude: Sequence[str] = ("__pycache__",)) -> AnalysisReport:
    """Lint every ``*.py`` under ``root`` (the ``src/repro`` directory)."""
    root = Path(root)
    report = AnalysisReport()
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if any(part in exclude for part in path.parts):
            continue
        report.n_files += 1
        try:
            report.add(lint_source(path.read_text(), rel))
        except SyntaxError as exc:  # pragma: no cover - tree is importable
            report.add([Finding("RPL000", rel, exc.lineno or 0,
                                f"syntax error: {exc.msg}")])
    return report


def iter_rule_ids(findings: Iterable[Finding]) -> Set[str]:
    return {f.rule for f in findings}
