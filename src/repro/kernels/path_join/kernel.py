"""Pallas kernel: path-pair overlap counting for the ⊕ join (Def 3.1).

    overlap[i, j] = #{ (p, q) : A[i, p] == B[j, q], A[i, p] >= 0 }

The enumeration hot spot (Fig 3c: join/scan dominates): joining forward and
backward half-paths requires, for every candidate pair, the simple-path
check "do the two halves share a vertex?". On CPU that is a hash probe per
pair; here it is a dense (BA, BB, LA, LB) equality reduction — regular,
vectorizable, and tiny in the L dimensions (L <= 9), so the VPU runs it at
full tilt. The wrapper derives join validity:

  keyed join  : valid = key match (last cols) & overlap == 1 (join vertex only)
  splice join : valid = overlap == 0 (prefix vs cached suffix are disjoint)

Tiling: grid = (A blocks, B blocks); each program owns a (BA, BB) int32
tile; A tile (BA, LA) and B tile (BB, LB) are VMEM-resident
(BA=BB=256, L=9 -> ~18 KB in, 256 KB out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["path_overlap_pallas"]


def _kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                            # (BA, LA) int32
    b = b_ref[...]                            # (BB, LB) int32
    eq = (a[:, None, :, None] == b[None, :, None, :]) & (a >= 0)[:, None, :, None]
    out_ref[...] = jnp.sum(eq.astype(jnp.int32), axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def path_overlap_pallas(a_verts: jax.Array, b_verts: jax.Array,
                        *, block_a: int = 256, block_b: int = 256,
                        interpret: bool = False) -> jax.Array:
    """a_verts: (NA, LA), b_verts: (NB, LB) int32 (pad -1) -> (NA, NB) int32."""
    NA, LA = a_verts.shape
    NB, LB = b_verts.shape
    ba = min(block_a, NA)
    bb = min(block_b, NB)
    grid = (pl.cdiv(NA, ba), pl.cdiv(NB, bb))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, LA), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, LB), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ba, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((NA, NB), jnp.int32),
        interpret=interpret,
    )(a_verts, b_verts)
