"""Model zoo smoke + invariants on reduced configs (deliverable f).

One smoke per assigned architecture: instantiate the REDUCED same-family
config, run a forward/train step on CPU, assert output shapes and no NaNs.
Plus semantic checks: decode==prefill consistency, MoE vs dense oracle,
GNN permutation invariance, EmbeddingBag semantics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cr
from repro.config import RunOptions
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.launch.steps import build_bundle, _gnn_dims
from repro.models.sharding import Rules
from repro.models import transformer, gnn, recsys
from repro.optim import adamw_init

OPTS = RunOptions(remat=True, loss_chunk=32, attn_chunk=64, moe_groups=4,
                  seq_parallel=False)

SMOKE_CASES = [
    ("granite-8b", "train_4k", {"seq_len": 64, "global_batch": 2}),
    ("qwen1.5-110b", "train_4k", {"seq_len": 64, "global_batch": 2}),
    ("qwen2.5-14b", "train_4k", {"seq_len": 64, "global_batch": 2}),
    ("moonshot-v1-16b-a3b", "train_4k", {"seq_len": 32, "global_batch": 2}),
    ("olmoe-1b-7b", "train_4k", {"seq_len": 32, "global_batch": 2}),
    ("meshgraphnet", "full_graph_sm", {"n_nodes": 150, "n_edges": 600,
                                       "d_feat": 9}),
    ("graphcast", "full_graph_sm", {"n_nodes": 150, "n_edges": 600,
                                    "d_feat": 9}),
    ("schnet", "molecule", {"batch": 4, "n_nodes": 10, "n_edges": 24}),
    ("graphsage-reddit", "minibatch_lg", {"n_nodes": 2000, "batch_nodes": 16,
                                          "fanout": (4, 3), "d_feat": 11}),
    ("two-tower-retrieval", "train_batch", {"batch": 16}),
]


def _concretize(rng, tree, arch_mod, shape, over):
    """Real arrays for a bundle's abstract inputs."""
    out = []
    for i, a in enumerate(tree):
        if i == 0:
            cfg = arch_mod.REDUCED
            if arch_mod.FAMILY == "lm":
                out.append(transformer.init_lm_params(
                    jax.random.PRNGKey(0), cfg, tp=1))
            elif arch_mod.FAMILY == "gnn":
                from repro.config import ShapeSpec
                sh = arch_mod.SHAPES[shape]
                sh = ShapeSpec(sh.name, sh.kind,
                               tuple(dict(dict(sh.dims), **over).items()))
                d_in, d_out = _gnn_dims(cfg, sh)
                out.append(gnn.init_gnn_params(jax.random.PRNGKey(0), cfg,
                                               d_in=d_in, d_out=d_out))
            else:
                out.append(recsys.init_recsys_params(jax.random.PRNGKey(0),
                                                     cfg))
        elif hasattr(a, "_fields") and "m" in getattr(a, "_fields", ()):
            out.append(adamw_init(out[0]))
        else:
            def conc(s):
                if s.dtype == jnp.int32:
                    return jnp.asarray(
                        rng.integers(0, 8, s.shape).astype(np.int32))
                if s.dtype == jnp.bool_:
                    return jnp.asarray(rng.random(s.shape) < 0.9)
                return jnp.asarray(
                    rng.standard_normal(s.shape).astype(np.float32))
            out.append(jax.tree.map(conc, a))
    return out


@pytest.mark.parametrize("arch,shape,over", SMOKE_CASES,
                         ids=[c[0] + ":" + c[1] for c in SMOKE_CASES])
def test_arch_smoke(arch, shape, over):
    rng = np.random.default_rng(7)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    b = build_bundle(arch, shape, rules, OPTS, reduced=True, overrides=over)
    args = _concretize(rng, b.abstract_inputs, cr.get(arch), shape, over)
    with use_mesh(mesh):
        out = jax.jit(b.step_fn, in_shardings=b.in_shardings,
                      out_shardings=b.out_shardings)(*args)
    # output shapes match the abstract eval, and no NaNs anywhere
    abstract = jax.eval_shape(b.step_fn, *b.abstract_inputs)
    got_shapes = jax.tree.map(lambda x: x.shape, out)
    want_shapes = jax.tree.map(lambda x: x.shape, abstract)
    assert got_shapes == want_shapes
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert not bool(jnp.any(jnp.isnan(leaf))), f"NaN in {arch}"
    if isinstance(out, tuple) and len(out) == 3 and isinstance(out[2], dict):
        assert float(out[2]["loss"]) > 0


def test_lm_loss_decreases():
    """A few steps of training on structured data reduce the loss."""
    from repro.data.lm_data import TokenStream
    mesh = make_host_mesh()
    rules = Rules(mesh)
    b = build_bundle("granite-8b", "train_4k", rules, OPTS, reduced=True,
                     overrides={"seq_len": 64, "global_batch": 8})
    cfg = cr.get("granite-8b").REDUCED
    params = transformer.init_lm_params(jax.random.PRNGKey(0), cfg, tp=1)
    opt = adamw_init(params)
    stream = TokenStream(cfg.vocab, 8, 64, seed=1)
    step = jax.jit(b.step_fn)
    losses = []
    with use_mesh(mesh):
        for i in range(8):
            tok, tgt = stream.batch_at(i)
            params, opt, m = step(params, opt, jnp.asarray(tok),
                                  jnp.asarray(tgt))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_decode_matches_prefill():
    """Greedy decode logits == teacher-forced forward logits (KV cache
    correctness), for both dense and MoE reduced configs."""
    for arch in ["granite-8b", "qwen2.5-14b"]:
        cfg = cr.get(arch).REDUCED
        opts = dataclasses.replace(OPTS, attn_chunk=16)
        params = transformer.init_lm_params(jax.random.PRNGKey(1), cfg, tp=1)
        B, S = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
        ident = lambda x, a: x
        # teacher forced: logits at every position
        x, _ = transformer.lm_forward(params, toks, cfg, opts, ident)
        x = transformer.rmsnorm(x, params["final_norm"])
        unemb = params["unembed"].astype(x.dtype)
        full_logits = np.asarray((x @ unemb).astype(jnp.float32))
        # incremental decode
        cache = transformer.init_cache(cfg, B, S, dtype=jnp.float32)
        got = []
        for i in range(S):
            logits, cache = transformer.decode_step(
                params, toks[:, i:i + 1], cache, cfg, opts, ident)
            got.append(np.asarray(logits)[:, 0])
        got = np.stack(got, axis=1)
        np.testing.assert_allclose(got, full_logits, atol=2e-3, rtol=2e-3)


def test_moe_matches_dense_oracle():
    from repro.models.moe import moe_ffn, moe_ffn_dense_ref
    cfg = cr.get("olmoe-1b-7b").REDUCED
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = transformer.init_lm_params(jax.random.PRNGKey(0), cfg, tp=1)
    lp = jax.tree.map(lambda a: a[0], p["layers"])
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    ident = lambda x, a: x
    for groups in [1, 2, 8]:
        out, aux = moe_ffn(h, lp, cfg, ident, groups=groups)
        ref = moe_ffn_dense_ref(h, lp, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
        assert float(aux) > 0


def test_gnn_permutation_invariance():
    """Relabeling nodes permutes outputs consistently (message passing is
    permutation-equivariant)."""
    cfg = cr.get("meshgraphnet").REDUCED
    params = gnn.init_gnn_params(jax.random.PRNGKey(0), cfg, d_in=5, d_out=3)
    rng = np.random.default_rng(3)
    N, E = 20, 60
    batch = {"nodes": rng.standard_normal((N, 5)).astype(np.float32),
             "edge_src": rng.integers(0, N, E).astype(np.int32),
             "edge_dst": rng.integers(0, N, E).astype(np.int32),
             "edge_feat": rng.standard_normal((E, 4)).astype(np.float32)}
    out = np.asarray(gnn.gnn_forward(params, jax.tree.map(jnp.asarray, batch),
                                     cfg))
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    batch2 = dict(batch)
    batch2["nodes"] = batch["nodes"][perm]
    batch2["edge_src"] = inv[batch["edge_src"]].astype(np.int32)
    batch2["edge_dst"] = inv[batch["edge_dst"]].astype(np.int32)
    out2 = np.asarray(gnn.gnn_forward(params, jax.tree.map(jnp.asarray, batch2),
                                      cfg))
    np.testing.assert_allclose(out2, out[perm], atol=1e-4)


def test_embedding_bag_semantics():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray(np.array([[1, 3, -1], [-1, -1, -1]], np.int32))
    mean = np.asarray(recsys.embedding_bag(table, ids, "mean"))
    np.testing.assert_allclose(mean[0], (np.array([2., 3.]) + [6., 7.]) / 2)
    np.testing.assert_allclose(mean[1], [0., 0.])
    s = np.asarray(recsys.embedding_bag(table, ids, "sum"))
    np.testing.assert_allclose(s[0], [8., 10.])


def test_retrieval_topk_matches_argsort():
    cfg = cr.get("two-tower-retrieval").REDUCED
    params = recsys.init_recsys_params(jax.random.PRNGKey(0), cfg)
    hist = jnp.asarray(np.array([[1, 2, 3, -1, -1]], np.int32))
    cands = jnp.arange(cfg.n_items, dtype=jnp.int32)
    vals, ids = recsys.retrieve_topk(params, hist, cands, k=10)
    u = recsys.user_tower(params, hist)
    v = recsys.item_tower(params, cands)
    scores = np.asarray(v @ u[0])
    top = np.argsort(-scores)[:10]
    np.testing.assert_allclose(np.asarray(vals), scores[top], atol=1e-5)


def test_neighbor_sampler_respects_fanout():
    from repro.core import generators
    from repro.models.sampler import sample_blocks
    g = generators.erdos(500, 6.0, seed=5)
    rng = np.random.default_rng(0)
    roots = rng.integers(0, g.n, 32)
    blk = sample_blocks(g, roots, (5, 3), rng)
    assert blk.edge_mask.sum() <= 32 * 5 + 32 * 5 * 3
    # all edges reference valid local nodes
    n_valid = blk.n_nodes
    assert blk.edge_src[blk.edge_mask].max() < n_valid
    assert blk.edge_dst[blk.edge_mask].max() < n_valid
    # every sampled edge exists in g
    ids = blk.node_ids
    # direction: sampler collects in-neighbors: each edge src->dst exists in G
    for s_, d_ in zip(blk.edge_src[blk.edge_mask][:50],
                      blk.edge_dst[blk.edge_mask][:50]):
        assert int(ids[d_]) in list(g.neighbors(int(ids[s_])))
