"""Synthetic graph generators (offline stand-ins for the paper's datasets).

The paper evaluates on SNAP/LAW graphs (Epinions .. Friendster). Those are
not downloadable here, so benchmarks use parameter-matched synthetics:

  * ``powerlaw``  -- directed preferential attachment (Barabási–Albert
                     flavoured); degree tail ~ the social graphs (EP/SL/PO/LJ).
  * ``erdos``     -- uniform random (WT-like sparse).
  * ``community`` -- planted partition: dense intra-community, sparse
                     inter-community edges; gives the *controllable query
                     similarity* used by Exp-1 (queries within a community
                     overlap heavily).
  * ``grid``      -- 2-D torus (road-network-ish diameter, for KSP compares).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph

__all__ = ["powerlaw", "erdos", "community", "grid",
           "random_queries", "similar_queries"]


def powerlaw(n: int, avg_deg: float = 8.0, seed: int = 0,
             alpha: float = 0.7) -> Graph:
    """Directed preferential-attachment-ish graph with power-law in-degree."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    # mixture: fraction alpha prefers low ids (Zipf-ish popularity), rest uniform
    zipf = np.minimum((rng.pareto(1.5, size=m) * n * 0.01).astype(np.int64), n - 1)
    uni = rng.integers(0, n, size=m, dtype=np.int64)
    pick = rng.random(m) < alpha
    dst = np.where(pick, zipf, uni)
    return Graph.from_edges(n, src, dst)


def erdos(n: int, avg_deg: float = 8.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    return Graph.from_edges(n, src, dst)


def community(n: int, n_comm: int = 8, avg_deg: float = 10.0,
              p_intra: float = 0.9, seed: int = 0) -> Graph:
    """Planted-partition digraph; queries inside a community share structure."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    comm = rng.integers(0, n_comm, size=n)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    intra = rng.random(m) < p_intra
    # destination drawn from same community (intra) or anywhere (inter)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    # resample intra edges within src's community via bucket trick
    order = np.argsort(comm, kind="stable")
    bucket_start = np.searchsorted(comm[order], np.arange(n_comm))
    bucket_end = np.searchsorted(comm[order], np.arange(n_comm), side="right")
    c = comm[src]
    lo, hi = bucket_start[c], bucket_end[c]
    draw = lo + (rng.random(m) * np.maximum(hi - lo, 1)).astype(np.int64)
    dst = np.where(intra, order[np.minimum(draw, n - 1)], dst)
    return Graph.from_edges(n, src, dst)


def grid(side: int, seed: int = 0) -> Graph:
    """2-D torus, 4 out-neighbors each."""
    n = side * side
    v = np.arange(n, dtype=np.int64)
    x, y = v % side, v // side
    right = ((x + 1) % side) + y * side
    left = ((x - 1) % side) + y * side
    up = x + ((y + 1) % side) * side
    down = x + ((y - 1) % side) * side
    src = np.concatenate([v, v, v, v])
    dst = np.concatenate([right, left, up, down])
    return Graph.from_edges(n, src, dst)


# ----------------------------------------------------------------------
# query workload generators (paper §V Settings)
# ----------------------------------------------------------------------

def random_queries(g: Graph, nq: int, k_range=(4, 7), seed: int = 0,
                   require_reachable: bool = True, max_tries: int = 200):
    """Random (s, t, k) with s reaching t within k hops (paper's default)."""
    from .oracle import bfs_dist_from  # light host BFS

    rng = np.random.default_rng(seed)
    out = []
    tries = 0
    while len(out) < nq and tries < max_tries * nq:
        tries += 1
        s = int(rng.integers(0, g.n))
        k = int(rng.integers(k_range[0], k_range[1] + 1))
        if require_reachable:
            dist = bfs_dist_from(g, s, k)
            cand = np.flatnonzero((dist >= 1) & (dist <= k))
            if cand.size == 0:
                continue
            t = int(cand[rng.integers(0, cand.size)])
        else:
            t = int(rng.integers(0, g.n))
            if t == s:
                continue
        out.append((s, t, k))
    if len(out) < nq:
        raise RuntimeError("could not generate enough reachable queries")
    return out


def similar_queries(g: Graph, nq: int, similarity: float, k_range=(4, 7),
                    seed: int = 0):
    """Workload with tunable overlap (Exp-1): fraction ``similarity`` of the
    queries are drawn from a small set of hub (s, t) seed pairs perturbed to
    1-hop neighbors, the rest uniformly at random."""
    rng = np.random.default_rng(seed)
    base = random_queries(g, max(1, nq // 16), k_range, seed=seed + 1)
    out = []
    for i in range(nq):
        k = int(rng.integers(k_range[0], k_range[1] + 1))
        if rng.random() < similarity:
            s0, t0, _ = base[int(rng.integers(0, len(base)))]
            # perturb to a neighbor of the seed endpoints (keeps Γ overlap high)
            nb_s = g.neighbors(s0, reverse=True)
            nb_t = g.neighbors(t0)
            s = int(nb_s[rng.integers(0, nb_s.size)]) if nb_s.size and rng.random() < 0.5 else s0
            t = int(nb_t[rng.integers(0, nb_t.size)]) if nb_t.size and rng.random() < 0.5 else t0
            if s == t:
                s, t = s0, t0
            out.append((s, t, k))
        else:
            out.extend(random_queries(g, 1, (k, k), seed=seed + 1000 + i))
    return out[:nq]
