"""Exp-9: query-variant throughput — paths vs count-only vs exists-only.

The typed query layer threads the per-query ``output`` kind all the way
into ⊕ assembly: count-only queries use counting joins (no output buffer,
no compaction, scalar-only host transfer) and exists-only queries
additionally early-terminate at the first witness. This experiment runs
the *same* batch under the three output kinds and reports warm wall time
per variant, verifying that

  * all three agree with each other (count == paths row count,
    exists == count > 0), and
  * count/exists runs assemble exactly zero path rows
    (stats ``n_rows_assembled``).
"""
from __future__ import annotations

import time

from repro.core import BatchPathEngine, EngineConfig, PathQuery
from repro.core import generators
from .common import record


def _time(engine, queries):
    engine.run(queries)                      # warm the jit caches
    t0 = time.perf_counter()
    res = engine.run(queries)
    return time.perf_counter() - t0, res


def main(scale: float = 1.0) -> dict:
    n = max(300, int(6000 * scale))
    g = generators.community(n, n_comm=max(2, n // 1500), avg_deg=6.0, seed=4)
    base = generators.similar_queries(g, max(8, int(24 * min(scale, 1.0))),
                                      similarity=0.7, k_range=(4, 5), seed=5)

    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    variants = {
        "paths": [PathQuery(s, t, k) for s, t, k in base],
        "count": [PathQuery(s, t, k, output="count") for s, t, k in base],
        "exists": [PathQuery(s, t, k, output="exists") for s, t, k in base],
    }
    times, reports = {}, {}
    for name, qs in variants.items():
        times[name], reports[name] = _time(eng, qs)
        qps = len(base) / max(times[name], 1e-9)
        record(f"exp9_{name}", times[name] * 1e6 / len(base),
               f"qps={qps:.0f} "
               f"rows_assembled={reports[name].stats['n_rows_assembled']}")

    # the variants must tell one consistent story
    for qi in range(len(base)):
        n_paths = reports["paths"][qi].count
        assert reports["count"][qi].count == n_paths, qi
        assert reports["exists"][qi].exists == (n_paths > 0), qi
    for name in ("count", "exists"):
        assert reports[name].stats["n_rows_assembled"] == 0, (
            f"{name}-only run assembled path rows")

    speedup = {name: times["paths"] / max(times[name], 1e-9)
               for name in ("count", "exists")}
    record("exp9_speedup_count", speedup["count"], "vs paths")
    record("exp9_speedup_exists", speedup["exists"], "vs paths")
    return {"n": n, "n_queries": len(base),
            "t_paths_s": times["paths"], "t_count_s": times["count"],
            "t_exists_s": times["exists"], **{f"speedup_{k}": v
                                              for k, v in speedup.items()}}


if __name__ == "__main__":
    main()
