"""Multi-device semantics tests.

Two families:

* sharded-engine tests (`core.distributed`): run on any jax with the
  classic ``jax.sharding.Mesh`` + ``NamedSharding`` GSPMD API. The
  multi-device ones run under 8 fake CPU devices via subprocess — the
  XLA device-count flag must be set before jax initializes, so they get
  isolated interpreters; the placement/identity unit tests run in-process
  on the single default device (a mesh of size 1 is the identity).
* legacy model-stack tests marked ``modern_jax`` (flash decode,
  checkpoint reshard, ring aggregate): need jax.make_mesh axis_types /
  jax.set_mesh / jax.shard_map and skip on older jax.
"""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

modern_jax = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs the modern jax sharding API (jax.make_mesh axis_types, "
           "jax.set_mesh, jax.shard_map); installed jax is too old")


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PREAMBLE = """
import os, sys
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
"""


@modern_jax
def test_flash_decode_matches_baseline():
    """shard_map flash-decoding == gathered-KV decode on a (2, 4) mesh."""
    out = _run(PREAMBLE + """
import dataclasses
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_bundle
from repro.models.sharding import Rules
from repro.config import RunOptions
from repro.models import transformer
from repro import configs as cr

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = Rules(mesh)
cfg = cr.get("granite-8b").REDUCED
B, S = 4, 32
params = transformer.init_lm_params(jax.random.PRNGKey(0), cfg, tp=4)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
outs = {}
with jax.set_mesh(mesh):
    for fd in [False, True]:
        opts = RunOptions(flash_decode=fd, attn_chunk=8, seq_parallel=False)
        cache = transformer.init_cache(cfg, B, S, dtype=jnp.float32)
        # pre-fill some cache content at positions 0..9
        k0 = jax.random.normal(jax.random.PRNGKey(2),
                               (cfg.n_layers, B, 10, cfg.n_kv_heads, cfg.hd))
        cache["k"] = cache["k"].at[:, :, :10].set(k0)
        cache["v"] = cache["v"].at[:, :, :10].set(k0 * 0.5)
        cache["pos"] = jnp.int32(10)
        c_spec = jax.tree.map(
            lambda ax: rules.sharding(*ax) if isinstance(ax, tuple) else rules.sharding(),
            transformer.cache_logical(False),
            is_leaf=lambda x: isinstance(x, tuple))
        cache = jax.device_put(cache, c_spec)
        constrain = lambda x, axes: jax.lax.with_sharding_constraint(
            x, rules.sharding(*axes))
        logits, _ = jax.jit(
            lambda p, t, c: transformer.decode_step(p, t, c, cfg, opts,
                                                    constrain))(params, toks, cache)
        outs[fd] = np.asarray(logits)
err = np.abs(outs[True] - outs[False]).max()
print("MAXERR", err)
assert err < 2e-3, err
""")
    assert "MAXERR" in out


def test_distributed_msbfs_matches_single_device():
    """Edge-sharded MS-BFS under GSPMD == single-device reference (via
    the classic Mesh API, so this runs on old and new jax alike)."""
    out = _run(PREAMBLE + """
from repro.core.graph import DeviceGraph
from repro.core import generators
from repro.core.distributed import shard_edges
from repro.core.msbfs import msbfs_dist
from jax.sharding import Mesh

g = generators.erdos(512, 4.0, seed=0)
dg = DeviceGraph.build(g, pad=False)   # exact m: forces a sharding pad
srcs = jnp.asarray(np.arange(16, dtype=np.int32))
ref = np.asarray(msbfs_dist(dg.esrc, dg.edst, srcs, n=g.n, k_max=4))

mesh = Mesh(np.array(jax.devices()), ("cells",))
esrc, edst = shard_edges(dg.esrc, dg.edst, mesh, n=g.n)
m8 = -(-g.m // 8) * 8
assert esrc.shape[0] == m8
# the device-multiple pad is the sentinel (n, n), never a repeated edge
assert np.all(np.asarray(esrc)[g.m:] == g.n)
assert np.all(np.asarray(edst)[g.m:] == g.n)
dist = np.asarray(msbfs_dist(esrc, edst, srcs, n=g.n, k_max=4))
print("EQ", np.array_equal(ref, dist))
assert np.array_equal(ref, dist)
""")
    assert "EQ True" in out


@modern_jax
def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,2) — elastic scaling."""
    out = _run(PREAMBLE + """
import tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import save_checkpoint, restore_checkpoint

tree = {"w": jnp.arange(64.0).reshape(8, 8), "s": jnp.float32(3.0)}
m1 = jax.make_mesh((4, 2), ("data", "model"),
                   axis_types=(jax.sharding.AxisType.Auto,) * 2)
placed = {"w": jax.device_put(tree["w"], NamedSharding(m1, P("data", "model"))),
          "s": tree["s"]}
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 3, placed)
    m2 = jax.make_mesh((2, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = {"w": NamedSharding(m2, P("data", "model")),
          "s": NamedSharding(m2, P())}
    got, step, _ = restore_checkpoint(d, abstract, sh)
    assert step == 3
    assert np.array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.mesh.devices.size == 4
print("RESHARD OK")
""")
    assert "RESHARD OK" in out


@modern_jax
def test_ring_aggregate_matches_segment_sum():
    """GNN ring SpMM (collective_permute schedule) == local segment_sum."""
    out = _run(PREAMBLE + """
from jax.sharding import PartitionSpec as P
from repro.models.gnn import ring_aggregate

P_DEV = 8
N_loc, F, Eb = 16, 5, 40
N = P_DEV * N_loc
rng = np.random.default_rng(0)
h = rng.standard_normal((N, F)).astype(np.float32)
# random edges; bucket by (dst_owner, src_owner)
E = 500
src = rng.integers(0, N, E)
dst = rng.integers(0, N, E)
es = np.zeros((P_DEV, P_DEV, Eb), np.int32)
ed = np.zeros((P_DEV, P_DEV, Eb), np.int32)
em = np.zeros((P_DEV, P_DEV, Eb), bool)
fill = np.zeros((P_DEV, P_DEV), int)
kept = []
for s_, d_ in zip(src, dst):
    po, so = d_ // N_loc, s_ // N_loc
    i = fill[po, so]
    if i >= Eb:
        continue
    es[po, so, i] = s_ % N_loc
    ed[po, so, i] = d_ % N_loc
    em[po, so, i] = True
    fill[po, so] += 1
    kept.append((s_, d_))
ref = np.zeros((N, F), np.float32)
for s_, d_ in kept:
    ref[d_] += h[s_]

mesh = jax.make_mesh((P_DEV,), ("cells",),
                     axis_types=(jax.sharding.AxisType.Auto,))
fn = jax.shard_map(
    lambda hh, a, b, c: ring_aggregate(hh, a[0], b[0], c[0], "cells"),
    mesh=mesh,
    in_specs=(P("cells"), P("cells"), P("cells"), P("cells")),
    out_specs=P("cells"), check_vma=False)
got = np.asarray(fn(h.reshape(P_DEV * N_loc, F), es, ed, em))
print("MAXERR", np.abs(got - ref).max())
assert np.allclose(got, ref, atol=1e-5)
""")
    assert "MAXERR" in out


# ----------------------------------------------------------------------
# sharded-engine subsystem (core.distributed): placement units run
# in-process; end-to-end parity runs under 8 forced CPU devices
# ----------------------------------------------------------------------

def test_plan_clusters_balance_and_uneven_shapes():
    from repro.core.distributed import plan_clusters

    # more clusters than replicas: every cluster placed exactly once
    costs = [5.0, 1.0, 4.0, 2.0, 3.0, 1.0, 8.0]
    assign, loads = plan_clusters(costs, 3)
    placed = sorted(ci for a in assign for ci in a)
    assert placed == list(range(len(costs)))
    # greedy LPT keeps the makespan near the mean: no replica exceeds
    # the heaviest single cluster + mean of the rest
    assert max(loads) <= max(costs) + sum(costs) / 3
    # fewer clusters than replicas: trailing replicas stay empty
    assign, loads = plan_clusters([2.0, 1.0], 4)
    assert sorted(ci for a in assign for ci in a) == [0, 1]
    assert sum(1 for a in assign if not a) == 2
    # zero clusters
    assign, loads = plan_clusters([], 4)
    assert all(a == [] for a in assign) and loads == [0.0] * 4
    # heaviest first onto distinct replicas
    assign, _ = plan_clusters([10.0, 9.0, 1.0], 2)
    heavy = [a for a in assign if 0 in a][0]
    assert 1 not in heavy
    # all-zero costs: ties must spread round-robin, not serialize on
    # replica 0 (load ties break on assignment count)
    assign, loads = plan_clusters([0.0] * 6, 3)
    assert [len(a) for a in assign] == [2, 2, 2]
    assert sorted(ci for a in assign for ci in a) == list(range(6))
    assert loads == [0.0] * 3
    # zero-cost remainder spreads too (4 ties over 3 replicas: 2/1/1)
    assign, _ = plan_clusters([0.0] * 4, 3)
    assert sorted(len(a) for a in assign) == [1, 1, 2]


def test_edge_bucket_alignment():
    from repro.core.distributed import edge_bucket_for

    assert edge_bucket_for(1000, 8) == 1024          # pow2 stays pow2
    assert edge_bucket_for(3, 8) == 8                # floor at n_dev
    assert edge_bucket_for(1024, 8) == 1024
    assert edge_bucket_for(1000, 6) % 6 == 0         # non-pow2 aligns
    assert edge_bucket_for(1000, 6) >= 1024


def test_sentinel_pad_not_edge_repeat_in_walk_counts():
    """The device-multiple pad must be the inert sentinel (n, n), not a
    repeat of the last real edge — a repeated edge double-counts in
    walk_counts (segment_sum), even though it is invisible to the
    boolean-semiring BFS. This is the host-side half of the shard_edges
    fix; the sharded tail itself is asserted under the 8-device mesh in
    test_distributed_msbfs_matches_single_device."""
    import jax.numpy as jnp
    from repro.core import generators
    from repro.core.graph import DeviceGraph, pad_edge_list
    from repro.core.index import walk_counts

    g = generators.erdos(96, 3.0, seed=3)
    dg = DeviceGraph.build(g, pad=False)     # exact shapes
    slack = jnp.full((g.n + 1,), 7, jnp.int8)
    # source = the repeated edge's own src, so the duplicated edge is
    # guaranteed to lie on counted walks (level 1 already diverges)
    src = int(np.asarray(dg.esrc)[-1])
    exact = np.asarray(walk_counts(dg.esrc, dg.edst, src, slack,
                                   n=g.n, budget=3))
    # sentinel pad (what shard_edges now uses): bit-equal counts
    pe, pd = pad_edge_list(np.asarray(dg.esrc), np.asarray(dg.edst),
                           g.n, g.m + 13)
    padded = np.asarray(walk_counts(jnp.asarray(pe), jnp.asarray(pd), src,
                                    slack, n=g.n, budget=3))
    assert np.array_equal(exact, padded)
    # the old repeat-last-edge pad really does diverge (double count)
    re_ = np.concatenate([np.asarray(dg.esrc)] + [np.asarray(dg.esrc)[-1:]] * 13)
    rd_ = np.concatenate([np.asarray(dg.edst)] + [np.asarray(dg.edst)[-1:]] * 13)
    repeat = np.asarray(walk_counts(jnp.asarray(re_), jnp.asarray(rd_), src,
                                    slack, n=g.n, budget=3))
    assert not np.array_equal(exact, repeat)


def test_mesh_size_one_is_identity():
    """n_devices=1 runs the sharded code path on one device and must be
    indistinguishable from the plain engine (same results, same stats
    shape, one replica, index view is the engine's own)."""
    from repro.core import BatchPathEngine, EngineConfig, generators

    g = generators.community(400, n_comm=4, avg_deg=4.0, seed=0)
    qs = generators.similar_queries(g, 8, 0.5, (3, 4), seed=1)
    plain = BatchPathEngine(g, EngineConfig(min_cap=128))
    one = BatchPathEngine(g, EngineConfig(min_cap=128, n_devices=1))
    assert one.executor.n_replicas == 1 and not one.executor.sharded
    r0 = plain.run(qs, planner="batch")
    r1 = one.run(qs, planner="batch")
    for qi in range(len(qs)):
        assert np.array_equal(r0[qi].paths, r1[qi].paths)
    assert "per_device" not in r1.stats   # no fan-out happened
    # empty batch through the same path
    assert len(one.run([])) == 0


def test_cluster_costs_monotone_in_hops():
    from repro.core import build_index, generators
    from repro.core.graph import DeviceGraph
    from repro.core.distributed import cluster_costs

    g = generators.erdos(300, 4.0, seed=2)
    dg = DeviceGraph.build(g)
    from repro.core.oracle import bfs_dist_from
    s = 0
    d = bfs_dist_from(g, s, 6)
    ts = np.flatnonzero((d >= 1) & (d <= 3))
    t = int(ts[0])
    index = build_index(dg, [(s, t, 2), (s, t, 6)])
    c_small, c_big = cluster_costs(index, [[0], [1]])
    assert c_big >= c_small > 0


def test_clustering_min_clusters_floor():
    from repro.core.clustering import cluster_queries

    mu = np.full((6, 6), 0.9)
    np.fill_diagonal(mu, 1.0)
    assert len(cluster_queries(mu, 0.5)) == 1
    assert len(cluster_queries(mu, 0.5, min_clusters=3)) == 3
    # floor above Q degrades to singletons
    assert len(cluster_queries(mu, 0.5, min_clusters=10)) == 6


def test_sharded_batch_matches_single_device():
    """8-device cluster-parallel BatchEnum == single-device, bit-equal,
    across planners and uneven cluster/device ratios."""
    out = _run(PREAMBLE + """
from repro.core import BatchPathEngine, EngineConfig, generators

assert len(jax.devices()) == 8
# 12 disconnected communities -> ~12 clusters over 8 devices (more
# clusters than devices); the 3-query subset exercises fewer-than-devices
g = generators.community(1200, n_comm=12, avg_deg=4.0, p_intra=1.0, seed=0)
qs = generators.random_queries(g, 16, k_range=(4, 5), seed=1)
e1 = BatchPathEngine(g, EngineConfig(min_cap=128))
e8 = BatchPathEngine(g, EngineConfig(min_cap=128, n_devices=8))
pd = None
for planner in ("batch", "batch+", "basic"):
    r1 = e1.run(qs, planner=planner)
    r8 = e8.run(qs, planner=planner)
    assert r1.stats.get("n_clusters") == r8.stats.get("n_clusters")
    for qi in range(len(qs)):
        assert np.array_equal(r1[qi].paths, r8[qi].paths), (planner, qi)
    if planner == "batch":
        pd = r8.stats.get("per_device")
        n_clusters = r8.stats["n_clusters"]
assert pd is not None and len(pd) == 8
assert sum(d["n_clusters"] for d in pd) == n_clusters
# fewer clusters than devices
sub = qs[:3]
r1 = e1.run(sub); r8 = e8.run(sub)
for qi in range(len(sub)):
    assert np.array_equal(r1[qi].paths, r8[qi].paths)
# zero queries
assert len(e8.run([])) == 0
# count/exists parity (no path assembly on either side)
from repro.core import PathQuery
cq = [PathQuery(s, t, k, output="count") for s, t, k in qs[:6]]
r1 = e1.run(cq); r8 = e8.run(cq)
assert [r.count for r in r1] == [r.count for r in r8]
print("SHARDED PARITY OK")
""")
    assert "SHARDED PARITY OK" in out


def test_sharded_apply_delta_parity():
    """Delta churn on a sharded engine: results stay bit-equal to the
    single-device engine and every replica cache sees the same epoch."""
    out = _run(PREAMBLE + """
from repro.core import BatchPathEngine, EngineConfig, GraphDelta, generators

g = generators.community(900, n_comm=6, avg_deg=4.0, p_intra=1.0, seed=0)
qs = generators.random_queries(g, 12, k_range=(4, 4), seed=1)
e1 = BatchPathEngine(g, EngineConfig(min_cap=128, cache_bytes=16 << 20))
e8 = BatchPathEngine(g, EngineConfig(min_cap=128, cache_bytes=16 << 20,
                                     n_devices=8))
rng = np.random.default_rng(0)
r1 = e1.run(qs); r8 = e8.run(qs)      # warm caches on both engines
for rnd in range(4):
    src = np.repeat(np.arange(g.n), np.diff(e1.g.indptr))
    dst = e1.g.indices
    pick = rng.choice(src.size, 6, replace=False)
    rem = list(zip(src[pick].tolist(), dst[pick].tolist()))
    adds = []
    while len(adds) < 6:
        u, v = (int(x) for x in rng.integers(0, g.n, 2))
        if u != v:
            adds.append((u, v))
    delta = GraphDelta.from_pairs(add=adds, remove=rem)
    rep1 = e1.apply_delta(delta)
    rep8 = e8.apply_delta(delta)
    eps = rep8.get("cache_epochs")
    assert eps and len(set(eps)) == 1, eps       # lockstep epochs
    assert rep8["n_touched"] == rep1["n_touched"]
    r1 = e1.run(qs); r8 = e8.run(qs)
    for qi in range(len(qs)):
        assert np.array_equal(r1[qi].paths, r8[qi].paths), (rnd, qi)
# replica caches exist and agree with the primary epoch
caches = e8._all_caches()
assert len(caches) == 8
assert len({c.epoch for c in caches}) == 1
print("DELTA PARITY OK epochs", sorted({c.epoch for c in caches}))
""")
    assert "DELTA PARITY OK" in out


def test_sharded_streaming_server():
    """StreamingServer over a sharded engine: admission fans the micro-
    batch across the mesh and results match the single-device server."""
    out = _run(PREAMBLE + """
from repro.core import BatchPathEngine, EngineConfig, generators
from repro.launch.serve import AdmissionPolicy, StreamingServer

g = generators.community(800, n_comm=8, avg_deg=4.0, p_intra=1.0, seed=0)
qs = generators.random_queries(g, 12, k_range=(4, 4), seed=1)
def serve(n_devices):
    eng = BatchPathEngine(g, EngineConfig(
        min_cap=128, cache_bytes=16 << 20, n_devices=n_devices))
    srv = StreamingServer(eng, policy=AdmissionPolicy(max_batch=12,
                                                      max_delay_s=0.0))
    qids = [srv.submit(q) for q in qs]
    srv.drain()
    return srv, [srv.take(qid).paths for qid in qids]
srv1, p1 = serve(None)
srv8, p8 = serve(8)
for a, b in zip(p1, p8):
    assert np.array_equal(a, b)
log = srv8.batch_log[-1]
assert log["n_devices"] == 8 and len(log["per_device"]) == 8
assert srv8.sched.steals == 0          # the mesh replaces the stealing loop
print("STREAMING SHARDED OK", log["n_clusters"], "clusters")
""")
    assert "STREAMING SHARDED OK" in out
