"""Query similarity (Def 4.4/4.5) from hop-constrained neighborhoods.

Γ(q) / Γ_r(q) are reusable by-products of the index BFS (Def 4.4 note): a
vertex is in Γ(q) iff dist(q.s, v) <= q.k. We materialize them as boolean
rows and compute all-pairs intersection sizes either as a chunked MXU
matmul (jnp reference) or with the packed AND+popcount Pallas kernel.

Def 4.5's printed formula has a stray ^{-1}; properties (1)-(3) and the
zero-intersection footnote pin the intended quantity to a mean of the two
directional *overlap coefficients*  i = |Γ_A ∩ Γ_B| / min(|Γ_A|, |Γ_B|).
We use the arithmetic mean (the only reading consistent with the footnote's
"the corresponding part ... is 0"), documented in DESIGN.md.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .index import QueryIndex
from ..kernels.registry import resolve_backend

__all__ = ["gamma_matrix", "intersection_matrix", "similarity_matrix"]


def gamma_matrix(index: QueryIndex, reverse: bool = False) -> jax.Array:
    """(Q, n) bool — Γ_r if reverse else Γ."""
    ks = jnp.asarray(np.array([q[2] for q in index.queries], np.int8))
    if reverse:
        cols = index.dist_t[:-1, index.tgt_col]      # (n, Q)
    else:
        cols = index.dist_s[:-1, index.src_col]
    return (cols <= ks[None, :]).T                   # (Q, n)


@partial(jax.jit, static_argnames=("chunk",))
def intersection_matrix(gam: jax.Array, chunk: int = 1 << 16) -> jax.Array:
    """All-pairs |Γ_A ∩ Γ_B| via chunked f32 matmul on the MXU (ref path)."""
    Q, n = gam.shape
    out = jnp.zeros((Q, Q), jnp.float32)
    for lo in range(0, n, chunk):
        g = gam[:, lo:lo + chunk].astype(jnp.float32)
        out = out + g @ g.T
    return out.astype(jnp.int32)


def similarity_matrix(index: QueryIndex,
                      backend: Optional[str] = None) -> np.ndarray:
    """(Q, Q) float64 μ matrix on host (diagonal = 1).

    ``backend`` resolves through the kernel registry (None -> env/auto;
    unknown names raise ValueError): kernel backends run the packed
    AND+popcount kernel, ``jnp`` the chunked MXU matmul reference.
    """
    gf = gamma_matrix(index, reverse=False)
    gr = gamma_matrix(index, reverse=True)
    kb = resolve_backend(backend)
    if kb.uses_kernel:
        from ..kernels.pairwise_popcount.ops import pairwise_intersections
        inter_f = np.asarray(pairwise_intersections(gf, backend=kb.value))
        inter_r = np.asarray(pairwise_intersections(gr, backend=kb.value))
    else:
        inter_f = np.asarray(intersection_matrix(gf))
        inter_r = np.asarray(intersection_matrix(gr))
    size_f = np.asarray(gf.sum(1)).astype(np.int64)
    size_r = np.asarray(gr.sum(1)).astype(np.int64)

    def overlap(inter, size):
        mins = np.minimum(size[:, None], size[None, :]).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            i = np.where(mins > 0, inter / np.maximum(mins, 1), 0.0)
        return np.where(inter > 0, i, 0.0)

    mu = 0.5 * (overlap(inter_f, size_f) + overlap(inter_r, size_r))
    np.fill_diagonal(mu, 1.0)
    return mu
