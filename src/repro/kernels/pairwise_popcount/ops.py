"""Public wrapper: all-pairs |Γ_A ∩ Γ_B| from boolean reachability rows."""
from __future__ import annotations

import jax

from ..registry import BackendLike, dispatch, register_op
from ..msbfs_expand.ops import pack_bits
from .kernel import pairwise_popcount_pallas
from .ref import intersections_bool_ref

__all__ = ["pairwise_intersections"]


register_op(
    "pairwise_popcount",
    pallas=lambda bits: pairwise_popcount_pallas(pack_bits(bits)),
    interpret=lambda bits: pairwise_popcount_pallas(pack_bits(bits),
                                                    interpret=True),
    jnp=intersections_bool_ref,
)


def pairwise_intersections(gamma_bits: jax.Array,
                           backend: BackendLike = None) -> jax.Array:
    """gamma_bits: (Q, V) bool -> (Q, Q) int32 intersection sizes."""
    return dispatch("pairwise_popcount", backend)(gamma_bits)
