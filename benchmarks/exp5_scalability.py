"""Exp-5 (Fig 11): scalability with graph size (20%..100% samples).

Paper claim: all engines grow with graph size; BatchEnum(+) stays fastest.
"""
from __future__ import annotations

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, record, time_planner


def main(scale: float = 1.0) -> list[dict]:
    rows = []
    for frac in [0.2, 0.4, 0.6, 0.8, 1.0]:
        g = default_graph(scale * frac, seed=6)
        eng = BatchPathEngine(g, EngineConfig(min_cap=128))
        qs = generators.similar_queries(g, 20, similarity=0.6,
                                        k_range=(5, 5), seed=7)
        t_basic, _ = time_planner(eng, qs, "basic")
        t_batch, _ = time_planner(eng, qs, "batch")
        rows.append(dict(frac=frac, n=g.n, m=g.m, t_basic=t_basic,
                         t_batch=t_batch))
        record(f"exp5_frac{frac:.1f}_basic", t_basic * 1e6, f"n={g.n};m={g.m}")
        record(f"exp5_frac{frac:.1f}_batch", t_batch * 1e6,
               f"speedup={t_basic / t_batch:.2f}")
    return rows


if __name__ == "__main__":
    main()
