"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf]."""
from ..config import LMConfig, MoEConfig
from ._shapes import LM_SHAPES as SHAPES  # noqa: F401

CONFIG = LMConfig(name="olmoe-1b-7b", n_layers=16, d_model=2048,
                  n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
                  qkv_bias=False,
                  moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024))

REDUCED = LMConfig(name="olmoe-reduced", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=96, vocab=256,
                   moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                 capacity_factor=2.0),
                   dtype="float32")

FAMILY = "lm"
