"""repro.obs — runtime observability for the batch/streaming pipeline.

Three layers, increasingly optional:

* :mod:`repro.obs.trace` — hierarchical span tracer (stdlib-only).
  Every hot module times its stages through ``Span`` handles; enabling
  the process tracer (``EngineConfig.trace`` / ``PathSession(trace=True)``
  / ``serve --trace``) records them into a ring buffer exportable as
  Chrome-trace/Perfetto JSON.
* :mod:`repro.obs.metrics` — process-wide registry of counters, gauges
  and log-bucketed histograms (cache hit/miss/evict/bytes, per-query
  latency p50/p95/p99/p99.9), with ``snapshot()/since()`` windowing and
  a plain-text exposition dump.
* :mod:`repro.obs.jaxprof` — opt-in ``jax.profiler`` bridge: span
  annotations on the device timeline, ``start_trace``/``stop_trace``
  capture, device-memory gauges.

``python -m repro.obs summarize <trace.json>`` aggregates a saved trace;
see ``docs/observability.md`` for the span taxonomy and metric names.
"""
from . import metrics, trace  # noqa: F401  (jaxprof imported lazily)
from .metrics import registry  # noqa: F401
from .trace import Span, Tracer, disable, enable, span, tracer  # noqa: F401

__all__ = ["trace", "metrics", "registry", "Span", "Tracer",
           "enable", "disable", "span", "tracer"]
