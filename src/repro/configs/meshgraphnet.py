"""meshgraphnet [gnn] — 15L d=128 sum-agg, 2-layer MLPs [arXiv:2010.03409]."""
from ..config import GNNConfig
from ._shapes import GNN_SHAPES as SHAPES  # noqa: F401

CONFIG = GNNConfig(name="meshgraphnet", kind="meshgraphnet", n_layers=15,
                   d_hidden=128, aggregator="sum", mlp_layers=2,
                   extras=(("d_out", 3),))

REDUCED = GNNConfig(name="meshgraphnet-reduced", kind="meshgraphnet",
                    n_layers=2, d_hidden=16, aggregator="sum", mlp_layers=2,
                    extras=(("d_out", 3),))

FAMILY = "gnn"
