"""Exp-3 (Fig 9): BatchEnum+ time decomposition.

Paper claim: Enumeration dominates; BuildIndex / ClusterQuery /
IdentifySubquery (detect) overheads are comparatively small.
"""
from __future__ import annotations

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, record


def main(scale: float = 1.0) -> dict:
    g = default_graph(scale, seed=2)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    qs = generators.similar_queries(g, 32, similarity=0.6, k_range=(5, 5),
                                    seed=3)
    res = eng.run(qs, planner="batch+")
    st = res.stats
    parts = {"BuildIndex": st["t_build_index"],
             "ClusterQuery": st["t_cluster"],
             "IdentifySubquery": st["t_detect"],
             "Enumeration": st["t_enumerate"]}
    total = sum(parts.values())
    for name, t in parts.items():
        record(f"exp3_{name}", t * 1e6, f"frac={t / total:.3f}")
    return parts


if __name__ == "__main__":
    main()
