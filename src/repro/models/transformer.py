"""Dense + MoE decoder-only transformer (the 5 assigned LM architectures).

Covers: GQA attention (optional QKV bias — Qwen), RoPE, RMSNorm, SwiGLU FFN
or MoE FFN, tied/untied embeddings, scan-over-layers with remat, chunked
cross-entropy (vocab stays tensor-sharded), prefill and KV-cache decode.

All attention goes through a chunked online-softmax implementation (the
jnp twin of kernels/flash_attention) so scores are never (S, S)-resident —
required for the 32k prefill dry-run cells; on TPU the Pallas kernel takes
over via the backend switch.

Sharding (logical axes; see models/sharding.py):
  params:  rows "fsdp", cols "tensor" (up) / rows "tensor", cols "fsdp" (down)
  acts:    batch "batch"; heads "tensor"; ffn hidden "tensor"
  decode KV cache: sequence axis "seq_kv" ("seq_kv_wide" when batch == 1)
Q heads are padded up to a multiple of the tensor axis when needed
(qwen2.5-14b: 40 -> 48 on a 16-way axis; zero-init extra heads are exact
no-ops); KV projections are replicated across "tensor" when
n_kv_heads < tensor size (standard GQA TP practice).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import LMConfig, RunOptions

__all__ = ["init_lm_params", "lm_param_logical", "lm_forward", "lm_loss",
           "prefill", "decode_step", "init_cache", "cache_logical",
           "padded_heads"]


def padded_heads(cfg: LMConfig, tp: int) -> int:
    return -(-cfg.n_heads // tp) * tp


def _dtype(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------

def init_lm_params(rng: jax.Array, cfg: LMConfig, tp: int = 1) -> dict:
    """f32 master params. Layer params stacked on a leading L axis (scan)."""
    L, D, hd = cfg.n_layers, cfg.d_model, cfg.hd
    Hq = padded_heads(cfg, tp)
    Hkv = cfg.n_kv_heads
    keys = jax.random.split(rng, 16)

    def norm(k, *shape, scale=1.0):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32) * scale
                / np.sqrt(fan_in))

    p: dict[str, Any] = {
        "embed": norm(keys[0], cfg.vocab, D, scale=1.0),
        "final_norm": jnp.ones((D,), jnp.float32),
        "layers": {
            "attn_norm": jnp.ones((L, D), jnp.float32),
            "ffn_norm": jnp.ones((L, D), jnp.float32),
            "wq": norm(keys[1], L, D, Hq * hd),
            "wk": norm(keys[2], L, D, Hkv * hd),
            "wv": norm(keys[3], L, D, Hkv * hd),
            "wo": norm(keys[4], L, Hq * hd, D),
        },
    }
    # zero the padded q heads so they are exact no-ops
    if Hq != cfg.n_heads:
        mask = (jnp.arange(Hq * hd) < cfg.n_heads * hd).astype(jnp.float32)
        p["layers"]["wq"] = p["layers"]["wq"] * mask[None, None, :]
        p["layers"]["wo"] = p["layers"]["wo"] * mask[None, :, None]
    if cfg.qkv_bias:
        p["layers"]["bq"] = jnp.zeros((L, Hq * hd), jnp.float32)
        p["layers"]["bk"] = jnp.zeros((L, Hkv * hd), jnp.float32)
        p["layers"]["bv"] = jnp.zeros((L, Hkv * hd), jnp.float32)
    if cfg.moe is None:
        p["layers"]["w_gate"] = norm(keys[5], L, D, cfg.d_ff)
        p["layers"]["w_up"] = norm(keys[6], L, D, cfg.d_ff)
        p["layers"]["w_down"] = norm(keys[7], L, cfg.d_ff, D)
    else:
        E, F = cfg.moe.n_experts, cfg.moe.d_ff_expert
        p["layers"]["router"] = norm(keys[8], L, D, E)
        p["layers"]["e_gate"] = norm(keys[9], L, E, D, F)
        p["layers"]["e_up"] = norm(keys[10], L, E, D, F)
        p["layers"]["e_down"] = norm(keys[11], L, E, F, D)
    if not cfg.tie_embeddings:
        p["unembed"] = norm(keys[12], D, cfg.vocab)
    return p


def lm_param_logical(cfg: LMConfig) -> dict:
    lay = {
        "attn_norm": (None, None),
        "ffn_norm": (None, None),
        "wq": (None, "fsdp", "tensor"),
        "wk": (None, "fsdp", None),       # KV replicated across tensor
        "wv": (None, "fsdp", None),
        "wo": (None, "tensor", "fsdp"),
    }
    if cfg.qkv_bias:
        lay.update({"bq": (None, "tensor"), "bk": (None, None),
                    "bv": (None, None)})
    if cfg.moe is None:
        lay.update({"w_gate": (None, "fsdp", "tensor"),
                    "w_up": (None, "fsdp", "tensor"),
                    "w_down": (None, "tensor", "fsdp")})
    else:
        lay.update({"router": (None, "fsdp", None),
                    "e_gate": (None, "expert", "fsdp", None),
                    "e_up": (None, "expert", "fsdp", None),
                    "e_down": (None, "expert", None, "fsdp")})
    out = {"embed": ("tensor", "fsdp"), "final_norm": (None,), "layers": lay}
    if not cfg.tie_embeddings:
        out["unembed"] = ("fsdp", "tensor")
    return out


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_offset, chunk: int = 1024,
                      kv_valid_len=None, return_stats: bool = False):
    """Online-softmax attention, never materializing (Sq, Skv).

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd). q_offset: scalar — absolute
    position of q[0] (decode). kv_valid_len: scalar — mask cache tail.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    # all matmuls stay in the input dtype with f32 accumulation
    # (preferred_element_type); converting K/V chunks to f32 lets XLA hoist
    # the convert out of both scans and materialize a full f32 cache copy
    # (measured +5 GiB/device on qwen110 decode_32k).
    qf = (q / np.sqrt(hd).astype(q.dtype)).transpose(0, 2, 1, 3)  # (B,Hq,Sq,hd)
    kf = k.transpose(0, 2, 1, 3)                  # (B,Hkv,Skv,hd), input dtype
    vf = v.transpose(0, 2, 1, 3)
    nchunk = -(-Skv // chunk)
    pad = nchunk * chunk - Skv
    kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kf = kf.reshape(B, Hkv, nchunk, chunk, hd).transpose(2, 0, 1, 3, 4)
    vf = vf.reshape(B, Hkv, nchunk, chunk, hd).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)
    valid_len = Skv if kv_valid_len is None else kv_valid_len

    def step(carry, inp):
        acc, m, l = carry
        idx, kc, vc = inp                     # kc: (B, Hkv, chunk, hd)
        if kc.dtype.itemsize == 1:            # f8-quantized KV: dequant chunk
            kc = kc.astype(jnp.bfloat16)
            vc = vc.astype(jnp.bfloat16)
        kv_pos = idx * chunk + jnp.arange(chunk)
        kq = jnp.repeat(kc, group, axis=1)    # (B, Hq, chunk, hd)
        vq = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhqd,bhcd->bhqc", qf, kq,
                       preferred_element_type=jnp.float32)
        mask = (kv_pos < valid_len)[None, None, None, :]
        if causal:
            mask = mask & (kv_pos[None, None, None, :] <= q_pos[None, None, :, None])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p.astype(vq.dtype), vq,
            preferred_element_type=jnp.float32)
        l = l * alpha + jnp.sum(p, axis=-1)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hq, Sq, hd), jnp.float32)
    m0 = jnp.full((B, Hq, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (jnp.arange(nchunk), kf, vf))
    if return_stats:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def flash_decode_attention(q, ck, cv, pos, opts: RunOptions):
    """Decode attention over a sequence-sharded KV cache WITHOUT gathering
    it: each model-shard computes online-softmax partials over its local
    S-slice; the combine is a pmax/psum of (B, Hq, 1[, hd]) stats — per-layer
    comm drops from O(B*S*Hkv*hd) to O(B*Hq*hd).

    q: (B, 1, Hq, hd) replicated over 'model'; ck/cv: (B, S, Hkv, hd) with S
    sharded over 'model'. Requires an ambient mesh with a 'model' axis.
    """
    mesh = jax.sharding.get_abstract_mesh()
    names = mesh.axis_names
    batch_axes = tuple(n for n in names if n in ("pod", "data"))
    from jax.sharding import PartitionSpec as P

    def local_attn(q_loc, k_loc, v_loc, pos_):
        S_loc = k_loc.shape[1]
        shard = jax.lax.axis_index("model")
        offset = shard * S_loc
        valid = jnp.clip(pos_ + 1 - offset, 0, S_loc)
        acc, m, l = chunked_attention(
            q_loc, k_loc, v_loc, causal=False, q_offset=0,
            chunk=min(opts.attn_chunk, S_loc), kv_valid_len=valid,
            return_stats=True)
        # handle empty shards (valid == 0): m = -inf, acc = 0, l = 0 already
        m_g = jax.lax.pmax(m, "model")
        scale = jnp.exp(m - m_g)
        acc = jax.lax.psum(acc * scale[..., None], "model")
        l = jax.lax.psum(l * scale, "model")
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q_loc.dtype)

    qs = P(batch_axes if batch_axes else None, None, None, None)
    kvs = P(batch_axes if batch_axes else None, "model", None, None)
    return jax.shard_map(local_attn, mesh=mesh,
                         in_specs=(qs, kvs, kvs, P()),
                         out_specs=qs, check_vma=False)(q, ck, cv, pos)


def _attention(q, k, v, *, causal, q_offset, opts: RunOptions, kv_valid_len=None):
    if opts.kernel_backend in ("pallas", "interpret"):
        from ..kernels.flash_attention.ops import gqa_attention
        return gqa_attention(q, k, v, causal=causal, backend=opts.kernel_backend)
    return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                             kv_valid_len=kv_valid_len,
                             chunk=min(opts.attn_chunk, k.shape[1]))


def swiglu(x, w_gate, w_up, w_down, constrain):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = constrain(h, ("batch", None, "tensor"))
    return h @ w_down


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def _residual_axes(opts: RunOptions, S: int, tp_ok: bool):
    """Residual-stream logical axes: sequence-parallel when enabled."""
    if opts.seq_parallel and tp_ok and S > 1:
        return ("batch", "seq", None)
    return ("batch", None, None)


def _layer(x, lp, cfg: LMConfig, opts: RunOptions, constrain, positions,
           cache=None, res_axes=("batch", None, None)):
    """One transformer block. cache: None or (k, v, pos) for decode."""
    dt = _dtype(cfg)
    B, S, D = x.shape
    hd = cfg.hd
    Hq = lp["wq"].shape[-1] // hd
    Hkv = cfg.n_kv_heads

    h = constrain(rmsnorm(x, lp["attn_norm"]), res_axes)
    q = h @ lp["wq"].astype(dt)
    k = h @ lp["wk"].astype(dt)
    v = h @ lp["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dt)
        k = k + lp["bk"].astype(dt)
        v = v + lp["bv"].astype(dt)
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = constrain(rope(q, positions, cfg.rope_theta),
                  ("batch", None, "tensor", None))
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        attn = _attention(q, k, v, causal=True, q_offset=0, opts=opts)
    else:
        ck, cv, pos = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
        if opts.flash_decode and S == 1:
            attn = flash_decode_attention(q, ck, cv, pos, opts)
        else:
            attn = _attention(q, ck, cv, causal=True, q_offset=pos, opts=opts,
                              kv_valid_len=pos + S)
        new_cache = (ck, cv)
    attn = constrain(attn, ("batch", None, "tensor", None))
    x = x + (attn.reshape(B, S, Hq * hd) @ lp["wo"].astype(dt))
    x = constrain(x, res_axes)

    h = constrain(rmsnorm(x, lp["ffn_norm"]), res_axes)
    if cfg.moe is None:
        f = swiglu(h, lp["w_gate"].astype(dt), lp["w_up"].astype(dt),
                   lp["w_down"].astype(dt), constrain)
        aux = jnp.float32(0.0)
    else:
        from .moe import moe_ffn
        f, aux = moe_ffn(h, lp, cfg, constrain, groups=opts.moe_groups)
    x = constrain(x + f, res_axes)
    return x, new_cache, aux


def lm_forward(params, tokens, cfg: LMConfig, opts: RunOptions, constrain,
               positions=None):
    """tokens: (B, S) int32 -> hidden states (B, S, D) + aux losses."""
    dt = _dtype(cfg)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    res_axes = _residual_axes(opts, S, S % 16 == 0)
    x = params["embed"].astype(dt)[tokens]
    x = constrain(x, res_axes)

    L = cfg.n_layers
    g = opts.layer_group if (opts.layer_group and L % opts.layer_group == 0) else 1
    layers = params["layers"]
    if opts.cast_params_early:
        # cast the sharded f32 master to bf16 BEFORE the scan: the per-layer
        # fsdp all-gathers then move bf16 (2x less ICI traffic) and the
        # per-layer converts disappear. Gradients still flow to f32 masters.
        layers = jax.tree.map(
            lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, layers)
    if g > 1:  # stack layers in groups: remat carry saved once per group
        layers = jax.tree.map(
            lambda a: a.reshape((L // g, g) + a.shape[1:]), layers)

    def body(carry, lp):
        x, aux = carry
        for i in range(g):
            lpi = jax.tree.map(lambda a: a[i], lp) if g > 1 else lp
            x, _, a = _layer(x, lpi, cfg, opts, constrain, positions,
                             res_axes=res_axes)
            aux = aux + a
        return (x, aux), ()

    layer_fn = body
    if opts.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if opts.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        layer_fn = jax.checkpoint(body, policy=policy)
    (x, aux), _ = jax.lax.scan(layer_fn, (x, jnp.float32(0.0)), layers)
    x = rmsnorm(x, params["final_norm"])
    return x, aux


def lm_loss(params, tokens, targets, cfg: LMConfig, opts: RunOptions,
            constrain):
    """Chunked cross-entropy over the (tensor-sharded) vocab."""
    x, aux = lm_forward(params, tokens, cfg, opts, constrain)
    dt = _dtype(cfg)
    unemb = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"]).astype(dt)
    B, S, D = x.shape
    C = min(opts.loss_chunk, S)
    nchunk = S // C
    xs = x.reshape(B, nchunk, C, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nchunk, C).transpose(1, 0, 2)

    def step(tot, inp):
        xc, tc = inp
        logits = (xc @ unemb).astype(jnp.float32)      # (B, C, V)
        logits = constrain(logits, ("batch", None, "tensor"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), ()

    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (xs, ts))
    ntok = B * S
    loss = tot / ntok
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / max(cfg.n_layers, 1)
    return loss


# ----------------------------------------------------------------------
# serving: prefill + decode
# ----------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_logical(wide: bool = False) -> dict:
    seq = "seq_kv_wide" if wide else "seq_kv"
    b = None if wide else "batch"
    return {"k": (None, b, seq, None, None),
            "v": (None, b, seq, None, None),
            "pos": ()}


def prefill(params, tokens, cfg: LMConfig, opts: RunOptions, constrain):
    """Full forward over the prompt; returns last-position logits."""
    x, _ = lm_forward(params, tokens, cfg, opts, constrain)
    dt = _dtype(cfg)
    unemb = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"]).astype(dt)
    logits = (x[:, -1:] @ unemb).astype(jnp.float32)
    return constrain(logits, ("batch", None, "tensor"))


def decode_step(params, token, cache, cfg: LMConfig, opts: RunOptions,
                constrain):
    """One token with KV cache. token: (B, 1) int32. Returns (logits, cache)."""
    dt = _dtype(cfg)
    B = token.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    x = params["embed"].astype(dt)[token]
    x = constrain(x, ("batch", None, None))

    def body(x, lp_and_cache):
        lp, ck, cv = lp_and_cache
        x, new_kv, _ = _layer(x, lp, cfg, opts, constrain, positions,
                              cache=(ck, cv, pos))
        return x, new_kv

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = rmsnorm(x, params["final_norm"])
    unemb = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"]).astype(dt)
    logits = (x @ unemb).astype(jnp.float32)
    logits = constrain(logits, ("batch", None, "tensor"))
    new_cache = {"k": nk, "v": nv, "pos": pos + 1}
    return logits, new_cache
