"""Pure-jnp oracle for path-pair overlap counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["path_overlap_ref", "rowwise_overlap_ref", "path_member_ref"]


def path_overlap_ref(a_verts: jax.Array, b_verts: jax.Array) -> jax.Array:
    eq = (a_verts[:, None, :, None] == b_verts[None, :, None, :])
    eq = eq & (a_verts >= 0)[:, None, :, None]
    return jnp.sum(eq.astype(jnp.int32), axis=(2, 3))


def rowwise_overlap_ref(a_verts: jax.Array, b_verts: jax.Array) -> jax.Array:
    """out[i] = #{(p, q): A[i, p] == B[i, q], A[i, p] >= 0} (row-aligned)."""
    eq = (a_verts[:, :, None] == b_verts[:, None, :])
    eq = eq & (a_verts >= 0)[:, :, None]
    return jnp.sum(eq.astype(jnp.int32), axis=(1, 2))


def path_member_ref(verts: jax.Array, cand: jax.Array) -> jax.Array:
    """out[i, d] = #{p: cand[i, d] == verts[i, p]} (per-row membership)."""
    eq = (cand[:, :, None] == verts[:, None, :])
    return jnp.sum(eq.astype(jnp.int32), axis=2)
