"""Pallas kernel: blocked causal GQA attention (FlashAttention-2 schedule).

LM-substrate hot spot for the assigned transformer architectures. Online
softmax over KV blocks -- the (S, S) score matrix is never materialized:

  for each (batch*q_head, q block):
      m, l, acc = -inf, 0, 0
      for kv block:                            # fori_loop, VMEM-resident KV
          s = q @ k^T * scale  (+ causal mask)
          m' = max(m, rowmax(s)); p = exp(s - m')
          acc = acc * exp(m - m') + p @ v; l = l * exp(m - m') + rowsum(p)
      out = acc / l

GQA: q-head h reads kv-head h // (Hq // Hkv); the kernel receives K/V
already indexed per q-head group so the BlockSpec stays rectangular.

Tiling: grid = (B * Hq, nQ). Per program: Q tile (BQ, Dh), K/V slices
(S, Dh) VMEM-resident (decode/serve shapes shard S across devices first;
for 32k x 128 x 2 x 4B = 32 MB the launcher splits the KV axis, this
kernel sees the local shard). MXU-aligned: BQ, Dh multiples of 128 where
possible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _make_kernel(block_k: int, causal: bool, scale: float, q_offset: int):
    def _kernel(q_ref, k_ref, v_ref, o_ref):
        q = q_ref[...][0]                      # (BQ, Dh)
        S = k_ref.shape[1]
        BQ, Dh = q.shape
        q_blk = pl.program_id(1)
        q_off = q_blk * BQ
        nk = pl.cdiv(S, block_k)

        def body(kb, carry):
            acc, m, l = carry
            k = jax.lax.dynamic_slice(k_ref[...][0], (kb * block_k, 0),
                                      (block_k, Dh))
            v = jax.lax.dynamic_slice(v_ref[...][0], (kb * block_k, 0),
                                      (block_k, Dh))
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            kv_pos = kb * block_k + jnp.arange(block_k)
            mask = kv_pos[None, :] < S
            if causal:
                # q_offset aligns decode-style queries (Sq < Skv) to the
                # tail of the KV axis, matching the reference.
                q_pos = q_off + jnp.arange(BQ) + q_offset
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m - m_new)
            acc = acc * alpha[:, None] + jnp.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32)
            l = l * alpha + jnp.sum(p, axis=1)
            return acc, m_new, l

        acc0 = jnp.zeros((BQ, Dh), jnp.float32)
        m0 = jnp.full((BQ,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((BQ,), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
        out = acc / jnp.maximum(l, 1e-30)[:, None]
        o_ref[...] = out[None].astype(o_ref.dtype)
    return _kernel


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, Dh); k, v: (BH, Skv, Dh) -- kv already per-q-head (GQA
    expansion done by the wrapper). Returns (BH, Sq, Dh).
    """
    BH, Sq, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    scale = 1.0 / (Dh ** 0.5)
    grid = (BH, pl.cdiv(Sq, bq))
    return pl.pallas_call(
        _make_kernel(min(block_k, Skv), causal, scale, Skv - Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, Dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, Dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
