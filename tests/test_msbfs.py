"""MS-BFS index vs host BFS oracle (+ packed kernel parity)."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core.graph import Graph, DeviceGraph
from repro.core.msbfs import msbfs_dist, INF_FOR
from repro.core.oracle import bfs_dist_from
from repro.core import generators


def _check(g: Graph, sources, k_max):
    dg = DeviceGraph.build(g)
    dist = np.asarray(msbfs_dist(dg.esrc, dg.edst, jnp.asarray(sources),
                                 n=g.n, k_max=k_max))
    INF = INF_FOR(k_max)
    for i, s in enumerate(sources):
        truth = bfs_dist_from(g, int(s), k_max)
        got = dist[:-1, i].astype(np.int32)
        got = np.where(got >= INF, k_max + 1, got)
        assert np.array_equal(got, truth), f"source {s}"
    assert np.all(dist[-1] == INF)  # sentinel row


@given(st.integers(5, 80), st.integers(0, 300), st.integers(1, 6),
       st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_msbfs_matches_oracle(n, m, k_max, seed):
    r = np.random.default_rng(seed)
    g = Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))
    sources = r.integers(0, n, size=min(8, n)).astype(np.int32)
    _check(g, sources, k_max)


def test_msbfs_reverse_direction():
    g = generators.erdos(60, 3.0, seed=7)
    dg = DeviceGraph.build(g)
    tgts = np.array([3, 11], np.int32)
    dist = np.asarray(msbfs_dist(dg.r_esrc, dg.r_edst, jnp.asarray(tgts),
                                 n=g.n, k_max=4))
    for i, t in enumerate(tgts):
        truth = bfs_dist_from(g, int(t), 4, reverse=True)
        got = np.where(dist[:-1, i] >= 5, 5, dist[:-1, i])
        assert np.array_equal(got.astype(np.int32), truth)


def test_msbfs_edge_chunking_invariant():
    g = generators.erdos(50, 4.0, seed=8)
    dg = DeviceGraph.build(g)
    srcs = jnp.asarray(np.array([0, 1, 2], np.int32))
    a = msbfs_dist(dg.esrc, dg.edst, srcs, n=g.n, k_max=4)
    b = msbfs_dist(dg.esrc, dg.edst, srcs, n=g.n, k_max=4, edge_chunk=17)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_packed_msbfs_hop_matches_dense():
    """kernels/msbfs_expand (interpret) == one unpacked msbfs hop."""
    from repro.kernels.msbfs_expand import ops as mops
    from repro.kernels.msbfs_expand.ref import pack_bits, unpack_bits
    from repro.core.msbfs import msbfs_hop
    g = generators.powerlaw(80, 4.0, seed=9)
    dg = DeviceGraph.build(g)
    r = np.random.default_rng(0)
    S = 37
    frontier = r.random((g.n + 1, S)) < 0.2
    frontier[-1] = False
    dense_next = np.asarray(msbfs_hop(jnp.asarray(frontier, jnp.int8),
                                      dg.esrc, dg.edst, g.n))
    # packed path uses the reverse-ELL (in-neighbors OR)
    words = pack_bits(jnp.asarray(frontier))
    nxt = mops.msbfs_hop_packed(dg.r_ell_idx, words, backend="interpret")
    unpacked = np.asarray(unpack_bits(nxt, S))
    assert np.array_equal(unpacked[:-1], dense_next[:-1].astype(bool))
