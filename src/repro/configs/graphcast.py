"""graphcast [gnn] — encoder-processor-decoder mesh GNN, 16L d=512,
n_vars=227 [arXiv:2212.12794].

Adaptation (DESIGN.md §5): the grid2mesh/mesh2grid bipartite stages of the
original run on *this* cell's assigned graph directly — the processor
(16 message-passing blocks at d=512) operates on the given node/edge set;
the encoder maps shape d_feat -> 512, the decoder emits the 227 variables.
mesh_refinement=6 governs the synthetic icosahedral generator in data/.
"""
from ..config import GNNConfig
from ._shapes import GNN_SHAPES as SHAPES  # noqa: F401

CONFIG = GNNConfig(name="graphcast", kind="graphcast", n_layers=16,
                   d_hidden=512, aggregator="sum", mlp_layers=2,
                   extras=(("d_out", 227), ("mesh_refinement", 6),
                           ("n_vars", 227)))

REDUCED = GNNConfig(name="graphcast-reduced", kind="graphcast", n_layers=2,
                    d_hidden=24, aggregator="sum", mlp_layers=2,
                    extras=(("d_out", 8), ("n_vars", 8)))

FAMILY = "gnn"
