"""Process-wide metrics registry: counters, gauges, log-bucketed histograms.

Spans (:mod:`repro.obs.trace`) answer "where did *this* run spend its
wall"; metrics answer the fleet questions — cache hit ratio over the last
thousand batches, p99 end-to-end query latency, bytes resident per cache.
The registry is deliberately tiny and stdlib-only:

* metrics are keyed by ``(name, labels)`` where labels are plain kwargs
  (``histogram("query_e2e_s", planner="hybrid", tenant="t0")``) —
  get-or-create, so instrumentation sites never need registration
  boilerplate;
* histograms use geometric (log-spaced) buckets, ~19% relative width,
  covering 1µs .. ~4000s — constant memory per histogram regardless of
  sample count, with p50/p95/p99/p99.9 readout interpolated inside the
  winning bucket and clamped to the observed min/max;
* :meth:`MetricsRegistry.snapshot` / :meth:`~MetricsRegistry.since`
  mirror :mod:`repro.core.compilelog`: take a snapshot, run a workload,
  and ``since(snap)`` gives the deltas for just that window — that is how
  tests isolate one engine's cache traffic from another's on the shared
  process registry;
* :meth:`MetricsRegistry.render` dumps a Prometheus-style plain-text
  exposition (``# TYPE`` comments, ``name{label="v"} value`` lines,
  ``_count``/``_sum``/``{quantile=...}`` for histograms) for scraping or
  eyeballing.

Like the tracer and the compile log, the default registry is a process
singleton (:func:`registry`). Instruments are cheap enough to update
unconditionally (a counter ``inc`` is one float add), so there is no
enable/disable gate — the readout is simply empty until something runs.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "registry", "DEFAULT_QUANTILES"]

DEFAULT_QUANTILES = (0.5, 0.95, 0.99, 0.999)

# Geometric bucket grid shared by every histogram: 1µs lower edge,
# factor 2**(1/4) (~+19%/bucket), enough buckets to pass ~4200s.
_BUCKET_LO = 1e-6
_BUCKET_FACTOR = 2.0 ** 0.25
_N_BUCKETS = 128
_BOUNDS = tuple(_BUCKET_LO * _BUCKET_FACTOR ** i for i in range(_N_BUCKETS))


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonic counter (float, so it can also accumulate bytes/seconds)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (e.g. resident cache bytes)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Log-bucketed histogram over positive samples (latencies, sizes).

    Samples below the first bucket edge land in bucket 0; above the last
    edge, in the overflow bucket. Quantiles interpolate within the
    winning bucket's geometric span and are clamped to the observed
    min/max, so small-sample readouts stay inside the data range.
    """

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * (_N_BUCKETS + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, x: float) -> None:
        x = float(x)
        self.counts[bisect.bisect_right(_BOUNDS, x) if x > 0 else 0] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def quantile(self, q: float) -> float:
        return _quantile_from_counts(self.counts, self.count, q,
                                     self.min, self.max)

    def quantiles(self, qs=DEFAULT_QUANTILES) -> dict:
        return {q: self.quantile(q) for q in qs}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _quantile_from_counts(counts, total: int, q: float,
                          lo_clamp: float, hi_clamp: float) -> float:
    """Quantile readout from bucket counts (shared with window views)."""
    if total <= 0:
        return 0.0
    rank = q * (total - 1)
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c > rank:
            # interpolate within this bucket's geometric span
            lo = _BOUNDS[i - 1] if 0 < i <= _N_BUCKETS else 0.0
            hi = _BOUNDS[i] if i < _N_BUCKETS else _BOUNDS[-1] * _BUCKET_FACTOR
            frac = (rank - cum) / c
            val = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            return min(max(val, lo_clamp), hi_clamp)
        cum += c
    return hi_clamp


class _HistogramWindow:
    """Delta view of a histogram between two snapshots (quantiles over
    just the window's samples)."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self, counts, count, total, mn, mx):
        self.counts = counts
        self.count = count
        self.sum = total
        self.min = mn
        self.max = mx

    def quantile(self, q: float) -> float:
        return _quantile_from_counts(self.counts, self.count, q,
                                     self.min, self.max)

    def quantiles(self, qs=DEFAULT_QUANTILES) -> dict:
        return {q: self.quantile(q) for q in qs}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create store of labeled instruments + snapshot/diff/render."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}        # (kind, name, labels) -> instrument

    def _get(self, kind: str, cls, name: str, labels: dict):
        key = (kind, name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, cls())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def collect(self, name: str) -> dict[tuple, object]:
        """Every instrument registered under ``name``, keyed by its label
        tuple (``(("tenant", "gold"),)`` → instrument). How a readout
        walks one metric family across label values — e.g. the per-tenant
        ``serve_admission_wait_s`` histograms — without knowing the label
        set up front."""
        return {key[2]: m for key, m in list(self._metrics.items())
                if key[1] == name}

    # -- snapshot / since (the compilelog pattern) ----------------------
    def snapshot(self) -> dict:
        """Immutable copy of all instrument states, for later ``since``."""
        snap = {}
        for key, m in list(self._metrics.items()):
            kind = key[0]
            if kind == "histogram":
                snap[key] = (tuple(m.counts), m.count, m.sum, m.min, m.max)
            else:
                snap[key] = m.value
        return snap

    def since(self, snap: dict) -> dict:
        """Window deltas vs. a snapshot.

        Counters/gauges map to value deltas; histograms map to
        :class:`_HistogramWindow` objects whose quantiles cover only the
        samples recorded after the snapshot.
        """
        out = {}
        for key, m in list(self._metrics.items()):
            kind, name, labels = key
            if kind == "histogram":
                c0, n0, s0, mn0, mx0 = snap.get(
                    key, ((0,) * len(m.counts), 0, 0.0, math.inf, -math.inf))
                dcounts = [a - b for a, b in zip(m.counts, c0)]
                dn = m.count - n0
                if dn <= 0:
                    continue
                # window min/max are not tracked incrementally; use the
                # lifetime bounds as conservative clamps
                out[(name, labels)] = _HistogramWindow(
                    dcounts, dn, m.sum - s0, m.min, m.max)
            else:
                d = m.value - snap.get(key, 0.0)
                if d != 0.0:
                    out[(name, labels)] = d
        return out

    # -- exposition -----------------------------------------------------
    def render(self, quantiles=DEFAULT_QUANTILES) -> str:
        """Prometheus-style plain-text dump of every instrument."""
        lines = []
        typed = set()
        for key in sorted(self._metrics, key=lambda k: (k[1], k[2], k[0])):
            kind, name, labels = key
            m = self._metrics[key]
            if name not in typed:
                lines.append(f"# TYPE {name} {kind}")
                typed.add(name)
            if kind == "histogram":
                lines.append(f"{name}_count{_fmt(labels)} {m.count}")
                lines.append(f"{name}_sum{_fmt(labels)} {_num(m.sum)}")
                for q in quantiles:
                    ql = labels + (("quantile", repr(q)),)
                    lines.append(f"{name}{_fmt(ql)} {_num(m.quantile(q))}")
            else:
                lines.append(f"{name}{_fmt(labels)} {_num(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


def _fmt(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() and abs(v) < 1e15 else repr(v)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide metrics registry."""
    return _REGISTRY
