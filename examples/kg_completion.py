"""Knowledge-graph completion support (paper §I application 3).

For candidate entity pairs, enumerate bounded-hop paths as relation-path
features: entities connected by many short paths tend to be related. Many
pairs share head/tail entities -> natural batch sharing.

    pip install -e .            # once (or: export PYTHONPATH=src)
    python examples/kg_completion.py
"""
import numpy as np

from repro.core import PathSession, EngineConfig
from repro.core import generators

kg = generators.community(15_000, n_comm=12, avg_deg=8.0, seed=3)
session = PathSession(kg, EngineConfig(gamma=0.4))

# candidate pairs around a few entities of interest (same head, many tails)
rng = np.random.default_rng(1)
heads = rng.integers(0, kg.n, 4)
pairs = []
for h in heads:
    # tails sampled from the 2-hop neighborhood (plausible missing links)
    frontier = {int(h)}
    for _ in range(2):
        nxt = set()
        for v in frontier:
            nxt.update(int(x) for x in kg.neighbors(v)[:8])
        frontier = nxt or frontier
    cands = list(frontier - {int(h)})[:6]
    pairs += [(int(h), t, 4) for t in cands]

report = session.run(pairs)      # bare (s, t, k) tuples coerce to PathQuery
print(f"{len(pairs)} candidate pairs scored")
scores = []
for i, (h, t, k) in enumerate(pairs):
    r = report[i]
    lens = [int((row >= 0).sum()) - 1 for row in r.paths]
    # path-count feature with length discount (PRA-style score)
    score = sum(0.5 ** (l - 1) for l in lens)
    scores.append((score, h, t, r.count))
scores.sort(reverse=True)
print("top predicted links (score, head, tail, n_paths):")
for s, h, t, n in scores[:8]:
    print(f"  {s:8.2f}  {h:6d} -> {t:6d}   ({n} paths)")
print("batch stats:", {k: v for k, v in report.stats.items()
                       if k.startswith("n_") or k == "mu_mean"})
