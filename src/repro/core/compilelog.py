"""Compile telemetry: a ``jax_log_compiles``-based retrace recorder.

Shape stability is the precondition for warm serving (the whole point of
the sentinel-padded pow2 buckets in ``graph.DeviceGraph``), but XLA
retraces are invisible unless you measure them — a drifting ``(m,)``
shape silently turns every post-delta batch into a cold compile. This
module turns jax's compile logging into a queryable counter so warm-
compile reuse is observable in production stats and assertable in tests:

    recorder = enable()              # process-wide, idempotent
    snap = recorder.snapshot()
    ...run a batch...
    recorder.since(snap)             # {kernel_name: new compiles}
    recorder.retraces_since(snap)    # compiles of already-known kernels

Mechanism: enabling flips the ``jax_log_compiles`` config flag, which
makes jax emit one ``"Compiling <name> with global shapes..."`` log
record per actual trace-cache miss (cached executions emit nothing); a
logging.Handler attached to the emitting jax loggers parses those records
into per-kernel counters. Propagation of the captured loggers is disabled
while recording so enabling telemetry does not spray compile warnings
over user output.

Definitions (shared by the engine stats and the test harness):

* a **compile** is any trace-cache miss, including the first (cold) one;
* a **retrace** is a compile of a kernel name that had already compiled
  before the observation window opened — i.e. work that warm serving
  should have reused.

jit caches are process-global, so the recorder is a process-global
singleton; like the rest of the serving stack it is not thread-safe.
"""
from __future__ import annotations

import logging
import re
from collections import Counter
from typing import Optional

__all__ = ["CompileLog", "enable", "active"]

# jax emits exactly one of these per XLA compilation when the
# jax_log_compiles flag is on (jax._src.interpreters.pxla); the dispatch
# logger's "Finished tracing/compilation ..." records deliberately do NOT
# match, so each compile is counted once.
_COMPILING_RE = re.compile(r"Compiling ([^\s]+) with global shapes")

# every logger jax has used for the compile message across recent versions
_JAX_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class CompileLog(logging.Handler):
    """Process-wide per-kernel compile counter (a logging.Handler)."""

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.counts: Counter = Counter()     # kernel name -> compiles
        self._installed = False
        self._saved_propagate: dict[str, bool] = {}

    # -- logging.Handler ----------------------------------------------
    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILING_RE.match(record.getMessage())
        if m:
            self.counts[m.group(1)] += 1

    # -- queries -------------------------------------------------------
    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def snapshot(self) -> dict[str, int]:
        """Copy of the per-kernel counters (an observation-window mark)."""
        return dict(self.counts)

    def since(self, snapshot: dict[str, int]) -> dict[str, int]:
        """Per-kernel compiles since ``snapshot`` (only non-zero entries)."""
        out = {}
        for name, c in self.counts.items():
            d = c - snapshot.get(name, 0)
            if d > 0:
                out[name] = d
        return out

    def compiles_since(self, snapshot: dict[str, int]) -> int:
        return sum(self.since(snapshot).values())

    def retraces_since(self, snapshot: dict[str, int]) -> int:
        """Compiles of kernels that were already compiled before the
        snapshot — the warm-serving regressions, as opposed to first-time
        (cold) compiles of kernels the window introduced."""
        return sum(c for name, c in self.since(snapshot).items()
                   if snapshot.get(name, 0) > 0)

    def annotate(self, stats: dict, snapshot: dict[str, int]) -> dict:
        """Write the standard telemetry fields for one observation window
        into ``stats`` (engine run reports, delta reports, batch logs)."""
        new = self.since(snapshot)
        stats["n_compiles"] = sum(new.values())
        stats["n_retraces"] = sum(c for name, c in new.items()
                                  if snapshot.get(name, 0) > 0)
        stats["compiled_kernels"] = new
        return stats

    # -- install -------------------------------------------------------
    def install(self) -> "CompileLog":
        if self._installed:
            return self
        import jax

        for name in _JAX_LOGGERS:
            logger = logging.getLogger(name)
            self._saved_propagate[name] = logger.propagate
            logger.addHandler(self)
            logger.propagate = False     # keep compile spam off user output
            if logger.level > logging.WARNING or logger.level == 0:
                logger.setLevel(logging.WARNING)
        jax.config.update("jax_log_compiles", True)
        self._installed = True
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        import jax

        jax.config.update("jax_log_compiles", False)
        for name in _JAX_LOGGERS:
            logger = logging.getLogger(name)
            logger.removeHandler(self)
            logger.propagate = self._saved_propagate.get(name, True)
        self._installed = False


_RECORDER: Optional[CompileLog] = None


def enable() -> CompileLog:
    """Install (or return the already-installed) process-wide recorder.

    Counters are cumulative for the process lifetime — consumers take
    snapshots and diff, they never reset, so any number of engines and
    tests can share the singleton without clobbering each other.
    """
    global _RECORDER
    if _RECORDER is None:
        _RECORDER = CompileLog()
    return _RECORDER.install()


def active() -> Optional[CompileLog]:
    """The installed recorder, or None when telemetry is off."""
    return _RECORDER if (_RECORDER is not None and _RECORDER._installed) \
        else None
