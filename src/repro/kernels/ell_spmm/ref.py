"""Pure-jnp oracle for padded-ELL SpMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ell_spmm_ref"]


def ell_spmm_ref(ell_idx: jax.Array, x: jax.Array, op: str = "sum") -> jax.Array:
    g = x[ell_idx]                          # (V, D, F); sentinel row is neutral
    if op == "sum":
        return jnp.sum(g, axis=1)
    return jnp.max(g, axis=1)
