"""two-tower-retrieval [recsys] — embed 256, towers 1024-512-256, dot
interaction, sampled softmax [RecSys'19 (YouTube)]."""
from ..config import RecsysConfig
from ._shapes import RECSYS_SHAPES as SHAPES  # noqa: F401

CONFIG = RecsysConfig(name="two-tower-retrieval", embed_dim=256,
                      tower_mlp=(1024, 512, 256), interaction="dot",
                      n_users=5_242_880, n_items=2_097_152, n_user_hist=20)

REDUCED = RecsysConfig(name="two-tower-reduced", embed_dim=16,
                       tower_mlp=(32, 16), interaction="dot",
                       n_users=1000, n_items=500, n_user_hist=5)

FAMILY = "recsys"
