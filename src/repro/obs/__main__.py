"""CLI over saved traces: ``python -m repro.obs summarize|export ...``.

summarize  per-span-name aggregates (count, total/mean/max ms), the
           stage-name set, and the root span's child coverage — the same
           numbers the CI obs gate checks.
export     filter/normalize a saved Chrome-trace JSON (name prefix,
           minimum duration) into a smaller file that still opens in
           chrome://tracing or https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import trace as obstrace


def _cmd_summarize(args) -> int:
    doc = obstrace.load(args.trace)
    rows = obstrace.summarize(doc)
    if not rows:
        print("no complete span events in trace")
        return 1
    w = max(len(r["name"]) for r in rows)
    print(f"{'span':<{w}}  {'count':>7}  {'total_ms':>10}  "
          f"{'mean_ms':>9}  {'max_ms':>9}")
    for r in rows:
        print(f"{r['name']:<{w}}  {r['count']:>7}  {r['total_ms']:>10.3f}  "
              f"{r['mean_ms']:>9.3f}  {r['max_ms']:>9.3f}")
    cov = obstrace.coverage(doc, root=args.root)
    print(f"\n{args.root} child coverage: {cov:.1%} "
          f"(stage durations / root wall, last occurrence)")
    return 0


def _cmd_export(args) -> int:
    doc = obstrace.load(args.trace)
    events = doc.get("traceEvents", [])
    kept = [e for e in events
            if e.get("ph") != "X"
            or (e.get("dur", 0.0) >= args.min_dur_us
                and (not args.filter or e["name"].startswith(args.filter)))]
    out = {"displayTimeUnit": doc.get("displayTimeUnit", "ms"),
           "traceEvents": kept}
    with open(args.out, "w") as f:
        json.dump(out, f)
    n_x = sum(1 for e in kept if e.get("ph") == "X")
    print(f"wrote {args.out}: {n_x} span events "
          f"(of {sum(1 for e in events if e.get('ph') == 'X')})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="inspect traces exported by repro.obs.trace")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summarize", help="per-span aggregates + coverage")
    s.add_argument("trace", help="Chrome-trace JSON from Tracer.export")
    s.add_argument("--root", default="engine.run",
                   help="root span for the coverage readout")
    s.set_defaults(fn=_cmd_summarize)

    e = sub.add_parser("export", help="filter a trace into a smaller file")
    e.add_argument("trace", help="Chrome-trace JSON from Tracer.export")
    e.add_argument("-o", "--out", required=True)
    e.add_argument("--filter", default="",
                   help="keep only span names with this prefix")
    e.add_argument("--min-dur-us", type=float, default=0.0,
                   help="drop spans shorter than this many microseconds")
    e.set_defaults(fn=_cmd_export)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
