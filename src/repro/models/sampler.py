"""Neighbor sampler for minibatch GNN training (GraphSAGE fanout sampling).

Host-side numpy (part of the input pipeline, like DGL/PyG samplers): given
roots and per-layer fanouts, uniformly samples in-neighbors layer by layer
and emits a padded edge-list block per layer plus the union node set with
remapped local ids — static shapes for jit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph import Graph, pow2_ceil

__all__ = ["SampledBlock", "sample_blocks"]


@dataclasses.dataclass
class SampledBlock:
    node_ids: np.ndarray        # (N_cap,) global ids (-1 pad)
    n_nodes: int
    edge_src: np.ndarray        # (E_cap,) local ids into node_ids
    edge_dst: np.ndarray
    edge_mask: np.ndarray       # (E_cap,) bool
    root_mask: np.ndarray       # (N_cap,) bool -- loss restricted to roots


def sample_blocks(g: Graph, roots: np.ndarray, fanouts: tuple[int, ...],
                  rng: np.random.Generator, node_cap: int | None = None,
                  edge_cap: int | None = None) -> SampledBlock:
    """Union-graph variant: one merged block over all hops (message passing
    runs n_layers times over the union edge set, as in full-graph mode)."""
    frontier = np.unique(roots)
    all_nodes = [frontier]
    src_l, dst_l = [], []
    for f in fanouts:
        deg = g.r_indptr[frontier + 1] - g.r_indptr[frontier]
        reps = np.minimum(deg, f).astype(np.int64)
        dst = np.repeat(frontier, reps)
        # uniform sample without replacement per node (cheap: random offsets)
        offs = []
        for v, r in zip(frontier, reps):
            lo, hi = g.r_indptr[v], g.r_indptr[v + 1]
            if r == hi - lo:
                offs.append(np.arange(lo, hi))
            else:
                offs.append(rng.choice(hi - lo, size=r, replace=False) + lo)
        if offs:
            src = g.r_indices[np.concatenate(offs)] if dst.size else np.zeros(0, np.int64)
        else:
            src = np.zeros(0, np.int64)
        src_l.append(src.astype(np.int64))
        dst_l.append(dst.astype(np.int64))
        frontier = np.unique(src)
        all_nodes.append(frontier)

    nodes = np.unique(np.concatenate(all_nodes))
    remap = {int(v): i for i, v in enumerate(nodes)}
    src = np.concatenate(src_l) if src_l else np.zeros(0, np.int64)
    dst = np.concatenate(dst_l) if dst_l else np.zeros(0, np.int64)
    src_loc = np.array([remap[int(v)] for v in src], np.int32)
    dst_loc = np.array([remap[int(v)] for v in dst], np.int32)

    n_cap = node_cap or pow2_ceil(max(nodes.size, 2))
    e_cap = edge_cap or pow2_ceil(max(src_loc.size, 2))
    node_ids = np.full(n_cap, -1, np.int64)
    node_ids[:nodes.size] = nodes
    es = np.zeros(e_cap, np.int32)
    ed = np.zeros(e_cap, np.int32)
    em = np.zeros(e_cap, bool)
    es[:src_loc.size] = src_loc
    ed[:dst_loc.size] = dst_loc
    em[:src_loc.size] = True
    root_mask = np.zeros(n_cap, bool)
    root_set = set(int(r) for r in roots)
    for i, v in enumerate(nodes):
        if int(v) in root_set:
            root_mask[i] = True
    return SampledBlock(node_ids=node_ids, n_nodes=int(nodes.size),
                        edge_src=es, edge_dst=ed, edge_mask=em,
                        root_mask=root_mask)
