"""Public wrapper: one bit-packed MS-BFS hop with backend switch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import resolve_backend
from .kernel import msbfs_expand_pallas
from .ref import msbfs_expand_ref, pack_bits, unpack_bits

__all__ = ["msbfs_hop_packed", "pack_bits", "unpack_bits"]


def msbfs_hop_packed(ell_idx: jax.Array, frontier_words: jax.Array,
                     backend: str | None = None) -> jax.Array:
    """frontier_words: (V+1, W) uint32 with sentinel row V zeroed.

    Returns (V+1, W) next frontier (sentinel row re-zeroed).
    """
    backend = resolve_backend(backend)
    fw = frontier_words.at[-1].set(jnp.uint32(0))
    if backend == "pallas":
        nxt = msbfs_expand_pallas(ell_idx, fw)
    elif backend == "interpret":
        nxt = msbfs_expand_pallas(ell_idx, fw, interpret=True)
    else:
        nxt = msbfs_expand_ref(ell_idx, fw)
    zero = jnp.zeros((1, nxt.shape[1]), jnp.uint32)
    return jnp.concatenate([nxt, zero], axis=0)
