"""Graph container + generator invariants."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core.graph import Graph, DeviceGraph
from repro.core import generators


def random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    return Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))


class TestGraph:
    def test_csr_roundtrip(self):
        g = Graph.from_edges(4, [0, 0, 1, 2], [1, 2, 2, 3])
        assert g.n == 4 and g.m == 4
        assert list(g.neighbors(0)) == [1, 2]
        assert list(g.neighbors(2, reverse=True)) == [0, 1]

    def test_dedup_and_self_loops(self):
        g = Graph.from_edges(3, [0, 0, 1, 1], [1, 1, 1, 2])
        assert g.m == 2  # dup (0,1) removed, self loop (1,1) removed

    @given(st.integers(5, 60), st.integers(0, 200), st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_reverse_is_involution(self, n, m, seed):
        g = random_graph(n, m, seed)
        gr = g.reverse()
        assert np.array_equal(gr.indptr, g.r_indptr)
        for v in range(n):
            assert sorted(gr.neighbors(v)) == sorted(g.neighbors(v, reverse=True))

    @given(st.integers(5, 60), st.integers(1, 200), st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_ell_covers_all_edges(self, n, m, seed):
        g = random_graph(n, m, seed)
        ell = g.ell()
        edges = set()
        for v in range(n):
            for d in range(ell.cap):
                if ell.mask[v, d]:
                    edges.add((v, int(ell.idx[v, d])))
        truth = {(int(s), int(t)) for s in range(n)
                 for t in g.neighbors(s)}
        assert edges == truth
        assert ell.spill_src.size == 0

    def test_ell_spill(self):
        g = Graph.from_edges(5, [0, 0, 0, 0], [1, 2, 3, 4])
        ell = g.ell(cap=2)
        assert ell.spill_src.size == 2
        assert set(ell.spill_dst) | {int(x) for x in ell.idx[0] if x != 5} \
            == {1, 2, 3, 4}

    def test_edges_by_dst_sorted(self):
        g = random_graph(30, 100, 1)
        src, dst = g.edges_by_dst
        assert np.all(np.diff(dst) >= 0)
        assert src.shape == dst.shape == (g.m,)

    def test_device_graph(self):
        g = random_graph(20, 60, 2)
        dg = DeviceGraph.build(g)
        assert dg.n == g.n and dg.m == g.m
        assert dg.ell_idx.shape[0] == g.n

    def test_device_graph_pow2_buckets(self):
        from repro.core.graph import pow2_ceil
        g = random_graph(20, 60, 2)
        dg = DeviceGraph.build(g)
        # edge lists sentinel-padded to the pow2 bucket, ELL caps bucketed
        assert dg.m_cap == pow2_ceil(g.m) and dg.m_valid == g.m
        for esrc, edst in ((dg.esrc, dg.edst), (dg.r_esrc, dg.r_edst)):
            assert esrc.shape == edst.shape == (dg.m_cap,)
            assert np.all(np.asarray(esrc)[g.m:] == g.n)
            assert np.all(np.asarray(edst)[g.m:] == g.n)
            assert np.all(np.diff(np.asarray(edst)) >= 0)  # stays dst-sorted
        assert dg.ell_cap == pow2_ceil(int(g.out_degree().max()))
        assert dg.r_ell_cap == pow2_ceil(int(g.in_degree().max()))
        # pad=False restores the exact legacy shapes
        dgx = DeviceGraph.build(g, pad=False)
        assert dgx.m_cap == g.m
        assert dgx.ell_cap == int(g.out_degree().max())

    def test_device_graph_empty_graph_pads_to_one_sentinel(self):
        g = Graph.from_edges(4, [], [])
        dg = DeviceGraph.build(g)
        assert dg.m == 0 and dg.m_cap == 1
        assert int(dg.esrc[0]) == g.n and int(dg.edst[0]) == g.n


class TestGenerators:
    @pytest.mark.parametrize("gen,kw", [
        (generators.powerlaw, {}), (generators.erdos, {}),
        (generators.community, {"n_comm": 3})])
    def test_generators_basic(self, gen, kw):
        g = gen(200, avg_deg=4.0, seed=3, **kw)
        assert g.n == 200
        assert 0 < g.m <= 200 * 4.5
        assert g.indices.max() < 200

    def test_grid_degree(self):
        g = generators.grid(5)
        assert g.n == 25
        assert np.all(g.out_degree() == 4)

    def test_random_queries_reachable(self):
        from repro.core.oracle import bfs_dist_from
        g = generators.erdos(100, 4.0, seed=4)
        qs = generators.random_queries(g, 10, (2, 5), seed=5)
        for s, t, k in qs:
            assert bfs_dist_from(g, s, k)[t] <= k
