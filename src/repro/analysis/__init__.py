"""Two-layer static analysis of the hot query path.

Layer 1 (:mod:`.astlint`) lints the source tree for trace-invariant
violations (RPL001-RPL005: host syncs in jit-reachable code, kernel math
bypassing the registry, missing static declarations, Python loops over
device arrays, raw pow2 shape math). Layer 2 (:mod:`.jaxpr_audit`)
abstractly traces the hot-function manifest per backend and checks the
jaxprs themselves: no host callbacks, per-level dispatch counts within the
committed budgets, int8 bounds proven, no value-dependent retraces, and
full registry-op coverage.

CLI: ``python -m repro.analysis --all`` (the CI ``lint-deep`` job); exits
nonzero iff any unwaived finding remains.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from .astlint import lint_source, lint_tree
from .jaxpr_audit import measure_budgets, run_audit
from .report import AnalysisReport, Finding

__all__ = ["AnalysisReport", "Finding", "lint_source", "lint_tree",
           "run_audit", "run_all", "measure_budgets"]


def run_all(root: Optional[Path] = None,
            budgets_path: Optional[Path] = None) -> AnalysisReport:
    """Run both layers over ``root`` (default: the installed ``repro``
    package tree) and merge into one report."""
    if root is None:
        root = Path(__file__).resolve().parents[1]
    report = lint_tree(Path(root))
    report.extend(run_audit(budgets_path))
    return report
