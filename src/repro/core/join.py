"""Path concatenation ⊕ (Def 3.1) as static-shape sort/searchsorted joins.

Two flavours:

  * keyed_join   -- the bidirectional final join: forward paths of length
                    exactly `a` matched with backward paths on the shared
                    last vertex (hash join -> sort + searchsorted bucket
                    join; each output path is produced exactly once).
  * cross_join   -- the splice join: (prefix x cached child suffix), no key
                    (the prefix's appended vertex == child's source).

Both enumerate pair-ids into a static `out_cap` buffer with an overflow
flag, assemble the concatenated vertex rows, and apply the vectorized
simple-path (duplicate-vertex) filter -- the O(L^2) check the paper does
per emitted path (Alg 1 line 8 / Alg 4 line 13).

Backend routing (static ``backend`` arg, a resolved kernel-backend value):
the ``jnp`` path materializes the assembled rows and runs the dense
``_dup_mask`` pairwise-equality check; the kernel path
(``pallas``/``interpret``) replaces it with one row-aligned overlap
dispatch (kernels/path_join.rowwise_overlap) over the *half* rows:

  * keyed join : both halves are simple and share the key vertex, so the
    assembled row has a duplicate  <=>  overlap(A[:a+1], B[:b+1]) >= 2,
    i.e. valid <=> key match & overlap == 1.
  * cross join : prefix and child are each simple, so a duplicate
    <=>  overlap(prefix, child) >= 1, i.e. valid <=> overlap == 0.

The equivalence relies on the engine invariant that every half row is
itself simple (frontier paths and cached suffixes are, by construction);
``_dup_mask`` additionally detects in-half duplicates, which cannot occur
on engine inputs — property tests pin the two paths bit-equal there.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .pathset import PathSet, compact_rows

__all__ = ["sort_by_last", "keyed_join", "keyed_join_count", "cross_join",
           "SortedSide"]


class SortedSide(NamedTuple):
    verts: jax.Array   # (cap, L) rows sorted by key (invalid rows last)
    keys: jax.Array    # (cap,) sorted keys (invalid = big sentinel)
    count: jax.Array


@partial(jax.jit, static_argnames=("col",))
def sort_by_last(verts: jax.Array, count: jax.Array, *, col: int) -> SortedSide:
    cap = verts.shape[0]
    valid = jnp.arange(cap) < count
    keys = jnp.where(valid, verts[:, col], jnp.int32(2**31 - 1))
    order = jnp.argsort(keys)
    return SortedSide(verts=verts[order], keys=keys[order], count=count)


def _dup_mask(assembled: jax.Array, width: int) -> jax.Array:
    """True where a row contains a repeated (non-negative) vertex."""
    a = assembled[:, :, None]
    b = assembled[:, None, :]
    eq = (a == b) & (a >= 0)
    iu = jnp.triu(jnp.ones((width, width), bool), k=1)
    return (eq & iu[None]).any((1, 2))


def _join_ok_keyed(a_rows: jax.Array, b_full: jax.Array, assembled: jax.Array,
                   width: int, backend: str) -> jax.Array:
    """Simple-path validity for keyed-join candidates (see module docstring):
    the jnp route checks the assembled row densely; the kernel route runs
    one row-aligned overlap dispatch over the halves (valid <=> the key
    vertex is the only shared one)."""
    if backend == "jnp":
        return ~_dup_mask(assembled, width)
    from ..kernels.path_join.ops import rowwise_overlap
    return rowwise_overlap(a_rows, b_full, backend=backend) == 1


def _join_ok_cross(p_rows: jax.Array, c_rows: jax.Array, assembled: jax.Array,
                   width: int, backend: str) -> jax.Array:
    """Simple-path validity for splice-join candidates: prefix and child are
    vertex-disjoint <=> their row-aligned overlap count is zero."""
    if backend == "jnp":
        return ~_dup_mask(assembled, width)
    from ..kernels.path_join.ops import rowwise_overlap
    return rowwise_overlap(p_rows, c_rows, backend=backend) == 0


def _enumerate_pairs(a: SortedSide, b_verts: jax.Array, b_count: jax.Array,
                     b_col: int, out_cap: int):
    """Key-bucket pair enumeration shared by the materializing and
    counting keyed joins: map pair id i -> (A row, B row) over rows
    sharing the last vertex. Returns (a_pos, b_idx, pair_valid, total)
    with pair ids beyond out_cap dropped (total still exact).
    """
    b_cap = b_verts.shape[0]
    b_valid = jnp.arange(b_cap) < b_count
    b_keys = jnp.where(b_valid, b_verts[:, b_col], jnp.int32(-7))  # never matches
    lo = jnp.searchsorted(a.keys, b_keys, side="left")
    hi = jnp.searchsorted(a.keys, b_keys, side="right")
    cnt = (hi - lo) * b_valid
    offs = jnp.cumsum(cnt)
    total = offs[-1] if b_cap > 0 else jnp.int32(0)

    i = jnp.arange(out_cap)
    pair_valid = i < jnp.minimum(total, out_cap)
    b_idx = jnp.searchsorted(offs, i, side="right")
    b_idx = jnp.minimum(b_idx, b_cap - 1)
    prev = jnp.where(b_idx > 0, offs[jnp.maximum(b_idx - 1, 0)], 0)
    a_pos = lo[b_idx] + (i - prev)
    a_pos = jnp.clip(a_pos, 0, a.verts.shape[0] - 1)
    return a_pos, b_idx, pair_valid, total


@partial(jax.jit,
         static_argnames=("a_col", "b_col", "out_cap", "out_width", "backend"))
def keyed_join(a: SortedSide, b_verts: jax.Array, b_count: jax.Array,
               *, a_col: int, b_col: int, out_cap: int, out_width: int,
               backend: str = "jnp") -> PathSet:
    """⊕ join: A rows (forward, last col = a_col) with B rows (backward,
    last col = b_col) sharing the last vertex.

    Output row = A[0..a_col] ++ reversed(B[0..b_col-1])   (B's join vertex
    and direction folded away), so out length = a_col + b_col hops.
    """
    a_pos, b_idx, pair_valid, total = _enumerate_pairs(
        a, b_verts, b_count, b_col, out_cap)

    a_rows = a.verts[a_pos][:, :a_col + 1]                  # (out_cap, a_col+1)
    b_full = b_verts[b_idx][:, :b_col + 1]                  # incl. key vertex
    b_rev = b_full[:, :b_col][:, ::-1]                      # x_{b-1} ... x_1, t
    assembled = jnp.full((out_cap, out_width), -1, jnp.int32)
    assembled = assembled.at[:, :a_col + 1].set(a_rows)
    assembled = assembled.at[:, a_col + 1:a_col + 1 + b_col].set(b_rev)
    assembled = jnp.where(pair_valid[:, None], assembled, -1)

    ok = pair_valid & _join_ok_keyed(a_rows, b_full, assembled, out_width,
                                     backend)
    out, n_out, ovf = compact_rows(ok, assembled, out_cap)
    return PathSet(out, n_out, ovf | (total > out_cap))


@partial(jax.jit, static_argnames=("a_col", "b_col", "pair_cap", "backend"))
def keyed_join_count(a: SortedSide, b_verts: jax.Array, b_count: jax.Array,
                     *, a_col: int, b_col: int, pair_cap: int,
                     backend: str = "jnp") -> tuple[jax.Array, jax.Array]:
    """Count ⊕-join results without assembling an output PathSet.

    Same pair enumeration and simple-path filter as :func:`keyed_join`, but
    the joined rows exist only transiently for the duplicate-vertex check —
    no output buffer, no cumsum compaction, nothing to transfer to host but
    a scalar. Returns ``(n_results, overflow)``; overflow means the raw
    pair count exceeded ``pair_cap`` and the caller must retry larger.
    """
    a_pos, b_idx, pair_valid, total = _enumerate_pairs(
        a, b_verts, b_count, b_col, pair_cap)

    width = a_col + 1 + b_col
    a_rows = a.verts[a_pos][:, :a_col + 1]
    b_full = b_verts[b_idx][:, :b_col + 1]
    assembled = jnp.concatenate([a_rows, b_full[:, :b_col][:, ::-1]], axis=1)
    assembled = jnp.where(pair_valid[:, None], assembled, -1)
    ok = pair_valid & _join_ok_keyed(a_rows, b_full, assembled, width, backend)
    return ok.sum(dtype=jnp.int32), total > pair_cap


@partial(jax.jit,
         static_argnames=("p_col", "c_col", "out_cap", "out_width", "backend"))
def cross_join(p_verts: jax.Array, p_count: jax.Array,
               c_verts: jax.Array, c_count: jax.Array,
               *, p_col: int, c_col: int, out_cap: int, out_width: int,
               backend: str = "jnp") -> PathSet:
    """Splice join: every prefix (cols 0..p_col) × every cached child path
    (cols 0..c_col; child path starts at the spliced vertex).

    Output row = prefix ++ child, out length = (p_col) + 1 + c_col hops
    counting the prefix->child edge.
    """
    i = jnp.arange(out_cap)
    total = p_count * c_count
    pair_valid = i < jnp.minimum(total, out_cap)
    denom = jnp.maximum(c_count, 1)
    p_idx = jnp.minimum(i // denom, jnp.maximum(p_count - 1, 0))
    c_idx = jnp.minimum(i % denom, jnp.maximum(c_count - 1, 0))

    p_rows = p_verts[p_idx][:, :p_col + 1]
    c_rows = c_verts[c_idx][:, :c_col + 1]
    assembled = jnp.full((out_cap, out_width), -1, jnp.int32)
    assembled = assembled.at[:, :p_col + 1].set(p_rows)
    assembled = assembled.at[:, p_col + 1:p_col + 2 + c_col].set(c_rows)
    assembled = jnp.where(pair_valid[:, None], assembled, -1)

    ok = pair_valid & _join_ok_cross(p_rows, c_rows, assembled, out_width,
                                     backend)
    out, n_out, ovf = compact_rows(ok, assembled, out_cap)
    return PathSet(out, n_out, ovf | (total > out_cap))
