"""Engine correctness: every mode vs the brute-force DFS oracle, plus
result-set invariants as hypothesis properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BatchPathEngine, EngineConfig
from repro.core.graph import Graph
from repro.core import generators
from repro.core.oracle import enumerate_paths_bruteforce, path_set

MODES = ["basic", "basic+", "batch", "batch+", "pathenum"]


def _run_and_compare(g, qs, mode, cfg=None):
    eng = BatchPathEngine(g, cfg or EngineConfig(min_cap=64))
    res = eng.process(qs, mode=mode)
    for qi, (s, t, k) in enumerate(qs):
        got_list = [tuple(int(x) for x in row if x >= 0)
                    for row in res.paths[qi]]
        got = set(got_list)
        truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
        assert len(got_list) == len(got), f"{mode} q{qi}: duplicate paths"
        assert got == truth, (f"{mode} q{qi}: {len(got)} vs {len(truth)}; "
                              f"missing {sorted(truth - got)[:3]} "
                              f"extra {sorted(got - truth)[:3]}")
    return res


@pytest.mark.parametrize("mode", MODES)
def test_modes_match_oracle_erdos(mode):
    g = generators.erdos(70, 3.0, seed=1)
    qs = generators.random_queries(g, 6, (2, 5), seed=2)
    _run_and_compare(g, qs, mode)


@pytest.mark.parametrize("mode", ["basic", "batch", "batch+"])
def test_modes_match_oracle_powerlaw(mode):
    g = generators.powerlaw(120, 3.0, seed=3)
    qs = generators.random_queries(g, 6, (3, 5), seed=4)
    _run_and_compare(g, qs, mode)


def test_batch_community_high_similarity():
    """Community graphs: heavy sharing; paper-faithful shared-node setting."""
    g = generators.community(90, n_comm=3, avg_deg=4.0, seed=5)
    qs = generators.similar_queries(g, 8, similarity=0.9, k_range=(3, 4),
                                    seed=6)
    res = _run_and_compare(g, qs, "batch",
                           EngineConfig(min_cap=64,
                                        paper_faithful_shares=True))
    assert res.stats["n_clusters"] >= 1


def test_k_edge_cases():
    g = generators.erdos(40, 3.0, seed=7)
    qs = generators.random_queries(g, 5, (1, 2), seed=8)
    for mode in ["basic", "batch"]:
        _run_and_compare(g, qs, mode)


def test_duplicate_and_nested_queries():
    g = generators.erdos(50, 3.0, seed=9)
    base = generators.random_queries(g, 3, (3, 4), seed=10)
    qs = base + [base[0], (base[1][0], base[1][1], 2)]
    _run_and_compare(g, qs, "batch")


def test_rejects_degenerate_queries():
    g = generators.erdos(20, 2.0, seed=11)
    eng = BatchPathEngine(g)
    with pytest.raises(ValueError):
        eng.process([(3, 3, 4)])
    with pytest.raises(ValueError):
        eng.process([(0, 1, 0)])


@given(st.integers(10, 60), st.integers(10, 160), st.integers(0, 30),
       st.integers(2, 5))
@settings(max_examples=12, deadline=None)
def test_property_batch_equals_oracle(n, m, seed, k):
    """Property: for ANY random digraph and query set, batch mode returns
    exactly the oracle's simple-path set (no dupes, no misses)."""
    r = np.random.default_rng(seed)
    g = Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))
    pairs = set()
    while len(pairs) < 4:
        s, t = int(r.integers(0, n)), int(r.integers(0, n))
        if s != t:
            pairs.add((s, t))
    qs = [(s, t, k) for s, t in pairs]
    _run_and_compare(g, qs, "batch")


@given(st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_property_results_are_simple_and_bounded(seed):
    g = generators.powerlaw(80, 3.0, seed=seed)
    qs = generators.random_queries(g, 4, (3, 5), seed=seed + 50)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))
    res = eng.process(qs, mode="batch")
    edge_set = {(int(s), int(t)) for s in range(g.n) for t in g.neighbors(s)}
    for qi, (s, t, k) in enumerate(qs):
        for row in res.paths[qi]:
            p = [int(x) for x in row if x >= 0]
            assert p[0] == s and p[-1] == t
            assert len(p) - 1 <= k                      # hop constraint
            assert len(set(p)) == len(p)                # simple
            for a, b in zip(p, p[1:]):                  # real edges
                assert (a, b) in edge_set
