"""Exp-8: cross-batch HC-s path cache — repeated/overlapping batch speedup.

Serving traffic repeats itself: the same (or heavily overlapping) query
batches arrive again and again. This experiment runs the batch engine with
the ``SharedPathCache`` enabled and measures, per round:

  * Ψ-node materializations (engine stat ``n_materialized``) — the paper's
    unit of shared enumeration work — cold vs warm,
  * warm-batch wall time vs the cacheless engine on the identical batch,
  * oracle validation that cached results are exactly right.

Acceptance target: a warm batch of identical queries materializes >= 30%
fewer Ψ nodes than the cold batch (in practice it is ~100%).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from repro.core.oracle import enumerate_paths_bruteforce, path_set

from .common import record


def _run(engine, queries):
    t0 = time.perf_counter()
    res = engine.run(queries)
    return time.perf_counter() - t0, res


def main(scale: float = 1.0) -> dict:
    n = max(300, int(4000 * scale))
    g = generators.community(n, n_comm=max(2, n // 1500), avg_deg=5.0, seed=0)
    queries = generators.similar_queries(g, max(8, int(24 * min(scale, 1.0))),
                                         similarity=0.8, k_range=(3, 4),
                                         seed=1)

    cached = BatchPathEngine(g, EngineConfig(min_cap=128,
                                             cache_bytes=256 << 20))
    plain = BatchPathEngine(g, EngineConfig(min_cap=128))

    # warm both jit caches so wall times compare enumeration, not compiles
    _run(plain, queries)
    t_cold, r_cold = _run(cached, queries)
    t_warm, r_warm = _run(cached, queries)
    t_plain, r_plain = _run(plain, queries)

    mat_cold = r_cold.stats["n_materialized"]
    mat_warm = r_warm.stats["n_materialized"]
    reduction = 1.0 - mat_warm / max(mat_cold, 1)
    record("exp8_cold_batch", t_cold * 1e6,
           f"materialized={mat_cold}/{r_cold.stats['n_psi_nodes']}")
    record("exp8_warm_batch", t_warm * 1e6,
           f"materialized={mat_warm} hits={r_warm.stats['n_cache_hits']} "
           f"reduction={reduction:.2f} speedup={t_plain / max(t_warm, 1e-9):.2f}x")

    # overlapping wave: half repeats, half new
    overlap = queries[:len(queries) // 2] + generators.similar_queries(
        g, len(queries) - len(queries) // 2, similarity=0.8,
        k_range=(3, 4), seed=2)
    t_ovl, r_ovl = _run(cached, overlap)
    record("exp8_overlap_batch", t_ovl * 1e6,
           f"materialized={r_ovl.stats['n_materialized']}"
           f"/{r_ovl.stats['n_psi_nodes']} "
           f"hits={r_ovl.stats['n_cache_hits']}")

    # oracle validation of warm results (sampled: the oracle is slow)
    rng = np.random.default_rng(0)
    sample = rng.choice(len(queries), size=min(4, len(queries)), replace=False)
    for qi in sample:
        s, t, k = queries[qi]
        truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
        assert path_set(r_warm[qi].paths) == truth, f"warm q{qi} != oracle"
        assert path_set(r_cold[qi].paths) == truth, f"cold q{qi} != oracle"
    assert reduction >= 0.30, (
        f"warm batch must materialize >=30% fewer Ψ nodes, got {reduction:.2f}")
    return {"n": n, "n_queries": len(queries),
            "mat_cold": mat_cold, "mat_warm": mat_warm,
            "reduction": reduction, "t_cold_s": t_cold, "t_warm_s": t_warm,
            "t_plain_s": t_plain, "cache": cached.cache.info(),
            "oracle_validated": int(len(sample))}


if __name__ == "__main__":
    main()
