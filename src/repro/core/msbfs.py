"""Bit-parallel multi-source BFS (the paper's BuildIndex, Alg 1/4 lines 1-2).

TPU adaptation of "The More the Merrier" MS-BFS [36]: instead of per-source
queues, the frontier is a dense (n+1, S) int8/bool matrix (one column per
source; row n is a sentinel for padded ELL gathers). One hop is an
edge-gather + ``segment_max`` (max == OR on {0,1}), i.e. a sparse-matrix ×
dense-frontier product in the boolean semiring — MXU/VPU-friendly and
shardable.

Two backends:
  * ``jnp``    -- reference path used everywhere (chunked edge gathers).
  * ``pallas`` -- bit-packed ELL OR-gather kernel (kernels/msbfs_expand),
                  validated against this reference in interpret mode.

Distances are int8 (k_max <= 120); unreached = INF = k_max + 1.

Sentinel padding: edge lists may be pow2-bucketed with sentinel edges
``(n, n)`` (``graph.pad_edge_list``). A sentinel edge gathers the all-zero
frontier row ``n`` and its ``edst = n`` falls outside ``num_segments = n``,
so segment reductions drop it — padded and exact edge lists are
bit-equivalent. Callers pass ``m_valid`` (the chunk-rounded valid-edge
span from :func:`edge_span`) so the chunk loop skips all-sentinel chunks;
it is a static jit argument, which is why it must be pre-rounded — raw
per-delta edge counts would retrace on every mutation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["msbfs_dist", "msbfs_set_dist", "msbfs_hop", "INF_FOR",
           "edge_span"]


def INF_FOR(k_max: int) -> int:
    return k_max + 1


def edge_span(m_valid: int, edge_chunk: int, m_cap: int) -> int:
    """Chunk-rounded prefix of a sentinel-padded edge list that the chunked
    sweeps must visit: ``m_valid`` rounded *up* to an ``edge_chunk``
    multiple, clamped to ``m_cap``. Rounding up means every edge count
    inside one chunk-granule maps to the same static value — in-bucket
    churn cannot retrace a kernel, only crossing a chunk (or bucket)
    boundary can."""
    if m_valid >= m_cap:
        return int(m_cap)
    return int(min(-(-int(m_valid) // int(edge_chunk)) * int(edge_chunk),
                   m_cap))


def msbfs_hop(frontier: jax.Array, esrc: jax.Array, edst: jax.Array,
              n: int, edge_chunk: int = 1 << 22,
              m_valid: Optional[int] = None) -> jax.Array:
    """One BFS relaxation: next[v, s] = OR over edges (u->v) frontier[u, s].

    frontier: (n+1, S) int8 in {0,1} (row n = sentinel zeros).
    m_valid: chunk-rounded valid-edge span (see :func:`edge_span`); None
    sweeps the full (possibly sentinel-padded) list — correct either way,
    the rounding only skips provably all-sentinel chunks.
    Returns (n+1, S) int8.
    """
    S = frontier.shape[1]
    m = esrc.shape[0]
    m_used = m if m_valid is None else min(int(m_valid), m)
    nxt = jnp.zeros((n, S), dtype=jnp.int8)
    # static chunking keeps the (Ec, S) gather bounded; a whole-list
    # sweep (the common case — m fits one chunk) skips the slice ops
    # entirely, so a GSPMD-sharded edge list is gathered shard-local
    # instead of being resharded at a mid-shard slice boundary
    for lo in range(0, m_used, edge_chunk):
        hi = min(lo + edge_chunk, m)
        es, ed = (esrc, edst) if lo == 0 and hi == m \
            else (esrc[lo:hi], edst[lo:hi])
        msgs = frontier[es]                               # (Ec, S) int8
        part = jax.ops.segment_max(msgs, ed, num_segments=n,
                                   indices_are_sorted=True)
        nxt = jnp.maximum(nxt, part)
    return jnp.concatenate([nxt, jnp.zeros((1, S), jnp.int8)], axis=0)


@partial(jax.jit, static_argnames=("n", "k_max", "edge_chunk", "m_valid"))
def msbfs_set_dist(esrc: jax.Array, edst: jax.Array, seed_mask: jax.Array,
                   *, n: int, k_max: int, edge_chunk: int = 1 << 22,
                   m_valid: Optional[int] = None) -> jax.Array:
    """Distance from a vertex *set*: one bit-column seeded with every
    member, so ``dist[v] = min over seeds of hops(seed -> v)`` in a single
    S=1 sweep. This is what hop-scoped cache invalidation asks ("how close
    is the nearest touched vertex?") — one compile per (n, k_max) instead
    of one per frontier size.

    seed_mask : (n+1,) int8 in {0,1} (row n must be 0).
    Returns (n+1,) int8 with unreached = INF = k_max + 1, row n = INF.
    """
    INF = np.int8(INF_FOR(k_max))
    seed = seed_mask.astype(jnp.int8)[:, None]          # (n+1, 1)
    dist = jnp.where(seed[:, 0].astype(bool), jnp.int8(0), INF)
    frontier = seed
    for hop in range(1, k_max + 1):
        reached = (dist < INF).astype(jnp.int8)
        nxt = msbfs_hop(frontier, esrc, edst, n, edge_chunk, m_valid)
        new = nxt * (1 - reached)[:, None]
        dist = jnp.where(new[:, 0].astype(bool), jnp.int8(hop), dist)
        frontier = new.at[n].set(0)
    return dist.at[n].set(INF)


@partial(jax.jit, static_argnames=("n", "k_max", "edge_chunk", "m_valid"))
def msbfs_dist(esrc: jax.Array, edst: jax.Array, sources: jax.Array,
               *, n: int, k_max: int, edge_chunk: int = 1 << 22,
               m_valid: Optional[int] = None) -> jax.Array:
    """Distances from each source, capped at k_max.

    esrc/edst : (m,) int32 edges sorted by dst (use reverse edges for G_r).
    sources   : (S,) int32 (padded entries may repeat; they are independent).
    Returns dist (n+1, S) int8; dist[v, i] = min(hops(sources[i] -> v), INF),
    row n is INF (sentinel for padded gathers).
    """
    S = sources.shape[0]
    INF = np.int8(INF_FOR(k_max))
    dist = jnp.full((n + 1, S), INF, dtype=jnp.int8)
    dist = dist.at[sources, jnp.arange(S)].min(jnp.int8(0))
    frontier = jnp.zeros((n + 1, S), jnp.int8).at[sources, jnp.arange(S)].set(1)
    for hop in range(1, k_max + 1):
        reached = (dist < INF).astype(jnp.int8)
        nxt = msbfs_hop(frontier, esrc, edst, n, edge_chunk, m_valid)
        new = nxt * (1 - reached)                          # newly reached only
        dist = jnp.where(new.astype(bool), jnp.int8(hop), dist)
        frontier = new.at[n].set(0)
        # NOTE: no early exit under jit; k_max is small (<= 8 in the paper).
    return dist.at[n].set(INF)
