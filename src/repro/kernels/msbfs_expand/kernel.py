"""Pallas kernel: bit-packed multi-source BFS frontier expansion.

    next[v, w] = OR over d of frontier[ell_idx[v, d], w]

The paper's BuildIndex hop adapted to the TPU memory hierarchy:
  * frontiers are bit-packed uint32 words -- 32 BFS sources per lane, the
    MS-BFS [36] trick; one VPU OR handles 32 sources at once.
  * the graph is padded ELL, so the gather is a *regular* row gather
    (vector index + static column range) instead of CSR pointer chasing.
  * grid = (row blocks, word blocks). Each program owns a (BV, BW) output
    tile; the full frontier word-slice (V+1, BW) is resident in VMEM
    (VMEM budget: (V_shard+1) * BW * 4B -- e.g. 128k rows x 8 words = 4 MB;
    the launcher shards vertices across devices to keep this bounded and
    the ELL tile streams in at (BV, D) * 4B).

Sentinel: ell row entries equal to V point at frontier row V, which the
wrapper pins to zero words, so padding contributes nothing to the OR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["msbfs_expand_pallas", "msbfs_step_pallas"]


def _kernel(idx_ref, fr_ref, out_ref):
    idx = idx_ref[...]                       # (BV, D) int32
    fr = fr_ref[...]                         # (V+1, BW) uint32
    D = idx.shape[1]

    def body(d, acc):
        rows = jax.lax.dynamic_index_in_dim(idx, d, axis=1, keepdims=False)
        return acc | fr[rows]                # row gather + OR

    acc0 = jnp.zeros(out_ref.shape, jnp.uint32)
    out_ref[...] = jax.lax.fori_loop(0, D, body, acc0)


@functools.partial(jax.jit, static_argnames=("block_v", "block_w", "interpret"))
def msbfs_expand_pallas(ell_idx: jax.Array, frontier: jax.Array,
                        *, block_v: int = 256, block_w: int = 8,
                        interpret: bool = False) -> jax.Array:
    """ell_idx: (V, D) int32 (pad = V); frontier: (V+1, W) uint32 (row V = 0).

    Returns next frontier words (V, W) uint32 (un-sentineled).
    """
    V, D = ell_idx.shape
    W = frontier.shape[1]
    bv = min(block_v, V)
    bw = min(block_w, W)
    grid = (pl.cdiv(V, bv), pl.cdiv(W, bw))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, D), lambda i, j: (i, 0)),
            pl.BlockSpec((V + 1, bw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bv, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((V, W), jnp.uint32),
        interpret=interpret,
    )(ell_idx, frontier)


def _step_kernel(hop, idx_ref, fr_ref, vis_ref, dist_ref,
                 nf_ref, vo_ref, do_ref):
    idx = idx_ref[...]                       # (BV, D) int32
    fr = fr_ref[...]                         # (V+1, BW) uint32
    D = idx.shape[1]

    def body(d, acc):
        rows = jax.lax.dynamic_index_in_dim(idx, d, axis=1, keepdims=False)
        return acc | fr[rows]

    acc = jax.lax.fori_loop(0, D, body, jnp.zeros(nf_ref.shape, jnp.uint32))
    vis = vis_ref[...]                       # (BV, BW) uint32
    new = acc & ~vis                         # dedup against the visited set
    nf_ref[...] = new
    vo_ref[...] = vis | new
    # unpack the freshly-set bits (little-endian within a word, matching
    # ref.pack_bits) and stamp the hop into the distance tile
    bv, bw = new.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((new[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)) != 0
    do_ref[...] = jnp.where(bits.reshape(bv, bw * 32), jnp.int8(hop),
                            dist_ref[...])


@functools.partial(jax.jit, static_argnames=("hop", "block_v", "block_w",
                                             "interpret"))
def msbfs_step_pallas(ell_idx: jax.Array, frontier: jax.Array,
                      visited: jax.Array, dist: jax.Array, *, hop: int,
                      block_v: int = 256, block_w: int = 8,
                      interpret: bool = False):
    """One fused MS-BFS level: expand + visited dedup + distance write.

    ell_idx  : (V, D) int32 in-neighbor table (pad = V)
    frontier : (V+1, W) uint32 packed level-(hop-1) frontier (row V = 0)
    visited  : (V, W) uint32 packed reached-set (hop-0 seeds included)
    dist     : (V, W*32) int8 distances, bit (v, w*32+b) <-> word bit
    hop      : static level being written (the per-k_max loop is unrolled
               under jit, so this is a compile-time constant)

    Returns (next_frontier (V, W), visited | next (V, W),
    dist with ``hop`` stamped where a new bit was set) — ONE device
    dispatch where the segment-op path issues gather + segment_max +
    mask-mul + where per level.

    Tiling mirrors :func:`msbfs_expand_pallas`; the distance tile is the
    (BV, BW*32) byte block aligned with the word block, so all three
    outputs stream through the same grid.
    """
    V, D = ell_idx.shape
    W = frontier.shape[1]
    bv = min(block_v, V)
    bw = min(block_w, W)
    grid = (pl.cdiv(V, bv), pl.cdiv(W, bw))
    return pl.pallas_call(
        functools.partial(_step_kernel, hop),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, D), lambda i, j: (i, 0)),
            pl.BlockSpec((V + 1, bw), lambda i, j: (0, j)),
            pl.BlockSpec((bv, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bv, bw * 32), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((bv, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bv, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bv, bw * 32), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((V, W), jnp.uint32),
            jax.ShapeDtypeStruct((V, W), jnp.uint32),
            jax.ShapeDtypeStruct((V, W * 32), jnp.int8),
        ),
        interpret=interpret,
    )(ell_idx, frontier, visited, dist)
