"""Layer 2: jaxpr audit of the registered hot-function manifest.

For every entry in :data:`MANIFEST` (the engine's per-level hot
functions), per backend, this module traces the function with
``jax.make_jaxpr`` on tiny concrete shapes and statically verifies:

  audit/trace      the function traces at all — a ``.item()``/``int()``
                   host sync inside jitted code surfaces here as a
                   ConcretizationTypeError, before any benchmark runs
  audit/callback   zero host-callback primitives in the jaxpr
                   (io_callback, pure_callback, debug_callback, ...)
  audit/budget     per-level (or total) jaxpr-eqn counts at or below the
                   committed ``benchmarks/baselines/DISPATCH_BUDGETS.json``
                   — the PR 6 eqn accounting, now a checked-in contract
                   (a pallas_call counts as ONE eqn: one fused dispatch);
                   kernel backends additionally pin pallas dispatches per
                   level (the fused MS-BFS step must stay at 1)
  audit/int8       the int8 distance dtype is proven in range: INF for
                   the K_MAX_INT8 ceiling fits with headroom, and an
                   out-of-range ``k_max`` raises ValueError instead of
                   clamping
  audit/retrace    a second execution on same-shape, different-value
                   inputs adds zero compiles (compilelog) — shape may
                   not depend on any non-static argument
  audit/coverage   every op in ``kernels.registry.op_manifest()`` is
                   either traced by some manifest entry or explicitly
                   exempted with a written reason

Per-level counts are measured as a finite difference: trace at level L
and L+1, ``per_level = eqns(L+1) - eqns(L)``, ``base = eqns(L) - L *
per_level`` — robust to constant setup/teardown around the hop loop.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, Optional, Sequence, Tuple

from .report import AnalysisReport, Finding

__all__ = ["MANIFEST", "AUDIT_EXEMPT_OPS", "HotFn", "run_audit",
           "audit_traceable", "measure_budgets", "DEFAULT_BUDGETS_PATH"]

DEFAULT_BUDGETS_PATH = Path("benchmarks/baselines/DISPATCH_BUDGETS.json")

# level knob values used for the finite-difference measurement
_LEVELS = (2, 3)


@dataclasses.dataclass(frozen=True)
class HotFn:
    """One audited hot function.

    ``make(backend, level)`` returns ``(fn, args)`` ready for
    ``jax.make_jaxpr(fn)(*args)`` / ``fn(*args)`` on tiny shapes; for
    unleveled entries the ``level`` argument is ignored.
    """
    name: str
    backends: Tuple[str, ...]
    make: Callable[[str, int], tuple]
    leveled: bool = True
    # entries whose inputs cannot be value-perturbed for the retrace
    # check (e.g. sorted-side invariants) may opt out with a reason
    retrace: bool = True


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------

def _mk_msbfs_dist(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.msbfs import msbfs_dist
    n, m, S = 16, 8, 4
    esrc = jnp.zeros((m,), jnp.int32)
    edst = jnp.zeros((m,), jnp.int32)
    srcs = jnp.zeros((S,), jnp.int32)
    return (lambda a, b, c: msbfs_dist(a, b, c, n=n, k_max=k),
            (esrc, edst, srcs))


def _mk_msbfs_set_dist(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.msbfs import msbfs_set_dist
    n, m = 16, 8
    esrc = jnp.zeros((m,), jnp.int32)
    edst = jnp.zeros((m,), jnp.int32)
    seed = jnp.zeros((n + 1,), jnp.int8)
    return (lambda a, b, c: msbfs_set_dist(a, b, c, n=n, k_max=k),
            (esrc, edst, seed))


def _mk_msbfs_dist_ell(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.msbfs import msbfs_dist_ell
    n, D, S = 16, 4, 4
    ell = jnp.full((n + 1, D), n, jnp.int32)
    srcs = jnp.zeros((S,), jnp.int32)
    return (lambda a, b: msbfs_dist_ell(a, b, n=n, k_max=k, backend=backend),
            (ell, srcs))


def _mk_msbfs_set_dist_ell(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.msbfs import msbfs_set_dist_ell
    n, D = 16, 4
    ell = jnp.full((n + 1, D), n, jnp.int32)
    seed = jnp.zeros((n + 1,), jnp.int8)
    return (lambda a, b: msbfs_set_dist_ell(a, b, n=n, k_max=k,
                                            backend=backend),
            (ell, seed))


def _mk_walk_counts(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.index import walk_counts
    n, m = 16, 8
    esrc = jnp.zeros((m,), jnp.int32)
    edst = jnp.zeros((m,), jnp.int32)
    slack = jnp.zeros((n + 1,), jnp.int8)
    return (lambda a, b, s: walk_counts(a, b, jnp.int32(0), s,
                                        n=n, budget=k),
            (esrc, edst, slack))


def _mk_walk_counts_ell(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.index import walk_counts_ell
    n, D = 16, 4
    ell = jnp.full((n + 1, D), n, jnp.int32)
    slack = jnp.zeros((n + 1,), jnp.int8)
    return (lambda a, s: walk_counts_ell(a, jnp.int32(0), s, n=n, budget=k,
                                         backend=backend),
            (ell, slack))


def _mk_expand_level(backend: str, k: int):
    import jax.numpy as jnp
    from ..core.enumerate import expand_level
    n, D, cap, L = 16, 4, 8, 6
    verts = jnp.zeros((cap, L), jnp.int32)
    ell = jnp.full((n, D), n, jnp.int32)
    tbl = jnp.zeros((n + 1, 2), jnp.int8)
    return (lambda v, c, e, t, s: expand_level(
                v, c, e, t, s, level=1, budget=4, out_cap=cap,
                backend=backend),
            (verts, jnp.int32(2), ell, tbl, jnp.int32(-2)))


def _join_sides():
    import jax.numpy as jnp
    cap, L = 8, 6
    verts = jnp.zeros((cap, L), jnp.int32)
    keys = jnp.zeros((cap,), jnp.int32)
    return verts, keys, jnp.int32(2)


def _mk_keyed_join(backend: str, k: int):
    from ..core.join import SortedSide, keyed_join
    verts, keys, count = _join_sides()
    return (lambda av, ak, ac, bv, bc: keyed_join(
                SortedSide(av, ak, ac), bv, bc, a_col=2, b_col=2,
                out_cap=8, out_width=6, backend=backend),
            (verts, keys, count, verts, count))


def _mk_keyed_join_count(backend: str, k: int):
    from ..core.join import SortedSide, keyed_join_count
    verts, keys, count = _join_sides()
    return (lambda av, ak, ac, bv, bc: keyed_join_count(
                SortedSide(av, ak, ac), bv, bc, a_col=2, b_col=2,
                pair_cap=8, backend=backend),
            (verts, keys, count, verts, count))


def _mk_cross_join(backend: str, k: int):
    from ..core.join import cross_join
    verts, _, count = _join_sides()
    return (lambda pv, pc, cv, cc: cross_join(
                pv, pc, cv, cc, p_col=2, c_col=2, out_cap=8, out_width=6,
                backend=backend),
            (verts, count, verts, count))


MANIFEST: Tuple[HotFn, ...] = (
    HotFn("msbfs_dist", ("jnp",), _mk_msbfs_dist),
    HotFn("msbfs_set_dist", ("jnp",), _mk_msbfs_set_dist),
    HotFn("msbfs_dist_ell", ("jnp", "interpret"), _mk_msbfs_dist_ell),
    HotFn("msbfs_set_dist_ell", ("jnp", "interpret"), _mk_msbfs_set_dist_ell),
    HotFn("walk_counts", ("jnp",), _mk_walk_counts),
    HotFn("walk_counts_ell", ("jnp", "interpret"), _mk_walk_counts_ell),
    HotFn("expand_level", ("jnp", "interpret"), _mk_expand_level,
          leveled=False),
    HotFn("keyed_join", ("jnp", "interpret"), _mk_keyed_join, leveled=False),
    HotFn("keyed_join_count", ("jnp", "interpret"), _mk_keyed_join_count,
          leveled=False),
    HotFn("cross_join", ("jnp", "interpret"), _mk_cross_join, leveled=False),
)

# registry ops deliberately not traced by the manifest. Every op in
# kernels.registry.op_manifest() must be either reached by a MANIFEST
# entry (see _OPS_COVERED) or listed here with a reason — silently
# unaudited kernel math is an audit/coverage finding.
AUDIT_EXEMPT_OPS: Dict[str, str] = {
    "msbfs_expand": "single-hop building block superseded by the fused "
                    "msbfs_step on the engine path; parity pinned by "
                    "tests/test_kernels.py",
    "path_overlap": "pairwise path-similarity op used by host-side "
                    "clustering tooling, not the per-level enumeration "
                    "loop; parity pinned by tests/test_kernels.py",
    "pairwise_popcount": "host-side similarity-matrix batch op (one "
                         "dispatch per batch, not per level); parity "
                         "pinned by tests/test_similarity_clustering.py",
    "flash_attention": "model-serving sidecar (models/transformer), not "
                       "on the HC-s-t query path",
}

# ops each manifest entry's kernel arms route through (for coverage)
_OPS_COVERED = {"msbfs_step", "ell_spmm", "rowwise_overlap", "path_member"}


# ---------------------------------------------------------------------------
# jaxpr scans
# ---------------------------------------------------------------------------

def _scan_callbacks(jaxpr, acc: set) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if "callback" in name or name in ("infeed", "outfeed"):
            acc.add(name)
        if name == "pallas_call":
            continue
        for val in eqn.params.values():
            for v in (val if isinstance(val, (tuple, list)) else [val]):
                if hasattr(v, "jaxpr"):
                    _scan_callbacks(v.jaxpr, acc)
                elif hasattr(v, "eqns"):
                    _scan_callbacks(v, acc)


def _kernel_dispatches(jaxpr) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        for val in eqn.params.values():
            for v in (val if isinstance(val, (tuple, list)) else [val]):
                if hasattr(v, "jaxpr"):
                    total += _kernel_dispatches(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += _kernel_dispatches(v)
    return total


def audit_traceable(fn: Callable, args: Sequence, *,
                    name: str) -> list:
    """Trace ``fn(*args)`` and return findings for trace failures (host
    syncs surface as ConcretizationTypeError) and callback primitives.
    Exposed for the analyzer's self-tests (seed a ``.item()`` into a toy
    fn and assert detection)."""
    import jax
    findings = []
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as exc:
        findings.append(Finding(
            "audit/trace", name, 0,
            f"failed to trace: {type(exc).__name__}: "
            f"{str(exc).splitlines()[0][:200]} (host sync inside the "
            f"traced region?)"))
        return findings
    cbs: set = set()
    _scan_callbacks(closed.jaxpr, cbs)
    if cbs:
        findings.append(Finding(
            "audit/callback", name, 0,
            f"host callback primitive(s) in jaxpr: {sorted(cbs)}"))
    return findings


# ---------------------------------------------------------------------------
# measurement + checks
# ---------------------------------------------------------------------------

def _measure_entry(entry: HotFn, backend: str) -> Dict[str, int]:
    """Measured dispatch stats for one (entry, backend) cell."""
    import jax
    from ..launch.hlo_analysis import count_eqns
    if entry.leveled:
        lo, hi = _LEVELS
        f_lo, a_lo = entry.make(backend, lo)
        f_hi, a_hi = entry.make(backend, hi)
        e_lo = count_eqns(jax.make_jaxpr(f_lo)(*a_lo).jaxpr)
        jx_hi = jax.make_jaxpr(f_hi)(*a_hi)
        e_hi = count_eqns(jx_hi.jaxpr)
        per = e_hi - e_lo
        stats = {"eqns_per_level": per, "base_eqns": e_lo - lo * per}
        if backend != "jnp":
            k_lo = _kernel_dispatches(jax.make_jaxpr(f_lo)(*a_lo).jaxpr)
            k_hi = _kernel_dispatches(jx_hi.jaxpr)
            stats["kernel_dispatches_per_level"] = k_hi - k_lo
    else:
        fn, args = entry.make(backend, _LEVELS[0])
        jx = jax.make_jaxpr(fn)(*args)
        stats = {"total_eqns": count_eqns(jx.jaxpr)}
        if backend != "jnp":
            stats["kernel_dispatches"] = _kernel_dispatches(jx.jaxpr)
    return stats


def measure_budgets() -> Dict[str, Dict[str, Dict[str, int]]]:
    """Measured dispatch stats for the full manifest (the budget-update
    workflow: ``python -m repro.analysis --write-budgets`` commits this)."""
    return {e.name: {b: _measure_entry(e, b) for b in e.backends}
            for e in MANIFEST}


def _check_budget(name: str, backend: str, stats: Dict[str, int],
                  budget: Optional[Dict[str, int]]) -> list:
    loc = f"{name}[{backend}]"
    if budget is None:
        return [Finding("audit/budget", loc, 0,
                        f"no committed budget in DISPATCH_BUDGETS.json "
                        f"(measured: {stats}); run --write-budgets and "
                        f"commit the baseline")]
    findings = []
    for key, actual in stats.items():
        allowed = budget.get(key)
        if allowed is None:
            findings.append(Finding(
                "audit/budget", loc, 0,
                f"budget entry missing key {key!r} (measured {actual})"))
        elif actual > allowed:
            findings.append(Finding(
                "audit/budget", loc, 0,
                f"{key} regressed: measured {actual} > committed budget "
                f"{allowed}"))
    return findings


def _check_int8(report: AnalysisReport) -> None:
    """int8 overflow hazards proven in range, not just clamped."""
    import jax.numpy as jnp
    from ..core import msbfs

    inf = msbfs.INF_FOR(msbfs.K_MAX_INT8)
    headroom = 127 - inf
    if inf > 127 or headroom < 1:
        report.add([Finding(
            "audit/int8", "msbfs.K_MAX_INT8", 0,
            f"INF_FOR(K_MAX_INT8)={inf} leaves headroom={headroom} in "
            f"int8 — the sentinel no longer fits")])
    report.meta["int8"] = {"k_max_ceiling": msbfs.K_MAX_INT8,
                          "inf": inf, "headroom": headroom}

    # the guard must RAISE for k_max past the ceiling (naming k_max), not
    # silently clamp
    n = 4
    ell = jnp.full((n + 1, 2), n, jnp.int32)
    seed = jnp.zeros((n + 1,), jnp.int8)
    for fn_name, call in (
        ("msbfs_set_dist", lambda k: msbfs.msbfs_set_dist(
            jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32), seed,
            n=n, k_max=k)),
        ("msbfs_set_dist_ell", lambda k: msbfs.msbfs_set_dist_ell(
            ell, seed, n=n, k_max=k)),
    ):
        try:
            call(msbfs.K_MAX_INT8 + 1)
            report.add([Finding(
                "audit/int8", fn_name, 0,
                f"k_max={msbfs.K_MAX_INT8 + 1} did not raise — the int8 "
                f"bound is clamped, not checked")])
        except ValueError as exc:
            if "k_max" not in str(exc):
                report.add([Finding(
                    "audit/int8", fn_name, 0,
                    f"out-of-range k_max raised but the error does not "
                    f"name k_max: {exc}")])


def _perturb(args):
    """Same-shape, different-value variants of the example args (zeros of
    index arrays stay in range)."""
    import jax
    import jax.numpy as jnp

    def bump(x):
        if hasattr(x, "dtype") and x.ndim == 0:
            return x          # scalar knobs (counts/stop) keep semantics
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.integer):
            return x * 0      # index arrays: all-zeros is always in range
        if hasattr(x, "dtype"):
            return x * 0
        return x
    return jax.tree_util.tree_map(bump, tuple(args))


def _check_retrace(entry: HotFn, backend: str) -> list:
    """Second same-shape execution must add zero compiles."""
    import jax
    from ..core import compilelog
    log = compilelog.enable()
    fn, args = entry.make(backend, _LEVELS[0])
    loc = f"{entry.name}[{backend}]"
    try:
        # materialize the perturbed args BEFORE the snapshot — building
        # them dispatches tiny jitted muls whose compiles must not be
        # attributed to the re-run
        args2 = jax.block_until_ready(_perturb(args))
        fn(*args)                       # warm (may compile)
        snap = log.snapshot()
        fn(*args2)                      # same shapes, new values
    except Exception as exc:  # trace check already reported the cause
        return [Finding("audit/retrace", loc, 0,
                        f"execution failed: {type(exc).__name__}: "
                        f"{str(exc).splitlines()[0][:160]}")]
    new = log.compiles_since(snap)
    if new:
        return [Finding(
            "audit/retrace", loc, 0,
            f"{new} new compile(s) on a same-shape re-run — output shape "
            f"or trace depends on a non-static argument value")]
    return []


def _check_coverage() -> list:
    from ..kernels.registry import op_manifest
    findings = []
    for op in op_manifest():
        if op in _OPS_COVERED or op in AUDIT_EXEMPT_OPS:
            continue
        findings.append(Finding(
            "audit/coverage", f"registry:{op}", 0,
            f"registered kernel op {op!r} is neither traced by the audit "
            f"manifest nor listed in AUDIT_EXEMPT_OPS with a reason"))
    stale = sorted(set(AUDIT_EXEMPT_OPS) - set(op_manifest()))
    for op in stale:
        findings.append(Finding(
            "audit/coverage", f"registry:{op}", 0,
            f"AUDIT_EXEMPT_OPS lists {op!r} which is no longer a "
            f"registered op — drop the stale exemption"))
    return findings


def run_audit(budgets_path: Optional[Path] = None, *,
              check_budgets: bool = True,
              check_retraces: bool = True) -> AnalysisReport:
    """Run the full layer-2 audit; returns one :class:`AnalysisReport`.

    ``budgets_path=None`` with ``check_budgets=True`` reads
    :data:`DEFAULT_BUDGETS_PATH` (relative to the current directory);
    a missing file reports one finding per audited cell.
    """
    report = AnalysisReport()
    budgets: Dict = {}
    if check_budgets:
        path = Path(budgets_path or DEFAULT_BUDGETS_PATH)
        if path.exists():
            budgets = {k: v for k, v in
                       json.loads(path.read_text()).items()
                       if not k.startswith("_")}
        else:
            report.add([Finding(
                "audit/budget", str(path), 0,
                "committed budget baseline not found — run "
                "`python -m repro.analysis --write-budgets` and commit it")])
            check_budgets = False

    measured: Dict[str, Dict[str, Dict[str, int]]] = {}
    for entry in MANIFEST:
        for backend in entry.backends:
            report.n_functions += 1
            loc = f"{entry.name}[{backend}]"
            fn, args = entry.make(backend, _LEVELS[0])
            trace_findings = audit_traceable(fn, args, name=loc)
            report.add(trace_findings)
            if any(f.rule == "audit/trace" for f in trace_findings):
                continue            # can't measure what doesn't trace
            stats = _measure_entry(entry, backend)
            measured.setdefault(entry.name, {})[backend] = stats
            if check_budgets:
                report.add(_check_budget(entry.name, backend, stats,
                                         budgets.get(entry.name, {})
                                         .get(backend)))
            if check_retraces and entry.retrace:
                report.add(_check_retrace(entry, backend))

    _check_int8(report)
    report.add(_check_coverage())
    report.meta["measured"] = measured
    return report
