"""Pure-jnp oracle for the bit-packed MS-BFS expansion + pack/unpack helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["msbfs_expand_ref", "msbfs_step_ref", "pack_bits", "unpack_bits"]


def pack_bits(bits: jax.Array) -> jax.Array:
    """(V, S) bool -> (V, ceil(S/32)) uint32 (little-endian within a word)."""
    V, S = bits.shape
    W = -(-S // 32)
    pad = W * 32 - S
    b = jnp.pad(bits.astype(jnp.uint32), ((0, 0), (0, pad)))
    b = b.reshape(V, W, 32)
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(b * powers[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, S: int) -> jax.Array:
    """(V, W) uint32 -> (V, S) bool."""
    V, W = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(V, W * 32)[:, :S].astype(bool)


def msbfs_expand_ref(ell_idx: jax.Array, frontier: jax.Array) -> jax.Array:
    """OR-gather over padded ELL rows: next[v, w] = OR_d frontier[idx[v,d], w]."""
    gathered = frontier[ell_idx]               # (V, D, W)
    return jax.lax.reduce(gathered, jnp.uint32(0), jax.lax.bitwise_or, (1,))


def msbfs_step_ref(ell_idx: jax.Array, frontier: jax.Array,
                   visited: jax.Array, dist: jax.Array, hop: int):
    """jnp twin of the fused step: expand, dedup vs visited, stamp hop.

    Shapes as :func:`~repro.kernels.msbfs_expand.kernel.msbfs_step_pallas`.
    """
    acc = msbfs_expand_ref(ell_idx, frontier)            # (V, W)
    new = acc & ~visited
    V, W = new.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((new[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)) != 0
    dist = jnp.where(bits.reshape(V, W * 32), jnp.int8(hop), dist)
    return new, visited | new, dist
