"""Hypothesis compat shim for mixed test modules.

``hypothesis`` is an optional [test] extra. Modules that mix property-based
and regular tests import ``given``/``settings``/``st`` from here: with
hypothesis installed this is a plain re-export; without it the property
tests degrade to individual skips while the rest of the module still runs
(a bare module-level import would error the whole suite at collection).

Purely property-based modules (test_engine_properties.py) use
``pytest.importorskip("hypothesis")`` instead.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    import pytest

    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: any attribute/call returns itself,
        enough for decorator-time ``st.integers(...)`` expressions."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
