"""Streaming batch query serving (the paper's deployment shape, made
continuous).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --queries 64 \
        --similarity 0.6 --groups 2 --rounds 3 --cache-mb 256

Queries arrive one at a time and are coalesced into micro-batches by a
deadline/size admission policy. Each micro-batch is clustered with a
*cache-aware* bias (queries whose half-query results are already warm in
the cross-batch ``SharedPathCache`` are pulled together), the clusters go
to replica groups through the work-stealing scheduler, and the engine
executes them consulting the cache before materializing any Ψ node.
Per-batch latency, sharing and cache hit/miss stats are logged; a result
sample is validated against the oracle.

The admission layer is SLO-aware (``docs/serving.md`` § SLO-aware
admission): per-query deadlines (``PathQuery.deadline_s``) cut a
micro-batch early when the oldest waiter's slack is spent, admission
ordering is weighted-fair across tenants, and under pressure
(``AdmissionPolicy.max_queue``) exists/count queries are answered through
the cost-router fast path while path queries are shed with a typed
:class:`~repro.core.query.ResultStatus.SHED` result. Replica-group
failures mid-batch are absorbed by the work-stealing scheduler's
checkpointable queue: the failed group's in-flight cluster is requeued
onto survivors (at-least-once; results land exactly once per query id).
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from ..core import BatchPathEngine, EngineConfig, build_index
from ..core import generators
from ..core.planner import admission_fast_path
from ..core.query import (Output, PathQuery, Planner, QueryLike, QueryResult)
from ..core.clustering import cluster_queries
from ..core.similarity import similarity_matrix
from ..ft.scheduler import WorkStealingScheduler
from ..obs import metrics as obsmetrics

__all__ = ["AdmissionPolicy", "StreamingServer", "GroupFailure",
           "VirtualClock", "serve_batch", "warm_cluster_bias"]


class GroupFailure(RuntimeError):
    """A replica group died while executing a scheduler item.

    Raised by a failure injector (tests, exp11's mid-stream kill) or by
    wrapping real executor errors; the serving loop catches it, marks the
    group dead, requeues the in-flight cluster via
    :meth:`WorkStealingScheduler.fail_group`, and carries on with the
    survivors.
    """

    def __init__(self, group: int, msg: str = ""):
        super().__init__(msg or f"replica group {group} failed")
        self.group = group


class VirtualClock:
    """A settable monotonic clock for open-loop replay (exp11).

    The streaming server reads its notion of "now" through a callable; a
    ``VirtualClock`` lets a benchmark drive arrivals in simulated time
    while still charging real execution walls — the server calls
    ``advance(wall_s)`` after each admitted batch, so queueing delay under
    load accumulates exactly as it would against a wall clock, without the
    replay having to sleep through idle gaps.
    """

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass
class AdmissionPolicy:
    """When to close the open micro-batch — and what to refuse.

    The first three fields are the classic size/delay cutoffs. The SLO
    layer on top of them:

    * ``max_queue`` — admission control: when this many queries already
      wait, a new exists/count submission is answered immediately through
      the cost-router fast path (cheap by construction) and a new paths
      submission is **shed** with a typed
      :class:`~repro.core.query.ResultStatus.SHED` result instead of
      joining a queue it would time out of. ``None`` disables shedding.
    * ``shed_expired`` — a query whose deadline has already passed at
      admission time is shed (reason ``"deadline"``) rather than executed:
      the work is wasted either way, and skipping it protects the queries
      that can still meet their SLO.
    * ``tenant_weights`` — weighted-fair admission ordering: queries are
      admitted in decreasing ``wait × weight(tenant)`` order (unknown
      tenants weigh 1.0), with deadline urgency taking precedence — see
      :meth:`order_key`.

    Deadline slack additionally *cuts the batch early*: ``due`` fires as
    soon as the oldest waiter's remaining slack (deadline − now − expected
    service time) is spent, regardless of ``min_batch``/``max_delay_s`` —
    deadlines take precedence over coalescing.
    """

    max_batch: int = 32         # admit as soon as this many queries wait
    max_delay_s: float = 0.02   # ... or the oldest has waited this long
    min_batch: int = 1          # never admit fewer, unless the deadline
    # has passed (the deadline overrides min_batch: a lone query older
    # than max_delay_s must not starve until drain())
    max_queue: Optional[int] = None     # waiting cap; beyond it, shed
    shed_expired: bool = True           # shed already-expired deadlines
    tenant_weights: Optional[dict] = None   # tenant -> weight (default 1.0)

    def due(self, n_waiting: int, oldest_wait_s: float,
            min_slack_s: Optional[float] = None) -> bool:
        if n_waiting <= 0:
            return False
        if min_slack_s is not None and min_slack_s <= 0:
            return True     # a waiter's SLO slack is spent: cut the batch now
        if oldest_wait_s >= self.max_delay_s:
            return True
        if n_waiting < self.min_batch:
            return False
        return n_waiting >= self.max_batch

    def weight(self, tenant: str) -> float:
        return (self.tenant_weights or {}).get(tenant, 1.0)

    def order_key(self, query: PathQuery, wait_s: float,
                  deadline: Optional[float]):
        """Admission-order sort key (ascending = admitted first).

        Deadline queries come first, earliest absolute deadline first
        (EDF); within the no-deadline tail, decreasing weighted wait —
        so a tenant with weight 2 drains twice as fast as weight 1 under
        contention, and nobody starves (wait grows without bound).
        """
        return (deadline if deadline is not None else math.inf,
                -wait_s * self.weight(query.tenant))


def warm_cluster_bias(engine: BatchPathEngine, queries: Sequence[QueryLike],
                      eps: float = 0.08) -> Optional[np.ndarray]:
    """(Q, Q) additive clustering bonus from cross-batch cache warmth.

    Two queries get a bonus when they share a half-query root (same source
    or same target) and the cache holds results enumerated from that root —
    landing them in the same cluster makes the plan regenerate the cached
    node's signature so the hit actually fires. A root-warmth probe is a
    heuristic (the consumer-set part of the key may still differ); a wrong
    bonus costs nothing but a slightly different clustering.
    """
    cache = engine.cache
    if cache is None or len(queries) < 2:
        return None
    if any(not isinstance(q, PathQuery) for q in queries):
        # coerce only mixed/legacy inputs; the admission hot path hands
        # us already-validated PathQuery objects every micro-batch
        queries = [PathQuery.coerce(q) for q in queries]
    warm_f = [cache.has_root("f", q.s) for q in queries]
    warm_b = [cache.has_root("b", q.t) for q in queries]
    Q = len(queries)
    bias = np.zeros((Q, Q), np.float64)
    src = np.array([q.s for q in queries])
    tgt = np.array([q.t for q in queries])
    wf = np.array(warm_f)
    wb = np.array(warm_b)
    same_src = (src[:, None] == src[None, :]) & wf[:, None] & wf[None, :]
    same_tgt = (tgt[:, None] == tgt[None, :]) & wb[:, None] & wb[None, :]
    bias += eps * same_src + eps * same_tgt
    np.fill_diagonal(bias, 0.0)
    return bias if bias.any() else None


@dataclasses.dataclass
class _Waiting:
    """One enqueued query: id, query, arrival time, absolute deadline."""

    qid: int
    query: PathQuery
    arrival: float
    deadline: Optional[float]   # arrival + query.deadline_s, or None


def _tenant_counts(queries: Sequence[PathQuery]) -> dict[str, int]:
    out: dict[str, int] = {}
    for q in queries:
        out[q.tenant] = out.get(q.tenant, 0) + 1
    return out


class StreamingServer:
    """Continuous admission loop over a shared engine + scheduler.

    Usage::

        srv = StreamingServer(engine, n_groups=2)
        qid = srv.submit((s, t, k))     # returns a stable query id
        srv.apply_delta(delta)          # edge churn: applied at the next
                                        # micro-batch boundary (see delta_log)
        srv.pump()                      # admit due micro-batches (call often)
        srv.drain()                     # flush everything still waiting
        srv.results[qid]                # QueryResult (same type as batch runs)

    Submissions are validated eagerly (``PathQuery`` coercion + graph
    bounds), so one malformed query is rejected at submit time instead of
    failing an entire admitted micro-batch inside the engine. The engine's
    cross-batch cache (if configured) persists across micro-batches;
    per-batch cache hit/miss and materialization stats are appended to
    ``batch_log``.
    """

    def __init__(self, engine: BatchPathEngine, n_groups: int = 2,
                 gamma: Optional[float] = None,
                 policy: Optional[AdmissionPolicy] = None,
                 warm_bias_eps: float = 0.08,
                 planner: Planner | str = Planner.BATCH,
                 clock: Optional[Callable[[], float]] = None):
        self.engine = engine
        self.n_groups = n_groups
        self.gamma = engine.cfg.gamma if gamma is None else gamma
        self.policy = policy or AdmissionPolicy()
        self.warm_bias_eps = warm_bias_eps
        # planner for admitted micro-batches; AUTO additionally turns on
        # the submit-time fast path (certainly-GREEN queries answered
        # immediately instead of waiting out micro-batch coalescing)
        self.planner = Planner.coerce(planner)
        self.n_fast_path = 0
        self.n_shed = 0
        self.n_deadline_miss = 0
        # the serving notion of "now": a wall clock by default, or a
        # VirtualClock for open-loop replay (advanced by real batch walls)
        self.clock = clock or time.monotonic
        # failure injection + failover state: a GroupFailure raised while
        # a group executes its item marks the group dead and requeues the
        # item via the scheduler's checkpointable queue (at-least-once)
        self.fail_injector: Optional[Callable] = None   # (group, item) -> None
        self.dead_groups: set[int] = set()
        self.n_failovers = 0
        self.sched = WorkStealingScheduler(
            n_groups, cost_fn=lambda qs: float(len(qs)) ** 1.5)
        self.results: dict[int, QueryResult] = {}
        self.batch_log: list[dict] = []
        self.delta_log: list[dict] = []             # per-delta engine reports
        self._waiting: list[_Waiting] = []
        self._query_of: dict[int, PathQuery] = {}   # qid -> query
        self._pending_deltas: list = []             # applied at batch boundary
        self._delta_mark = 0       # delta_log watermark of the last batch
        self._shed_mark = 0        # n_shed watermark of the last batch
        self._next_qid = 0
        self._service_ewma = 0.0   # smoothed batch wall, for slack estimates

    def _now(self) -> float:
        return self.clock()

    def _advance(self, dt: float, n_queries: int = 1) -> None:
        """Charge execution to a virtual clock (no-op on a real clock,
        whose reading already includes it). A clock exposing
        ``advance_batch(dt, n_queries)`` gets the dispatch size too — how
        exp11's deterministic service-cost model charges ``c0 + c1*Q``
        instead of the (noisy) real wall; a plain :class:`VirtualClock`
        is charged the real wall via ``advance(dt)``."""
        advance_batch = getattr(self.clock, "advance_batch", None)
        if advance_batch is not None:
            advance_batch(dt, n_queries)
            return
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(dt)

    # -- ingress -------------------------------------------------------
    def submit(self, query: QueryLike, now: Optional[float] = None) -> int:
        """Validate and enqueue one query; returns a stable query id.

        Raises ValueError immediately for malformed queries (bad arity,
        s == t, k < 1, vertices outside the graph) — admission never sees
        them, so they cannot poison a micro-batch.

        Under ``planner=AUTO``, certainly-GREEN queries (exists-only; see
        ``core.planner.admission_fast_path``) bypass coalescing entirely:
        they are answered here, against the graph as of the last flushed
        delta (the same boundary semantics an admitted batch would see —
        queued-but-unflushed deltas apply at the *next* batch boundary,
        which this fast path never waits for).

        Under pressure (``AdmissionPolicy.max_queue`` queries already
        waiting), load shedding kicks in: exists/count queries are
        answered immediately through the cost-router fast path (they
        never touch the queue), and paths queries are **shed** — the
        result is a typed ``ResultStatus.SHED`` ``QueryResult`` (reason
        ``"overload"``), delivered through ``results``/``take`` like any
        answer, and counted in ``serve_shed_total``.
        """
        q = PathQuery.coerce(query).check_bounds(self.engine.g.n)
        qid = self._next_qid
        self._next_qid += 1
        self._query_of[qid] = q
        reg = obsmetrics.registry()
        if self.planner is Planner.AUTO and admission_fast_path(q):
            reg.counter("serve_fast_path_total").inc()
            self.n_fast_path += 1
            return self._run_fast_path(qid, q)
        pol = self.policy
        if pol.max_queue is not None and len(self._waiting) >= pol.max_queue:
            if q.output in (Output.EXISTS, Output.COUNT):
                # pressure relief: cheap outputs take the direct routed
                # plan now instead of deepening the queue they'd time out of
                reg.counter("serve_pressure_fast_path_total").inc()
                return self._run_fast_path(qid, q)
            return self._shed(qid, q, "overload")
        arrival = self._now() if now is None else now
        deadline = None if q.deadline_s is None else arrival + q.deadline_s
        self._waiting.append(_Waiting(qid, q, arrival, deadline))
        return qid

    def _run_fast_path(self, qid: int, q: PathQuery) -> int:
        """Answer one query immediately (no coalescing) via Planner.AUTO
        routing; charges its wall to a virtual clock like a batch."""
        reg = obsmetrics.registry()
        with self.engine.obs.span("serve.fast_path") as sfp:
            r = self.engine.run([q], planner=Planner.AUTO)
        self.results[qid] = r[0].offload()
        self._advance(sfp.duration, 1)
        e2e = r.stats.get("t_wall_s", 0.0)
        reg.histogram("serve_admission_wait_s").record(0.0)
        reg.histogram("serve_admission_wait_s", tenant=q.tenant).record(0.0)
        reg.histogram("serve_query_e2e_s").record(e2e)
        if q.deadline_s is not None and e2e > q.deadline_s:
            self.n_deadline_miss += 1
            reg.counter("serve_deadline_miss_total").inc()
        return qid

    def _shed(self, qid: int, q: PathQuery, reason: str) -> int:
        self.results[qid] = QueryResult.shed(q, reason)
        self.n_shed += 1
        obsmetrics.registry().counter("serve_shed_total",
                                      reason=reason).inc()
        return qid

    def apply_delta(self, delta) -> None:
        """Queue a :class:`~repro.core.delta.GraphDelta` for application at
        the next micro-batch boundary.

        Deltas never interleave with an admitted batch — queries already
        handed to the engine finish against the graph they were admitted
        under, and every later admission sees the mutated graph. Queued
        deltas are flushed (in submission order) by ``pump()`` / ``drain()``
        even when no query batch is due; per-delta engine reports (CSR
        merge sizes, hop-scoped cache eviction counts) append to
        ``delta_log``, and the next batch's ``batch_log`` entry carries the
        aggregated delta/invalidation counters.

        Validated eagerly, like ``submit``: deltas only mutate edges (the
        vertex set is fixed), so an out-of-range vertex id is rejected
        here — not mid-flush, where the failed delta would be lost from
        the queue while later deltas still applied.
        """
        n = self.engine.g.n
        if delta.max_vertex() >= n:
            raise ValueError(f"delta references vertices outside the graph "
                             f"(n={n}, max id {delta.max_vertex()})")
        self._pending_deltas.append(delta)

    def flush_deltas(self) -> None:
        """Apply every queued delta now (the caller asserts this is a
        batch boundary — pump/drain/admission call it automatically, and
        ``PathSession.run`` does before a one-shot batch). A delta is
        dequeued only after it applied: if the engine raises mid-flush, the
        failed delta stays at the head so a retry cannot silently skip it
        while later deltas apply."""
        while self._pending_deltas:
            self.delta_log.append(
                self.engine.apply_delta(self._pending_deltas[0]))
            self._pending_deltas.pop(0)

    def discard_pending_deltas(self) -> list:
        """Drop queued deltas unapplied; returns them. A full graph swap
        supersedes edge deltas expressed against the replaced graph —
        applying them to the new graph would corrupt it (or crash on
        out-of-range vertices)."""
        dropped, self._pending_deltas = self._pending_deltas, []
        return dropped

    def pump(self, now: Optional[float] = None) -> bool:
        """Admit every micro-batch the policy says is due (a burst can
        leave several deadline-expired batches queued at once). Queued
        graph deltas are applied first — a batch boundary by definition.

        "Now" is re-read from the clock every iteration (an admitted
        batch advances a virtual clock by its execution wall), so later
        batches in a burst see the time earlier ones consumed.
        """
        self.flush_deltas()
        admitted = False
        while self._waiting:
            t = self._now() if now is None else now
            now = None      # only the first iteration honors the override
            oldest = t - min(w.arrival for w in self._waiting)
            if not self.policy.due(len(self._waiting), oldest,
                                   self._min_slack(t)):
                break
            self._admit()
            admitted = True
        return admitted

    def _min_slack(self, now: float) -> Optional[float]:
        """Tightest remaining SLO slack over the waiting queue: absolute
        deadline minus now minus the expected service wall (EWMA of recent
        batch walls). None when nothing waiting carries a deadline."""
        deadlines = [w.deadline for w in self._waiting
                     if w.deadline is not None]
        if not deadlines:
            return None
        return min(deadlines) - now - self._service_ewma

    def drain(self) -> None:
        """Flush: admit everything still waiting, policy notwithstanding."""
        self.flush_deltas()
        while self._waiting:
            self._admit()

    def take(self, qid: int) -> QueryResult:
        """Pop a finished query's QueryResult (KeyError if not finished).

        A continuous server must drain ``results`` this way — entries are
        kept until taken, so an untaken backlog grows without bound.
        """
        out = self.results.pop(qid)   # KeyError first: keep pending intact
        self._query_of.pop(qid, None)
        return out

    # -- failover ------------------------------------------------------
    def _fail_group(self, group: int) -> None:
        self.dead_groups.add(group)
        self.n_failovers += 1
        # the scheduler requeues every cluster in flight on the failed
        # group onto the least-loaded survivor (checkpointable queue —
        # the same path WorkStealingScheduler.restore takes after a
        # process crash); items carry global qids, so a requeue from any
        # earlier micro-batch still resolves to the right queries
        self.sched.fail_group(group)
        obsmetrics.registry().counter("serve_failover_total").inc()

    def kill_group(self, group: int) -> None:
        """Declare a replica group dead between batches (exp11 uses the
        ``fail_injector`` hook to kill one *mid-batch* instead). Its
        queued/in-flight clusters are requeued onto the survivors."""
        if group in self.dead_groups:
            return
        self._fail_group(group)

    def revive_group(self, group: int) -> None:
        """Bring a dead group back (a replacement replica joined). The
        engine-side cache state was never lost — replicas share the
        engine, so a revived group starts warm."""
        self.dead_groups.discard(group)

    # -- one micro-batch -----------------------------------------------
    def _admit(self) -> None:
        self.flush_deltas()   # an admission IS a micro-batch boundary
        deltas = self.delta_log[self._delta_mark:]
        self._delta_mark = len(self.delta_log)
        t_admit = self._now()
        reg = obsmetrics.registry()
        # deadline-expired waiters are shed before ordering: executing
        # them cannot meet their SLO and only steals slack from queries
        # that still can (AdmissionPolicy.shed_expired disables this)
        if self.policy.shed_expired:
            keep = []
            for w in self._waiting:
                if w.deadline is not None and t_admit > w.deadline:
                    self._shed(w.qid, w.query, "deadline")
                else:
                    keep.append(w)
            self._waiting = keep
            if not self._waiting:
                return
        # weighted-fair, deadline-first admission order (policy.order_key)
        self._waiting.sort(key=lambda w: self.policy.order_key(
            w.query, t_admit - w.arrival, w.deadline))
        batch = self._waiting[:self.policy.max_batch]
        self._waiting = self._waiting[self.policy.max_batch:]
        qids = [w.qid for w in batch]
        queries = [w.query for w in batch]
        # admission wait: submit -> this batch boundary, per query
        waits = [t_admit - w.arrival for w in batch]
        h_wait = reg.histogram("serve_admission_wait_s")
        for w, entry in zip(waits, batch):
            h_wait.record(w)
            reg.histogram("serve_admission_wait_s",
                          tenant=entry.query.tenant).record(w)
        with self.engine.obs.span("serve.batch",
                                  n_queries=len(batch)) as sb:
            steals_before = self.sched.steals
            failovers_before = self.sched.failovers
            requeued_before = self.sched.requeued
            with self.engine.obs.span("serve.assemble",
                                      n_queries=len(batch)) as sasm:
                index = build_index(
                    self.engine.dg, [q.key for q in queries],
                    backend=self.engine.kernel_backend.value)
                mu = similarity_matrix(
                    index, backend=self.engine.kernel_backend.value)
                bias = warm_cluster_bias(self.engine, queries,
                                         self.warm_bias_eps)
                # balance_clusters must act HERE, not just inside
                # engine.run — the engine keeps an explicitly passed
                # clustering verbatim, so a similar-traffic micro-batch
                # merged to one cluster would idle every replica but one
                min_clusters = 1
                executor = self.engine.executor
                if self.engine.cfg.balance_clusters and executor is not None:
                    min_clusters = executor.n_replicas
                clusters = cluster_queries(mu, self.gamma, bias=bias,
                                           min_clusters=min_clusters)
            # scheduler items carry global qids so a requeued item from
            # any earlier micro-batch still resolves to the right queries
            # n_compiles / n_retraces stay 0 unless the engine runs with
            # EngineConfig.log_compiles — then each batch_log entry shows
            # whether this micro-batch hit warm XLA compiles (retraces ==
            # 0) or paid a trace (e.g. after a shape-bucket crossing)
            agg = {"n_psi_nodes": 0, "n_materialized": 0,
                   "n_cache_hits": 0, "n_cache_misses": 0,
                   "n_compiles": 0, "n_retraces": 0,
                   "routed_green": 0, "routed_yellow": 0, "routed_red": 0}
            per_device = None
            executor = self.engine.executor
            if executor is not None and executor.sharded:
                # mesh-parallel serving: the executor's greedy
                # cost-balanced placement replaces the host work-stealing
                # loop — one run carries every (cache-aware) cluster,
                # fanned across the per-device replicas and gathered back
                r = self.engine.run(queries, planner=self.planner,
                                    clusters=clusters)
                for i, qid in enumerate(qids):
                    self.results[qid] = r[i].offload()
                for key in agg:
                    agg[key] += r.stats.get(key, 0)
                per_device = r.stats.get("per_device")
            else:
                cids = self.sched.submit([[qids[li] for li in cl]
                                          for cl in clusters])
                open_cids = set(cids)
                while open_cids:
                    progressed = False
                    for grp in range(self.n_groups):
                        if grp in self.dead_groups:
                            continue
                        item = self.sched.next_for(grp)
                        if item is None:
                            continue
                        progressed = True
                        try:
                            if self.fail_injector is not None:
                                self.fail_injector(grp, item)
                            sub = [self._query_of[qid]
                                   for qid in item.queries]
                            # the item IS one cluster — pass it through so
                            # the engine keeps our (cache-aware) grouping
                            # instead of re-clustering
                            r = self.engine.run(
                                sub, planner=self.planner,
                                clusters=[list(range(len(sub)))])
                        except GroupFailure:
                            # the group died mid-item: mark it dead and
                            # requeue its in-flight cluster onto the
                            # survivors (at-least-once — a result written
                            # before the crash would simply be overwritten
                            # by the re-run, idempotent by query id)
                            self._fail_group(grp)
                            continue
                        for i, qid in enumerate(item.queries):
                            # results may sit untaken indefinitely —
                            # offload so the backlog holds compact host
                            # rows, not padded device buffers (count/
                            # exists results hold none)
                            self.results[qid] = r[i].offload()
                        for key in agg:
                            agg[key] += r.stats.get(key, 0)
                        self.sched.complete(item.cluster_id, True)
                        open_cids.discard(item.cluster_id)
                    if not progressed:
                        if open_cids and len(self.dead_groups) \
                                >= self.n_groups:
                            raise RuntimeError(
                                f"all {self.n_groups} replica groups are "
                                f"dead with {len(open_cids)} cluster(s) "
                                f"unserved; revive_group() one first")
                        if not any(cid in self.sched.in_flight
                                   for cid in open_cids):
                            break   # nothing runnable (foreign in-flight)
        wall = sb.duration
        # a virtual clock is charged the real execution wall here, so the
        # e2e readout below sees queueing + service on one timeline
        self._advance(wall, len(batch))
        # end-to-end latency: submit -> results resident, per query
        t_done = self._now()
        # the slack estimator must live on the SAME clock deadlines do:
        # under a virtual clock the charged (model) time is the service
        # cost, and on a real clock t_done - t_admit is the batch wall
        svc = t_done - t_admit
        self._service_ewma = (svc if self._service_ewma == 0.0
                              else 0.7 * self._service_ewma + 0.3 * svc)
        e2e = [t_done - w.arrival for w in batch]
        h_e2e = reg.histogram("serve_query_e2e_s")
        n_miss = 0
        for v, entry in zip(e2e, batch):
            h_e2e.record(v)
            if entry.deadline is not None and t_done > entry.deadline:
                n_miss += 1
        if n_miss:
            self.n_deadline_miss += n_miss
            reg.counter("serve_deadline_miss_total").inc(n_miss)
        Q = len(queries)
        self.batch_log.append({
            "wall_s": wall, "n_queries": Q, "n_clusters": len(clusters),
            "kernel_backend": self.engine.kernel_backend.value,
            "steals": self.sched.steals - steals_before,
            "failovers": self.sched.failovers - failovers_before,
            "requeued": self.sched.requeued - requeued_before,
            "n_deadline_miss": n_miss,
            # sheds since the previous batch boundary (submit-time
            # overload sheds + this admission's deadline sheds)
            "n_shed": self.n_shed - self._shed_mark,
            "tenants": _tenant_counts(queries),
            "warm_biased": bias is not None,
            # micro-batch assembly (index + similarity + clustering) and
            # the per-query latency shape of this admission window
            "t_assemble_s": sasm.duration,
            "admission_wait_p50_s": float(np.percentile(waits, 50)),
            "admission_wait_max_s": float(max(waits)),
            "e2e_p50_s": float(np.percentile(e2e, 50)),
            "e2e_p99_s": float(np.percentile(e2e, 99)),
            "mu_mean": float((mu.sum() - Q) / max(Q * (Q - 1), 1)),
            # graph deltas applied since the previous micro-batch
            "n_deltas": len(deltas),
            "delta_edges": sum(d["n_added"] + d["n_removed"] for d in deltas),
            "delta_cache_evicted": sum(d.get("cache_evicted", 0)
                                       for d in deltas),
            # survivors after the last delta that actually touched the
            # cache (a trailing no-op delta reports nothing)
            "delta_cache_kept": next((d["cache_kept"] for d in
                                      reversed(deltas) if "cache_kept" in d),
                                     0),
            # retraces paid inside apply_delta itself (0 for in-bucket
            # churn; nonzero only when a delta crossed a shape bucket)
            "delta_retraces": sum(d.get("n_retraces", 0) for d in deltas),
            **({"per_device": per_device,
                "n_devices": len(per_device)} if per_device else {}),
            **agg,
            **({"cache": self.engine.cache.info()}
               if self.engine.cache is not None else {}),
        })
        self._shed_mark = self.n_shed


def serve_batch(engine: BatchPathEngine, queries, n_groups: int = 2,
                gamma: float = 0.5):
    """One-shot batch serving (compat wrapper over the streaming loop).

    Cluster -> schedule -> process with stealing. Returns (results, info)
    where results maps query index -> QueryResult. New code should prefer
    ``PathSession`` (``repro.core.session``), which fronts the same loop.
    """
    srv = StreamingServer(engine, n_groups=n_groups, gamma=gamma,
                          policy=AdmissionPolicy(max_batch=max(len(queries), 1),
                                                 max_delay_s=0.0))
    for q in queries:
        srv.submit(q)
    srv.drain()
    # deep copy: batch_log entries hold nested dicts (cache info,
    # per-device stats) that later batches/deltas keep mutating — a
    # shallow dict() would alias them into the returned snapshot
    info = copy.deepcopy(srv.batch_log[-1]) if srv.batch_log \
        else {"wall_s": 0.0}
    return srv.results, info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--similarity", type=float, default=0.6)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--k-min", type=int, default=4)
    ap.add_argument("--k-max", type=int, default=5)
    ap.add_argument("--validate", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=1,
                    help="repeat the workload to exercise the warm cache")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cross-batch cache budget in MiB (0 disables)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--devices", type=int, default=0,
                    help="shard over the first N local devices (0 = plain "
                         "single-device; see docs/serving.md §Sharded)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record stage spans and export a Chrome-trace "
                         "JSON here at exit (open in chrome://tracing or "
                         "ui.perfetto.dev; see docs/observability.md)")
    ap.add_argument("--jax-profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the serving "
                         "rounds into this TensorBoard logdir")
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    g = generators.community(args.n, n_comm=max(4, args.n // 2500),
                             avg_deg=6.0, seed=0)
    engine = BatchPathEngine(g, EngineConfig(
        min_cap=128, cache_bytes=args.cache_mb << 20,
        n_devices=args.devices or None,
        trace=args.trace is not None,
        trace_annotations=args.jax_profile is not None))
    queries = generators.similar_queries(g, args.queries, args.similarity,
                                         (args.k_min, args.k_max), seed=1)
    srv = StreamingServer(engine, n_groups=args.groups,
                          policy=AdmissionPolicy(max_batch=args.max_batch,
                                                 max_delay_s=0.0))
    from ..obs import jaxprof
    qids_by_round = []
    with jaxprof.profile_run(args.jax_profile):
        for _ in range(args.rounds):
            qids_by_round.append([srv.submit(q) for q in queries])
            srv.drain()
    for bi, b in enumerate(srv.batch_log):
        cache = b.get("cache", {})
        print(f"batch {bi}: {b['n_queries']} queries, "
              f"{b['n_clusters']} clusters, {b['wall_s']:.2f}s, "
              f"psi={b['n_psi_nodes']} materialized={b['n_materialized']} "
              f"hits={b['n_cache_hits']} "
              f"(cache: {cache.get('entries', 0)} entries, "
              f"{cache.get('nbytes', 0) >> 20} MiB)")
    n_paths = sum(srv.results[qid].count for qid in qids_by_round[0])
    print(f"served {args.rounds}x{len(queries)} queries -> "
          f"{n_paths} paths per round")
    # oracle validation sample + cross-round consistency
    from ..core.oracle import enumerate_paths_bruteforce, path_set
    rng = np.random.default_rng(0)
    for qi in rng.choice(len(queries), size=min(args.validate, len(queries)),
                         replace=False):
        s, t, k = queries[qi]
        truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
        for round_qids in qids_by_round:
            assert path_set(srv.results[round_qids[qi]].paths) == truth
    print(f"validated {args.validate} queries against the oracle "
          f"(all {args.rounds} rounds): OK")
    if args.trace:
        doc = engine.obs.export(args.trace)
        n_spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
        e2e = obsmetrics.registry().histogram("serve_query_e2e_s")
        print(f"trace: {n_spans} spans -> {args.trace} "
              f"(python -m repro.obs summarize {args.trace}); "
              f"e2e p50={e2e.quantile(0.5) * 1e3:.1f}ms "
              f"p99={e2e.quantile(0.99) * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
