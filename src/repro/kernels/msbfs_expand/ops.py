"""Public wrappers: packed MS-BFS hop and the fused per-level step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..registry import BackendLike, dispatch, register_op
from .kernel import msbfs_expand_pallas, msbfs_step_pallas
from .ref import msbfs_expand_ref, msbfs_step_ref, pack_bits, unpack_bits

__all__ = ["msbfs_hop_packed", "msbfs_step", "pack_bits", "unpack_bits"]


register_op(
    "msbfs_expand",
    pallas=msbfs_expand_pallas,
    interpret=lambda ell, fw: msbfs_expand_pallas(ell, fw, interpret=True),
    jnp=msbfs_expand_ref,
)

register_op(
    "msbfs_step",
    pallas=lambda ell, fw, vis, dist, hop: msbfs_step_pallas(
        ell, fw, vis, dist, hop=hop),
    interpret=lambda ell, fw, vis, dist, hop: msbfs_step_pallas(
        ell, fw, vis, dist, hop=hop, interpret=True),
    jnp=msbfs_step_ref,
)


def msbfs_hop_packed(ell_idx: jax.Array, frontier_words: jax.Array,
                     backend: BackendLike = None) -> jax.Array:
    """frontier_words: (V+1, W) uint32 with sentinel row V zeroed.

    Returns (V+1, W) next frontier (sentinel row re-zeroed).
    """
    fw = frontier_words.at[-1].set(jnp.uint32(0))
    nxt = dispatch("msbfs_expand", backend)(ell_idx, fw)
    zero = jnp.zeros((1, nxt.shape[1]), jnp.uint32)
    return jnp.concatenate([nxt, zero], axis=0)


def msbfs_step(ell_idx: jax.Array, frontier: jax.Array, visited: jax.Array,
               dist: jax.Array, hop: int,
               backend: BackendLike = None):
    """One fused MS-BFS level (expand + dedup + distance write).

    See :func:`~repro.kernels.msbfs_expand.kernel.msbfs_step_pallas` for
    shapes; ``hop`` must be a static Python int (the engine unrolls the
    k_max loop under jit). Returns (next_frontier, visited, dist).
    """
    return dispatch("msbfs_step", backend)(ell_idx, frontier, visited,
                                           dist, hop)
