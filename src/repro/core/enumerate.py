"""Frontier path-enumeration supersteps (TPU form of Alg 1/4 ``Search``).

The recursive DFS of the paper becomes level-synchronous: the level-l
frontier is a PathSet of all simple paths of length exactly l that survive
the slack prune. One superstep expands every frontier path by every
ELL neighbor at once, masks invalid candidates (padding / duplicate vertex /
Lemma-3.1 slack prune / splice triggers), and cumsum-compacts the survivors.

Splice handling (BatchEnum, Alg 4 lines 20-23): vertices that root a
materialized dominating HC-s path query are *not* expanded when the cached
budget covers the remaining budget; the (prefix x cached-suffix) cross join
happens in join.py.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .pathset import PathSet, compact_rows

__all__ = ["ExpandOut", "expand_level", "extract_rows", "select_ending_at",
           "count_ending_at"]


class ExpandOut(NamedTuple):
    frontier: PathSet     # level+1 frontier (spliced candidates excluded)
    nbrs: jax.Array       # (cap, D) raw neighbor matrix (for splice extraction)
    splice_hit: jax.Array  # (cap, D) bool -- candidates redirected to splice


@partial(jax.jit, static_argnames=("level", "budget", "out_cap", "backend"))
def expand_level(verts: jax.Array, count: jax.Array,
                 ell_idx: jax.Array, ell_mask: jax.Array,
                 slack: jax.Array, splice_budget: jax.Array,
                 stop_vertex: jax.Array,
                 *, level: int, budget: int, out_cap: int,
                 backend: str = "jnp") -> ExpandOut:
    """One superstep: expand all level-`level` paths by one hop.

    verts:  (cap, L) int32 frontier paths (cols 0..level used).
    slack:  (n+1,) int8 -- keep candidate v at depth d iff slack[v] >= d.
    splice_budget: (n+1,) int8 -- kappa' of a materialized dominating query
            rooted at v, else -1. Candidates with
            splice_budget[v] >= budget-(level+1) splice instead of expanding.
    stop_vertex: () int32 -- do not expand *from* this vertex (dedicated
            query optimization; pass -2 to disable).
    backend: static resolved kernel backend; ``pallas``/``interpret`` route
            the duplicate-vertex mask through one kernels/path_join
            membership dispatch instead of the broadcast-compare chain.
    """
    cap, L = verts.shape
    n = ell_idx.shape[0] - 1  # ell tables carry a sentinel row n
    D = ell_idx.shape[1]
    row_valid = jnp.arange(cap) < count
    last = jnp.where(row_valid, verts[:, level], n)
    nbrs = ell_idx[last]                             # (cap, D)
    valid = ell_mask[last] & row_valid[:, None]
    valid &= (last != stop_vertex)[:, None]
    # duplicate-vertex mask: candidate already on the path
    if backend != "jnp":
        from ..kernels.path_join.ops import path_member
        dup = path_member(verts[:, :level + 1], nbrs, backend=backend)
    else:
        dup = (nbrs[:, :, None] == verts[:, None, :level + 1]).any(-1)
    # Lemma 3.1 prune at depth level+1
    keep = valid & ~dup & (slack[nbrs] >= level + 1)
    # splice triggers (cached dominating query covers the remaining budget)
    remaining = budget - (level + 1)
    splice_hit = keep & (splice_budget[nbrs] >= remaining)
    expand_mask = keep & ~splice_hit

    # build candidate rows: prefix + new vertex at column level+1
    flat_mask = expand_mask.reshape(-1)
    rows = jnp.repeat(jnp.arange(cap), D)
    cand = verts[rows]                               # (cap*D, L)
    cand = cand.at[:, level + 1].set(nbrs.reshape(-1))
    out, n_out, ovf = compact_rows(flat_mask, cand, out_cap)
    return ExpandOut(frontier=PathSet(out, n_out, ovf),
                     nbrs=nbrs, splice_hit=splice_hit)


@partial(jax.jit, static_argnames=("out_cap",))
def extract_rows(verts: jax.Array, row_mask: jax.Array, *, out_cap: int) -> PathSet:
    """Compact the rows of `verts` where row_mask is True."""
    out, n_out, ovf = compact_rows(row_mask, verts, out_cap)
    return PathSet(out, n_out, ovf)


@partial(jax.jit, static_argnames=("col",))
def count_ending_at(verts: jax.Array, count: jax.Array, vertex,
                    *, col: int) -> jax.Array:
    """Number of rows ending (column `col`) at `vertex` — a mask reduction,
    no compaction and no output buffer (count-/exists-only fast path)."""
    cap = verts.shape[0]
    mask = (jnp.arange(cap) < count) & (verts[:, col] == vertex)
    return mask.sum(dtype=jnp.int32)


@partial(jax.jit, static_argnames=("col", "out_cap"))
def select_ending_at(verts: jax.Array, count: jax.Array, vertex,
                     *, col: int, out_cap: int) -> PathSet:
    """Rows whose path ends (column `col`) at `vertex` (forward-complete paths)."""
    cap = verts.shape[0]
    mask = (jnp.arange(cap) < count) & (verts[:, col] == vertex)
    out, n_out, ovf = compact_rows(mask, verts, out_cap)
    return PathSet(out, n_out, ovf)
