"""Unit + property tests for the static-shape substrate: PathSet compaction,
concat packing, the ⊕ bucket join vs a brute-force join, and the DP
capacity planner's upper-bound property."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core.pathset import PathSet, compact_rows, concat, empty, singleton
from repro.core.join import keyed_join, cross_join, sort_by_last


class TestCompact:
    @given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 6))
    @settings(max_examples=25, deadline=None)
    def test_compact_keeps_masked_rows_in_order(self, n, cap, seed):
        r = np.random.default_rng(seed)
        mask = jnp.asarray(r.random(n) < 0.5)
        payload = jnp.asarray(r.integers(0, 100, (n, 3)).astype(np.int32))
        out, count, ovf = compact_rows(mask, payload, cap)
        kept = np.asarray(payload)[np.asarray(mask)]
        expect = kept[:cap]
        assert int(count) == min(kept.shape[0], cap)
        assert bool(ovf) == (kept.shape[0] > cap)
        assert np.array_equal(np.asarray(out)[:int(count)], expect)

    def test_concat_packs(self):
        a = singleton(5, 3)
        b = PathSet(jnp.asarray([[1, 2, -1], [3, 4, -1]], jnp.int32),
                    jnp.int32(2), jnp.bool_(False))
        c = concat([a, b])
        assert int(c.count) == 3
        rows = np.asarray(c.verts)[:3]
        assert rows[0][0] == 5 and rows[1][0] == 1 and rows[2][0] == 3

    def test_empty(self):
        e = empty(4, 2)
        assert int(e.count) == 0 and e.verts.shape == (4, 2)


def _brute_join(A, a_col, B, b_col, width):
    out = set()
    for pa in A:
        if pa[0] < 0:
            continue
        for pb in B:
            if pb[0] < 0:
                continue
            if pa[a_col] != pb[b_col] or pa[a_col] < 0:
                continue
            path = list(pa[:a_col + 1]) + list(pb[:b_col][::-1])
            if len(set(path)) != len(path):
                continue
            out.add(tuple(path + [-1] * (width - len(path))))
    return out


class TestKeyedJoin:
    @given(st.integers(1, 20), st.integers(1, 20), st.integers(0, 8))
    @settings(max_examples=25, deadline=None)
    def test_matches_bruteforce(self, na, nb, seed):
        r = np.random.default_rng(seed)
        a_col, b_col = 2, 2
        width = a_col + b_col + 1
        A = r.integers(0, 8, (na, a_col + 1)).astype(np.int32)
        B = r.integers(0, 8, (nb, b_col + 1)).astype(np.int32)
        # make rows simple internally (join machinery assumes halves simple)
        keep_a = np.array([len(set(row)) == len(row) for row in A])
        keep_b = np.array([len(set(row)) == len(row) for row in B])
        A, B = A[keep_a], B[keep_b]
        if len(A) == 0 or len(B) == 0:
            return
        sa = sort_by_last(jnp.asarray(A), jnp.int32(len(A)), col=a_col)
        res = keyed_join(sa, jnp.asarray(B), jnp.int32(len(B)),
                         a_col=a_col, b_col=b_col, out_cap=256,
                         out_width=width)
        got = {tuple(int(x) for x in row)
               for row in np.asarray(res.verts)[:int(res.count)]}
        assert got == _brute_join(A, a_col, B, b_col, width)

    def test_overflow_flag(self):
        A = np.zeros((8, 2), np.int32)       # all join on vertex 0
        A[:, 1] = 0
        A[:, 0] = np.arange(1, 9)
        B = np.zeros((8, 2), np.int32)
        B[:, 0] = 9
        B[:, 1] = 0
        sa = sort_by_last(jnp.asarray(A), jnp.int32(8), col=1)
        res = keyed_join(sa, jnp.asarray(B), jnp.int32(8), a_col=1, b_col=1,
                         out_cap=4, out_width=3)
        assert bool(res.overflow)


class TestCrossJoin:
    def test_splice_semantics(self):
        P = jnp.asarray([[0, 1, -1], [2, 3, -1]], jnp.int32)
        C = jnp.asarray([[4, 5], [1, 6]], jnp.int32)
        res = cross_join(P, jnp.int32(2), C, jnp.int32(2),
                         p_col=1, c_col=1, out_cap=16, out_width=4)
        got = {tuple(int(x) for x in row)
               for row in np.asarray(res.verts)[:int(res.count)]}
        # (0,1)+(1,6) shares vertex 1 -> dropped; other three valid
        assert got == {(0, 1, 4, 5), (2, 3, 4, 5), (2, 3, 1, 6)}


class TestWalkCountsUpperBound:
    @given(st.integers(10, 40), st.integers(10, 80), st.integers(0, 5),
           st.integers(2, 4))
    @settings(max_examples=15, deadline=None)
    def test_dp_bounds_simple_path_counts(self, n, m, seed, k):
        """The DP plan is an upper bound on true per-level simple-path
        counts (so planned capacities never overflow)."""
        from repro.core.graph import Graph, DeviceGraph
        from repro.core.index import walk_counts
        r = np.random.default_rng(seed)
        g = Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))
        dg = DeviceGraph.build(g)
        s = int(r.integers(0, n))
        slack = jnp.asarray(np.full(n + 1, 127, np.int8))  # no pruning
        tot = np.asarray(walk_counts(dg.esrc, dg.edst, s, slack,
                                     n=g.n, budget=k))
        # count true simple paths from s per level by DFS
        counts = np.zeros(k + 1, np.int64)
        counts[0] = 1
        stack = [(s, (s,))]
        while stack:
            u, path = stack.pop()
            d = len(path) - 1
            if d == k:
                continue
            for v in g.neighbors(u):
                v = int(v)
                if v in path:
                    continue
                counts[d + 1] += 1
                stack.append((v, path + (v,)))
        assert np.all(tot + 1e-6 >= counts)
