"""Streaming batch query serving (the paper's deployment shape, made
continuous).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --queries 64 \
        --similarity 0.6 --groups 2 --rounds 3 --cache-mb 256

Queries arrive one at a time and are coalesced into micro-batches by a
deadline/size admission policy. Each micro-batch is clustered with a
*cache-aware* bias (queries whose half-query results are already warm in
the cross-batch ``SharedPathCache`` are pulled together), the clusters go
to replica groups through the work-stealing scheduler, and the engine
executes them consulting the cache before materializing any Ψ node.
Per-batch latency, sharing and cache hit/miss stats are logged; a result
sample is validated against the oracle.
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from ..core import BatchPathEngine, EngineConfig, build_index
from ..core import generators
from ..core.planner import admission_fast_path
from ..core.query import PathQuery, Planner, QueryLike, QueryResult
from ..core.clustering import cluster_queries
from ..core.similarity import similarity_matrix
from ..ft.scheduler import WorkStealingScheduler
from ..obs import metrics as obsmetrics

__all__ = ["AdmissionPolicy", "StreamingServer", "serve_batch",
           "warm_cluster_bias"]


@dataclasses.dataclass
class AdmissionPolicy:
    """When to close the open micro-batch and admit it to the engine."""

    max_batch: int = 32         # admit as soon as this many queries wait
    max_delay_s: float = 0.02   # ... or the oldest has waited this long
    min_batch: int = 1          # never admit fewer, unless the deadline
    # has passed (the deadline overrides min_batch: a lone query older
    # than max_delay_s must not starve until drain())

    def due(self, n_waiting: int, oldest_wait_s: float) -> bool:
        if n_waiting <= 0:
            return False
        if oldest_wait_s >= self.max_delay_s:
            return True
        if n_waiting < self.min_batch:
            return False
        return n_waiting >= self.max_batch


def warm_cluster_bias(engine: BatchPathEngine, queries: Sequence[QueryLike],
                      eps: float = 0.08) -> Optional[np.ndarray]:
    """(Q, Q) additive clustering bonus from cross-batch cache warmth.

    Two queries get a bonus when they share a half-query root (same source
    or same target) and the cache holds results enumerated from that root —
    landing them in the same cluster makes the plan regenerate the cached
    node's signature so the hit actually fires. A root-warmth probe is a
    heuristic (the consumer-set part of the key may still differ); a wrong
    bonus costs nothing but a slightly different clustering.
    """
    cache = engine.cache
    if cache is None or len(queries) < 2:
        return None
    if any(not isinstance(q, PathQuery) for q in queries):
        # coerce only mixed/legacy inputs; the admission hot path hands
        # us already-validated PathQuery objects every micro-batch
        queries = [PathQuery.coerce(q) for q in queries]
    warm_f = [cache.has_root("f", q.s) for q in queries]
    warm_b = [cache.has_root("b", q.t) for q in queries]
    Q = len(queries)
    bias = np.zeros((Q, Q), np.float64)
    src = np.array([q.s for q in queries])
    tgt = np.array([q.t for q in queries])
    wf = np.array(warm_f)
    wb = np.array(warm_b)
    same_src = (src[:, None] == src[None, :]) & wf[:, None] & wf[None, :]
    same_tgt = (tgt[:, None] == tgt[None, :]) & wb[:, None] & wb[None, :]
    bias += eps * same_src + eps * same_tgt
    np.fill_diagonal(bias, 0.0)
    return bias if bias.any() else None


class StreamingServer:
    """Continuous admission loop over a shared engine + scheduler.

    Usage::

        srv = StreamingServer(engine, n_groups=2)
        qid = srv.submit((s, t, k))     # returns a stable query id
        srv.apply_delta(delta)          # edge churn: applied at the next
                                        # micro-batch boundary (see delta_log)
        srv.pump()                      # admit due micro-batches (call often)
        srv.drain()                     # flush everything still waiting
        srv.results[qid]                # QueryResult (same type as batch runs)

    Submissions are validated eagerly (``PathQuery`` coercion + graph
    bounds), so one malformed query is rejected at submit time instead of
    failing an entire admitted micro-batch inside the engine. The engine's
    cross-batch cache (if configured) persists across micro-batches;
    per-batch cache hit/miss and materialization stats are appended to
    ``batch_log``.
    """

    def __init__(self, engine: BatchPathEngine, n_groups: int = 2,
                 gamma: Optional[float] = None,
                 policy: Optional[AdmissionPolicy] = None,
                 warm_bias_eps: float = 0.08,
                 planner: Planner | str = Planner.BATCH):
        self.engine = engine
        self.n_groups = n_groups
        self.gamma = engine.cfg.gamma if gamma is None else gamma
        self.policy = policy or AdmissionPolicy()
        self.warm_bias_eps = warm_bias_eps
        # planner for admitted micro-batches; AUTO additionally turns on
        # the submit-time fast path (certainly-GREEN queries answered
        # immediately instead of waiting out micro-batch coalescing)
        self.planner = Planner.coerce(planner)
        self.n_fast_path = 0
        self.sched = WorkStealingScheduler(
            n_groups, cost_fn=lambda qs: float(len(qs)) ** 1.5)
        self.results: dict[int, QueryResult] = {}
        self.batch_log: list[dict] = []
        self.delta_log: list[dict] = []             # per-delta engine reports
        self._waiting: list[tuple[int, PathQuery, float]] = []
        self._query_of: dict[int, PathQuery] = {}   # qid -> query
        self._pending_deltas: list = []             # applied at batch boundary
        self._delta_mark = 0       # delta_log watermark of the last batch
        self._next_qid = 0

    # -- ingress -------------------------------------------------------
    def submit(self, query: QueryLike, now: Optional[float] = None) -> int:
        """Validate and enqueue one query; returns a stable query id.

        Raises ValueError immediately for malformed queries (bad arity,
        s == t, k < 1, vertices outside the graph) — admission never sees
        them, so they cannot poison a micro-batch.

        Under ``planner=AUTO``, certainly-GREEN queries (exists-only; see
        ``core.planner.admission_fast_path``) bypass coalescing entirely:
        they are answered here, against the graph as of the last flushed
        delta (the same boundary semantics an admitted batch would see —
        queued-but-unflushed deltas apply at the *next* batch boundary,
        which this fast path never waits for).
        """
        q = PathQuery.coerce(query).check_bounds(self.engine.g.n)
        qid = self._next_qid
        self._next_qid += 1
        self._query_of[qid] = q
        if self.planner is Planner.AUTO and admission_fast_path(q):
            reg = obsmetrics.registry()
            reg.counter("serve_fast_path_total").inc()
            with self.engine.obs.span("serve.fast_path"):
                r = self.engine.run([q], planner=Planner.AUTO)
            self.results[qid] = r[0].offload()
            self.n_fast_path += 1
            reg.histogram("serve_admission_wait_s").record(0.0)
            reg.histogram("serve_query_e2e_s").record(
                r.stats.get("t_wall_s", 0.0))
            return qid
        self._waiting.append((qid, q,
                              time.monotonic() if now is None else now))
        return qid

    def apply_delta(self, delta) -> None:
        """Queue a :class:`~repro.core.delta.GraphDelta` for application at
        the next micro-batch boundary.

        Deltas never interleave with an admitted batch — queries already
        handed to the engine finish against the graph they were admitted
        under, and every later admission sees the mutated graph. Queued
        deltas are flushed (in submission order) by ``pump()`` / ``drain()``
        even when no query batch is due; per-delta engine reports (CSR
        merge sizes, hop-scoped cache eviction counts) append to
        ``delta_log``, and the next batch's ``batch_log`` entry carries the
        aggregated delta/invalidation counters.

        Validated eagerly, like ``submit``: deltas only mutate edges (the
        vertex set is fixed), so an out-of-range vertex id is rejected
        here — not mid-flush, where the failed delta would be lost from
        the queue while later deltas still applied.
        """
        n = self.engine.g.n
        if delta.max_vertex() >= n:
            raise ValueError(f"delta references vertices outside the graph "
                             f"(n={n}, max id {delta.max_vertex()})")
        self._pending_deltas.append(delta)

    def flush_deltas(self) -> None:
        """Apply every queued delta now (the caller asserts this is a
        batch boundary — pump/drain/admission call it automatically, and
        ``PathSession.run`` does before a one-shot batch). A delta is
        dequeued only after it applied: if the engine raises mid-flush, the
        failed delta stays at the head so a retry cannot silently skip it
        while later deltas apply."""
        while self._pending_deltas:
            self.delta_log.append(
                self.engine.apply_delta(self._pending_deltas[0]))
            self._pending_deltas.pop(0)

    def discard_pending_deltas(self) -> list:
        """Drop queued deltas unapplied; returns them. A full graph swap
        supersedes edge deltas expressed against the replaced graph —
        applying them to the new graph would corrupt it (or crash on
        out-of-range vertices)."""
        dropped, self._pending_deltas = self._pending_deltas, []
        return dropped

    def pump(self, now: Optional[float] = None) -> bool:
        """Admit every micro-batch the policy says is due (a burst can
        leave several deadline-expired batches queued at once). Queued
        graph deltas are applied first — a batch boundary by definition."""
        self.flush_deltas()
        admitted = False
        now = time.monotonic() if now is None else now
        while self._waiting:
            oldest = now - min(arr for _, _, arr in self._waiting)
            if not self.policy.due(len(self._waiting), oldest):
                break
            self._admit()
            admitted = True
        return admitted

    def drain(self) -> None:
        """Flush: admit everything still waiting, policy notwithstanding."""
        self.flush_deltas()
        while self._waiting:
            self._admit()

    def take(self, qid: int) -> QueryResult:
        """Pop a finished query's QueryResult (KeyError if not finished).

        A continuous server must drain ``results`` this way — entries are
        kept until taken, so an untaken backlog grows without bound.
        """
        out = self.results.pop(qid)   # KeyError first: keep pending intact
        self._query_of.pop(qid, None)
        return out

    # -- one micro-batch -----------------------------------------------
    def _admit(self) -> None:
        self.flush_deltas()   # an admission IS a micro-batch boundary
        deltas = self.delta_log[self._delta_mark:]
        self._delta_mark = len(self.delta_log)
        batch = self._waiting[:self.policy.max_batch]
        self._waiting = self._waiting[self.policy.max_batch:]
        qids = [qid for qid, _, _ in batch]
        queries = [q for _, q, _ in batch]
        # admission wait: submit -> this batch boundary, per query
        t_admit = time.monotonic()
        waits = [t_admit - arr for _, _, arr in batch]
        reg = obsmetrics.registry()
        h_wait = reg.histogram("serve_admission_wait_s")
        for w in waits:
            h_wait.record(w)
        with self.engine.obs.span("serve.batch",
                                  n_queries=len(batch)) as sb:
            steals_before = self.sched.steals
            with self.engine.obs.span("serve.assemble",
                                      n_queries=len(batch)) as sasm:
                index = build_index(
                    self.engine.dg, [q.key for q in queries],
                    backend=self.engine.kernel_backend.value)
                mu = similarity_matrix(
                    index, backend=self.engine.kernel_backend.value)
                bias = warm_cluster_bias(self.engine, queries,
                                         self.warm_bias_eps)
                # balance_clusters must act HERE, not just inside
                # engine.run — the engine keeps an explicitly passed
                # clustering verbatim, so a similar-traffic micro-batch
                # merged to one cluster would idle every replica but one
                min_clusters = 1
                executor = self.engine.executor
                if self.engine.cfg.balance_clusters and executor is not None:
                    min_clusters = executor.n_replicas
                clusters = cluster_queries(mu, self.gamma, bias=bias,
                                           min_clusters=min_clusters)
            # scheduler items carry global qids so a requeued item from
            # any earlier micro-batch still resolves to the right queries
            # n_compiles / n_retraces stay 0 unless the engine runs with
            # EngineConfig.log_compiles — then each batch_log entry shows
            # whether this micro-batch hit warm XLA compiles (retraces ==
            # 0) or paid a trace (e.g. after a shape-bucket crossing)
            agg = {"n_psi_nodes": 0, "n_materialized": 0,
                   "n_cache_hits": 0, "n_cache_misses": 0,
                   "n_compiles": 0, "n_retraces": 0,
                   "routed_green": 0, "routed_yellow": 0, "routed_red": 0}
            per_device = None
            executor = self.engine.executor
            if executor is not None and executor.sharded:
                # mesh-parallel serving: the executor's greedy
                # cost-balanced placement replaces the host work-stealing
                # loop — one run carries every (cache-aware) cluster,
                # fanned across the per-device replicas and gathered back
                r = self.engine.run(queries, planner=self.planner,
                                    clusters=clusters)
                for i, qid in enumerate(qids):
                    self.results[qid] = r[i].offload()
                for key in agg:
                    agg[key] += r.stats.get(key, 0)
                per_device = r.stats.get("per_device")
            else:
                cids = self.sched.submit([[qids[li] for li in cl]
                                          for cl in clusters])
                open_cids = set(cids)
                while open_cids:
                    progressed = False
                    for grp in range(self.n_groups):
                        item = self.sched.next_for(grp)
                        if item is None:
                            continue
                        progressed = True
                        sub = [self._query_of[qid] for qid in item.queries]
                        # the item IS one cluster — pass it through so the
                        # engine keeps our (cache-aware) grouping instead
                        # of re-clustering
                        r = self.engine.run(sub, planner=self.planner,
                                            clusters=[list(range(len(sub)))])
                        for i, qid in enumerate(item.queries):
                            # results may sit untaken indefinitely —
                            # offload so the backlog holds compact host
                            # rows, not padded device buffers (count/
                            # exists results hold none)
                            self.results[qid] = r[i].offload()
                        for key in agg:
                            agg[key] += r.stats.get(key, 0)
                        self.sched.complete(item.cluster_id, True)
                        open_cids.discard(item.cluster_id)
                    if not progressed and not any(
                            cid in self.sched.in_flight for cid in open_cids):
                        break   # nothing runnable (foreign in-flight only)
        wall = sb.duration
        # end-to-end latency: submit -> results resident, per query
        t_done = time.monotonic()
        e2e = [t_done - arr for _, _, arr in batch]
        h_e2e = reg.histogram("serve_query_e2e_s")
        for v in e2e:
            h_e2e.record(v)
        Q = len(queries)
        self.batch_log.append({
            "wall_s": wall, "n_queries": Q, "n_clusters": len(clusters),
            "kernel_backend": self.engine.kernel_backend.value,
            "steals": self.sched.steals - steals_before,
            "warm_biased": bias is not None,
            # micro-batch assembly (index + similarity + clustering) and
            # the per-query latency shape of this admission window
            "t_assemble_s": sasm.duration,
            "admission_wait_p50_s": float(np.percentile(waits, 50)),
            "admission_wait_max_s": float(max(waits)),
            "e2e_p50_s": float(np.percentile(e2e, 50)),
            "e2e_p99_s": float(np.percentile(e2e, 99)),
            "mu_mean": float((mu.sum() - Q) / max(Q * (Q - 1), 1)),
            # graph deltas applied since the previous micro-batch
            "n_deltas": len(deltas),
            "delta_edges": sum(d["n_added"] + d["n_removed"] for d in deltas),
            "delta_cache_evicted": sum(d.get("cache_evicted", 0)
                                       for d in deltas),
            # survivors after the last delta that actually touched the
            # cache (a trailing no-op delta reports nothing)
            "delta_cache_kept": next((d["cache_kept"] for d in
                                      reversed(deltas) if "cache_kept" in d),
                                     0),
            # retraces paid inside apply_delta itself (0 for in-bucket
            # churn; nonzero only when a delta crossed a shape bucket)
            "delta_retraces": sum(d.get("n_retraces", 0) for d in deltas),
            **({"per_device": per_device,
                "n_devices": len(per_device)} if per_device else {}),
            **agg,
            **({"cache": self.engine.cache.info()}
               if self.engine.cache is not None else {}),
        })


def serve_batch(engine: BatchPathEngine, queries, n_groups: int = 2,
                gamma: float = 0.5):
    """One-shot batch serving (compat wrapper over the streaming loop).

    Cluster -> schedule -> process with stealing. Returns (results, info)
    where results maps query index -> QueryResult. New code should prefer
    ``PathSession`` (``repro.core.session``), which fronts the same loop.
    """
    srv = StreamingServer(engine, n_groups=n_groups, gamma=gamma,
                          policy=AdmissionPolicy(max_batch=max(len(queries), 1),
                                                 max_delay_s=0.0))
    for q in queries:
        srv.submit(q)
    srv.drain()
    # deep copy: batch_log entries hold nested dicts (cache info,
    # per-device stats) that later batches/deltas keep mutating — a
    # shallow dict() would alias them into the returned snapshot
    info = copy.deepcopy(srv.batch_log[-1]) if srv.batch_log \
        else {"wall_s": 0.0}
    return srv.results, info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--similarity", type=float, default=0.6)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--k-min", type=int, default=4)
    ap.add_argument("--k-max", type=int, default=5)
    ap.add_argument("--validate", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=1,
                    help="repeat the workload to exercise the warm cache")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cross-batch cache budget in MiB (0 disables)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--devices", type=int, default=0,
                    help="shard over the first N local devices (0 = plain "
                         "single-device; see docs/serving.md §Sharded)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record stage spans and export a Chrome-trace "
                         "JSON here at exit (open in chrome://tracing or "
                         "ui.perfetto.dev; see docs/observability.md)")
    ap.add_argument("--jax-profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the serving "
                         "rounds into this TensorBoard logdir")
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    g = generators.community(args.n, n_comm=max(4, args.n // 2500),
                             avg_deg=6.0, seed=0)
    engine = BatchPathEngine(g, EngineConfig(
        min_cap=128, cache_bytes=args.cache_mb << 20,
        n_devices=args.devices or None,
        trace=args.trace is not None,
        trace_annotations=args.jax_profile is not None))
    queries = generators.similar_queries(g, args.queries, args.similarity,
                                         (args.k_min, args.k_max), seed=1)
    srv = StreamingServer(engine, n_groups=args.groups,
                          policy=AdmissionPolicy(max_batch=args.max_batch,
                                                 max_delay_s=0.0))
    from ..obs import jaxprof
    qids_by_round = []
    with jaxprof.profile_run(args.jax_profile):
        for _ in range(args.rounds):
            qids_by_round.append([srv.submit(q) for q in queries])
            srv.drain()
    for bi, b in enumerate(srv.batch_log):
        cache = b.get("cache", {})
        print(f"batch {bi}: {b['n_queries']} queries, "
              f"{b['n_clusters']} clusters, {b['wall_s']:.2f}s, "
              f"psi={b['n_psi_nodes']} materialized={b['n_materialized']} "
              f"hits={b['n_cache_hits']} "
              f"(cache: {cache.get('entries', 0)} entries, "
              f"{cache.get('nbytes', 0) >> 20} MiB)")
    n_paths = sum(srv.results[qid].count for qid in qids_by_round[0])
    print(f"served {args.rounds}x{len(queries)} queries -> "
          f"{n_paths} paths per round")
    # oracle validation sample + cross-round consistency
    from ..core.oracle import enumerate_paths_bruteforce, path_set
    rng = np.random.default_rng(0)
    for qi in rng.choice(len(queries), size=min(args.validate, len(queries)),
                         replace=False):
        s, t, k = queries[qi]
        truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
        for round_qids in qids_by_round:
            assert path_set(srv.results[round_qids[qi]].paths) == truth
    print(f"validated {args.validate} queries against the oracle "
          f"(all {args.rounds} rounds): OK")
    if args.trace:
        doc = engine.obs.export(args.trace)
        n_spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
        e2e = obsmetrics.registry().histogram("serve_query_e2e_s")
        print(f"trace: {n_spans} spans -> {args.trace} "
              f"(python -m repro.obs summarize {args.trace}); "
              f"e2e p50={e2e.quantile(0.5) * 1e3:.1f}ms "
              f"p99={e2e.quantile(0.99) * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
