"""Dynamic-graph subsystem: ``GraphDelta`` normalization, ``apply_delta``
equivalence with full ``from_edges`` rebuilds (CSR both directions, ELL
views, self-loop/duplicate handling — property-tested on random edge-churn
sequences), device-view patching, hop-scoped cache invalidation semantics,
and delta-at-micro-batch-boundary streaming behavior."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core import (BatchPathEngine, EngineConfig, GraphDelta,
                        PathSession, generators)
from repro.core.cache import SharedPathCache, dedicated_keys
from repro.core.delta import apply_delta, update_device_graph
from repro.core.graph import DeviceGraph, Graph
from repro.core.oracle import (bfs_dist_from, enumerate_paths_bruteforce,
                               path_set)
from repro.core.pathset import PathSet, offload, pathset_nbytes
from repro.core.query import midpoint_split
from repro.launch.serve import AdmissionPolicy, StreamingServer

import jax.numpy as jnp


def _edge_list(g: Graph):
    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    return src, g.indices.astype(np.int64)


def _rebuild_after(g: Graph, delta: GraphDelta) -> Graph:
    """Reference successor: edit the edge set, full from_edges rebuild."""
    src, dst = _edge_list(g)
    old = set(zip(src.tolist(), dst.tolist()))
    new = ((old - set(zip(delta.del_src.tolist(), delta.del_dst.tolist())))
           | set(zip(delta.add_src.tolist(), delta.add_dst.tolist())))
    ns = np.array([u for u, _ in new], np.int64)
    nd = np.array([v for _, v in new], np.int64)
    return Graph.from_edges(g.n, ns, nd)


def _assert_graph_equal(a: Graph, b: Graph):
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.r_indptr, b.r_indptr)
    np.testing.assert_array_equal(a.r_indices, b.r_indices)


def _random_delta(g: Graph, rng, n_add=6, n_del=6) -> GraphDelta:
    """Messy delta: self-loops, duplicates, absent deletes, present adds."""
    n = g.n
    a_s = rng.integers(0, n, n_add)
    a_d = rng.integers(0, n, n_add)
    src, dst = _edge_list(g)
    if g.m:
        pick = rng.integers(0, g.m, max(n_del // 2, 1))
        d_s = np.concatenate([src[pick], rng.integers(0, n, n_del)])
        d_d = np.concatenate([dst[pick], rng.integers(0, n, n_del)])
    else:
        d_s, d_d = rng.integers(0, n, n_del), rng.integers(0, n, n_del)
    return GraphDelta(a_s, a_d, d_s, d_d)


class TestGraphDelta:
    def test_normalization_drops_self_loops_and_dups(self):
        d = GraphDelta([1, 1, 2, 3], [2, 2, 4, 3], [5, 5], [6, 6])
        assert d.n_add == 2            # (1,2) deduped, (3,3) loop dropped
        assert d.n_del == 1
        assert bool(d)
        assert not bool(GraphDelta.empty())

    def test_from_pairs_and_max_vertex(self):
        d = GraphDelta.from_pairs(add=[(0, 9)], remove=[(4, 2)])
        assert d.max_vertex() == 9
        assert GraphDelta.empty().max_vertex() == -1

    def test_negative_ids_rejected(self):
        with pytest.raises(ValueError):
            GraphDelta([-1], [0], [], [])

    def test_out_of_bounds_rejected_at_apply(self):
        g = generators.erdos(10, 2.0, seed=0)
        with pytest.raises(ValueError):
            apply_delta(g, GraphDelta.from_pairs(add=[(0, 10)]))


class TestApplyDelta:
    def test_matches_full_rebuild_deterministic_churn(self):
        rng = np.random.default_rng(3)
        g = generators.community(120, n_comm=3, avg_deg=4.0, seed=1)
        for _ in range(12):   # a churn *sequence*: deltas compound
            delta = _random_delta(g, rng)
            ref = _rebuild_after(g, delta)
            applied = apply_delta(g, delta)
            _assert_graph_equal(applied.graph, ref)
            # touched == endpoints of effective changes, no-ops excluded
            old = set(zip(*(x.tolist() for x in _edge_list(g))))
            new = set(zip(*(x.tolist() for x in _edge_list(ref))))
            want = sorted({v for e in (old ^ new) for v in e})
            assert applied.touched.tolist() == want
            g = applied.graph

    def test_noop_delta_returns_same_graph(self):
        g = generators.erdos(30, 3.0, seed=2)
        src, dst = _edge_list(g)
        delta = GraphDelta.from_pairs(
            add=[(int(src[0]), int(dst[0]))],      # already present
            remove=[(int(src[1]), int(dst[1] + 1) % g.n)]
            if (int(src[1]), (int(dst[1]) + 1) % g.n) not in
            set(zip(src.tolist(), dst.tolist())) else [])
        applied = apply_delta(g, delta)
        assert applied.n_changed == 0 and applied.graph is g
        g2, touched = g.apply_delta(delta)
        assert g2 is g and touched.size == 0

    def test_delete_then_add_same_edge_is_noop(self):
        g = generators.erdos(30, 3.0, seed=4)
        src, dst = _edge_list(g)
        e = (int(src[0]), int(dst[0]))
        applied = apply_delta(g, GraphDelta.from_pairs(add=[e], remove=[e]))
        assert applied.n_changed == 0     # new = (old - e) | e == old

    def test_ell_views_match_rebuild(self):
        rng = np.random.default_rng(5)
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=3)
        delta = _random_delta(g, rng)
        g2 = apply_delta(g, delta).graph
        ref = _rebuild_after(g, delta)
        for reverse in (False, True):
            cap = max(int(np.diff(ref.r_indptr if reverse else
                                  ref.indptr).max()), 1)
            e1, e2 = g2.ell(cap, reverse), ref.ell(cap, reverse)
            np.testing.assert_array_equal(e1.idx, e2.idx)
            np.testing.assert_array_equal(e1.mask, e2.mask)

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_property_churn_equivalence(self, data):
        n = data.draw(st.integers(3, 50), label="n")
        m = data.draw(st.integers(0, 150), label="m")
        seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
        rng = np.random.default_rng(seed)
        g = Graph.from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
        for _ in range(data.draw(st.integers(1, 3), label="rounds")):
            delta = _random_delta(g, rng,
                                  n_add=data.draw(st.integers(0, 12)),
                                  n_del=data.draw(st.integers(0, 12)))
            ref = _rebuild_after(g, delta)
            applied = apply_delta(g, delta)
            _assert_graph_equal(applied.graph, ref)
            g = applied.graph


class TestDeviceGraphUpdate:
    def test_incremental_patch_matches_build(self):
        rng = np.random.default_rng(6)
        g = generators.community(70, n_comm=2, avg_deg=4.0, seed=7)
        dg = DeviceGraph.build(g)
        # keep per-row degree within the existing caps: rewire existing
        # edges (delete one, add one from the same source)
        src, dst = _edge_list(g)
        i = int(rng.integers(0, g.m))
        u, v = int(src[i]), int(dst[i])
        w = next(int(x) for x in rng.permutation(g.n)
                 if x != u and x not in g.neighbors(u))
        applied = apply_delta(g, GraphDelta.from_pairs(add=[(u, w)],
                                                       remove=[(u, v)]))
        dg2, incremental = update_device_graph(dg, applied)
        assert incremental
        g2 = applied.graph
        assert dg2.ell_cap == dg.ell_cap and dg2.r_ell_cap == dg.r_ell_cap
        # a rewire keeps m constant: the edge bucket (and with it every
        # kernel shape) must be preserved, valid prefix exact, sentinel
        # (n, n) edges beyond it
        assert dg2.m_cap == dg.m_cap and dg2.m == g2.m
        esrc, edst = g2.edges_by_dst
        r_esrc, r_edst = g2.r_edges_by_dst
        for got, want in ((dg2.esrc, esrc), (dg2.edst, edst),
                          (dg2.r_esrc, r_esrc), (dg2.r_edst, r_edst)):
            got = np.asarray(got)
            np.testing.assert_array_equal(got[:g2.m], want)
            assert np.all(got[g2.m:] == g2.n)
        ell = g2.ell(cap=dg2.ell_cap)
        rell = g2.reverse().ell(cap=dg2.r_ell_cap)
        np.testing.assert_array_equal(np.asarray(dg2.ell_idx), ell.idx)
        np.testing.assert_array_equal(np.asarray(dg2.ell_mask), ell.mask)
        np.testing.assert_array_equal(np.asarray(dg2.r_ell_idx), rell.idx)
        np.testing.assert_array_equal(np.asarray(dg2.r_ell_mask), rell.mask)
        assert dg2.m == g2.m

    def test_cap_overflow_falls_back_to_rebuild(self):
        g = Graph.from_edges(5, [0, 1], [1, 2])   # max out-degree 1
        dg = DeviceGraph.build(g)
        applied = apply_delta(g, GraphDelta.from_pairs(add=[(0, 2), (0, 3)]))
        dg2, incremental = update_device_graph(dg, applied)
        assert not incremental and dg2.ell_cap >= 3
        ref = DeviceGraph.build(applied.graph)
        np.testing.assert_array_equal(np.asarray(dg2.ell_idx),
                                      np.asarray(ref.ell_idx))

    def test_cap_overflow_rebuild_never_shrinks_buckets(self):
        """The ELL-overflow fallback must keep every shape bucket monotone
        (edge cap and both ELL caps): an overflow after deletion-heavy
        churn re-bucketing smaller would re-thrash the next insert wave."""
        g = Graph.from_edges(6, [0, 1, 2], [1, 2, 3])
        # simulate previously grown buckets: a larger edge pad + ELL caps
        dg = DeviceGraph.build(g, edge_cap=16)
        dg = DeviceGraph.build(g, edge_cap=16,
                               min_ell_caps=(dg.ell_cap * 4, dg.r_ell_cap))
        applied = apply_delta(g, GraphDelta.from_pairs(
            add=[(5, v) for v in range(5)]))      # out-row 5: deg 5 > cap
        dg2, incremental = update_device_graph(dg, applied)
        assert not incremental
        assert dg2.m_cap >= dg.m_cap              # edge bucket kept
        assert dg2.ell_cap >= dg.ell_cap          # fwd ELL bucket kept
        assert dg2.r_ell_cap >= dg.r_ell_cap
        # and the rebuilt views are still a correct padded graph
        g2 = applied.graph
        got = np.asarray(dg2.esrc)
        np.testing.assert_array_equal(got[:g2.m], g2.edges_by_dst[0])
        assert np.all(got[g2.m:] == g2.n)

    def test_frontier_dists_agree_on_old_and_new_graph(self):
        """The invalidation invariant: both endpoints of every changed edge
        are seeds, so set distances from the touched frontier are the same
        whether walked on the old or the new graph."""
        from repro.core.delta import host_set_dist
        rng = np.random.default_rng(23)
        for seed in range(4):
            g = generators.erdos(40, 3.0, seed=seed)
            applied = apply_delta(g, _random_delta(g, rng))
            if applied.touched.size == 0:
                continue
            for k_max in (2, 4):
                for reverse in (False, True):
                    d_old = host_set_dist(g, applied, k_max, reverse)
                    d_new = host_set_dist(applied.graph, applied, k_max,
                                          reverse)
                    np.testing.assert_array_equal(d_old, d_new)


def _levels(width=4, rows=4):
    verts = jnp.full((rows, width), -1, jnp.int32).at[:, 0].set(1)
    return [PathSet(verts, jnp.int32(rows), jnp.bool_(False))]


class TestHopScopedInvalidation:
    """invalidate_delta against hand-built distance fields: eviction iff the
    damage intersects the enumeration ball or a consumer prune radius."""

    def _dists(self, n, to=(), frm=()):
        INF = 99
        d_to = np.full(n + 1, INF, np.int32)
        d_from = np.full(n + 1, INF, np.int32)
        for v, d in to:
            d_to[v] = d
        for v, d in frm:
            d_from[v] = d
        return {"to": d_to, "from": d_from}

    def test_far_entries_survive_with_epoch_bump(self):
        c = SharedPathCache()
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())
        info = c.invalidate_delta([5], self._dists(20, to=[(3, 3)],
                                                   frm=[(9, 5)]))
        assert info == {"evicted": 0, "kept": 1, "epoch": 1}
        assert c.contains(("f", 3, 2, ((9, 4),), -2))
        assert c.stats.delta_kept == 1 and c.stats.delta_evictions == 0

    def test_enumeration_ball_eviction(self):
        c = SharedPathCache()
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())   # source can reach
        c.put(("b", 7, 2, ((1, 4),), -2), _levels())   # damage reaches root
        info = c.invalidate_delta([5], self._dists(
            20, to=[(3, 2), (1, 99)], frm=[(7, 1), (9, 99)]))
        assert info["evicted"] == 2 and info["kept"] == 0
        assert not c.has_root("f", 3) and c.nbytes == 0

    def test_consumer_prune_radius_eviction(self):
        c = SharedPathCache()
        # enumeration balls untouched, but an insert lands within a
        # consumer endpoint's prune radius -> the slack mask could loosen
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())
        info = c.invalidate_delta([5], self._dists(20, frm=[(9, 4)]))
        assert info["evicted"] == 1
        c.put(("b", 7, 2, ((1, 4),), -2), _levels())
        info = c.invalidate_delta([5], self._dists(20, to=[(1, 3)]))
        assert info["evicted"] == 1
        assert c.stats.delta_invalidations == 2

    def test_boundary_is_inclusive(self):
        c = SharedPathCache()
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())
        # exactly budget hops away -> a path could end on a changed edge
        assert c.invalidate_delta([5], self._dists(
            20, to=[(3, 2)]))["evicted"] == 1
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())
        assert c.invalidate_delta([5], self._dists(
            20, to=[(3, 3)]))["evicted"] == 0

    def test_empty_touched_keeps_everything(self):
        c = SharedPathCache()
        c.put(("f", 3, 2, ((9, 4),), -2), _levels())
        info = c.invalidate_delta([], {"to": np.empty(0), "from": np.empty(0)})
        assert info["evicted"] == 0 and c.epoch == 1

    def test_epoch_guard_drops_desynced_entries(self):
        """Defensive contract: a resident entry must carry the current
        epoch (invalidate_delta re-stamps survivors); one that somehow
        missed an invalidation pass serves as a miss, never as stale."""
        c = SharedPathCache()
        key = ("f", 3, 2, ((9, 4),), -2)
        c.put(key, _levels())
        assert c.get(key) is not None
        c.epoch += 1                      # simulate a missed invalidation
        assert c.get(key) is None and not c.contains(key)
        assert c.nbytes == 0 and not c.has_root("f", 3)

    def test_max_radius(self):
        c = SharedPathCache()
        assert c.max_radius() == 0
        c.put(("f", 3, 2, ((9, 6),), -2), _levels())
        c.put(("b", 7, 4, ((1, 3),), -2), _levels())
        assert c.max_radius() == 6


class TestSetDist:
    def test_host_backend_matches_device_backend(self):
        """host_set_dist (CSR ball walk) ≡ msbfs_set_dist (device sweep)
        from the touched frontier, both directions, across random deltas."""
        from repro.core.delta import host_set_dist
        from repro.core.msbfs import msbfs_set_dist
        rng = np.random.default_rng(19)
        for seed in range(4):
            g = generators.erdos(50, 3.0, seed=seed)
            dg = DeviceGraph.build(g)
            applied = apply_delta(g, _random_delta(g, rng))
            if applied.touched.size == 0:
                continue
            mask = np.zeros(g.n + 1, np.int8)
            mask[applied.touched] = 1
            for k_max in (1, 3, 5):
                for reverse in (False, True):
                    esrc, edst = ((dg.r_esrc, dg.r_edst) if reverse
                                  else (dg.esrc, dg.edst))
                    want = np.asarray(msbfs_set_dist(
                        esrc, edst, jnp.asarray(mask), n=g.n, k_max=k_max))
                    got = host_set_dist(g, applied, k_max, reverse=reverse)
                    np.testing.assert_array_equal(got, want,
                                                  err_msg=f"{seed} {k_max}")

    def test_msbfs_engine_backend_stays_exact(self):
        g = generators.community(200, n_comm=3, avg_deg=4.0, seed=14)
        qs = generators.similar_queries(g, 5, similarity=0.8,
                                        k_range=(3, 3), seed=15)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=32 << 20,
                                              delta_backend="msbfs"))
        eng.run(qs)
        rng = np.random.default_rng(16)
        rep = eng.apply_delta(_random_delta(g, rng, 3, 3))
        assert rep["cache_mode"] == "delta"
        r = eng.run(qs)
        fresh = BatchPathEngine(eng.g, EngineConfig(min_cap=64))
        rf = fresh.run(qs)
        for qi in range(len(qs)):
            assert path_set(r[qi].paths) == path_set(rf[qi].paths)

    def test_set_dist_is_min_over_sources(self):
        g = generators.erdos(60, 3.0, seed=20)
        dg = DeviceGraph.build(g)
        from repro.core.msbfs import msbfs_dist, msbfs_set_dist
        rng = np.random.default_rng(21)
        seeds = np.unique(rng.integers(0, g.n, 5)).astype(np.int32)
        per_src = np.asarray(msbfs_dist(dg.esrc, dg.edst, jnp.asarray(seeds),
                                        n=g.n, k_max=4))
        mask = np.zeros(g.n + 1, np.int8)
        mask[seeds] = 1
        got = np.asarray(msbfs_set_dist(dg.esrc, dg.edst, jnp.asarray(mask),
                                        n=g.n, k_max=4))
        np.testing.assert_array_equal(got, per_src.min(axis=1))
        assert got[g.n] == 5   # sentinel row stays INF


class TestEngineDelta:
    def _workload(self, n=900, nq=8, seed=0):
        g = generators.community(n, n_comm=max(3, n // 250), avg_deg=4.0,
                                 seed=seed)
        qs = generators.similar_queries(g, nq, similarity=0.85,
                                        k_range=(3, 4), seed=seed + 1)
        return g, qs

    def _cold_edges(self, g, qs, count):
        """Existing edges with both endpoints beyond every query's hop
        radius (the pool the hop-scoped invalidation must keep warm)."""
        hot = np.zeros(g.n, bool)
        for s, t, k in qs:
            hot |= bfs_dist_from(g, s, k) <= k
            hot |= bfs_dist_from(g, t, k, reverse=True) <= k
        cold = ~hot
        src, dst = _edge_list(g)
        idx = np.flatnonzero(cold[src] & cold[dst])
        if idx.size < count + 4:
            pytest.skip("graph too small for a cold edge pool")
        cold_v = np.flatnonzero(cold)
        adds, have = [], set(zip(src.tolist(), dst.tolist()))
        rng = np.random.default_rng(9)
        while len(adds) < count:
            u, v = (int(x) for x in rng.choice(cold_v, 2, replace=False))
            if u != v and (u, v) not in have:
                adds.append((u, v))
        dels = [(int(src[i]), int(dst[i])) for i in idx[:count]]
        return adds, dels

    def test_far_delta_keeps_cache_warm_and_exact(self):
        g, qs = self._workload()
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        eng.run(qs)
        n_entries = len(eng.cache)
        assert n_entries > 0
        adds, dels = self._cold_edges(g, qs, 2)
        rep = eng.apply_delta(GraphDelta.from_pairs(add=adds, remove=dels))
        assert rep["cache_mode"] == "delta"
        assert rep["cache_kept"] == n_entries and rep["cache_evicted"] == 0
        assert rep["device_update"] in ("incremental", "rebuild")
        r2 = eng.run(qs)
        assert r2.stats["n_materialized"] == 0        # fully warm
        fresh = BatchPathEngine(eng.g, EngineConfig(min_cap=64))
        rf = fresh.run(qs)
        for qi, (s, t, k) in enumerate(qs):
            truth = path_set(enumerate_paths_bruteforce(eng.g, s, t, k))
            assert path_set(r2[qi].paths) == truth, f"warm q{qi}"
            assert path_set(rf[qi].paths) == truth, f"fresh q{qi}"

    def test_near_delta_evicts_and_stays_exact(self):
        g, qs = self._workload(seed=2)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        eng.run(qs)
        s0 = qs[0][0]
        nb = g.neighbors(s0)
        assert nb.size > 0
        rep = eng.apply_delta(GraphDelta.from_pairs(remove=[(s0, int(nb[0]))]))
        assert rep["cache_evicted"] > 0
        r2 = eng.run(qs)
        for qi, (s, t, k) in enumerate(qs):
            truth = path_set(enumerate_paths_bruteforce(eng.g, s, t, k))
            assert path_set(r2[qi].paths) == truth, f"q{qi}"

    def test_random_churn_stays_exact(self):
        """No cold-edge engineering: arbitrary deltas, exactness only."""
        g, qs = self._workload(n=200, nq=5, seed=5)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        rng = np.random.default_rng(11)
        for round_ in range(3):
            eng.run(qs)
            rep = eng.apply_delta(_random_delta(g, rng, n_add=4, n_del=4))
            g = eng.g
            r = eng.run(qs)
            fresh = BatchPathEngine(g, EngineConfig(min_cap=64))
            rf = fresh.run(qs)
            for qi in range(len(qs)):
                assert path_set(r[qi].paths) == path_set(rf[qi].paths), \
                    (round_, qi, rep)

    def test_noop_delta_keeps_all_state(self):
        g, qs = self._workload(n=200, nq=4, seed=6)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20))
        eng.run(qs)
        src, dst = _edge_list(g)
        epoch = eng.cache.epoch
        dg = eng.dg
        rep = eng.apply_delta(GraphDelta.from_pairs(
            add=[(int(src[0]), int(dst[0]))]))    # already present
        assert rep["n_added"] == rep["n_removed"] == 0
        assert eng.g is g and eng.dg is dg and eng.cache.epoch == epoch

    def test_wide_delta_falls_back_to_full_invalidate(self):
        g, qs = self._workload(n=200, nq=4, seed=7)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=64 << 20,
                                              delta_max_sources=4))
        eng.run(qs)
        rng = np.random.default_rng(13)
        rep = eng.apply_delta(_random_delta(g, rng, n_add=16, n_del=16))
        assert rep["cache_mode"] == "full" and len(eng.cache) == 0
        assert rep["cache_evicted"] > 0 and rep["cache_kept"] == 0
        r = eng.run(qs)
        fresh = BatchPathEngine(eng.g, EngineConfig(min_cap=64))
        rf = fresh.run(qs)
        for qi in range(len(qs)):
            assert path_set(r[qi].paths) == path_set(rf[qi].paths)


class TestSessionAndStreaming:
    def test_session_apply_delta_batch_mode(self):
        g = generators.community(200, n_comm=3, avg_deg=4.0, seed=8)
        qs = generators.similar_queries(g, 5, similarity=0.8,
                                        k_range=(3, 3), seed=9)
        session = PathSession(g, EngineConfig(min_cap=64,
                                              cache_bytes=32 << 20))
        session.run(qs)
        rng = np.random.default_rng(15)
        rep = session.apply_delta(_random_delta(g, rng, 3, 3))
        assert rep is not None and "cache_mode" in rep
        r = session.run(qs)
        fresh = BatchPathEngine(session.engine.g, EngineConfig(min_cap=64))
        rf = fresh.run(qs)
        for qi in range(len(qs)):
            assert path_set(r[qi].paths) == path_set(rf[qi].paths)

    def test_streaming_delta_applies_at_batch_boundary(self):
        g = generators.community(200, n_comm=3, avg_deg=4.0, seed=10)
        qs = generators.similar_queries(g, 6, similarity=0.8,
                                        k_range=(3, 3), seed=11)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=32 << 20))
        srv = StreamingServer(eng, n_groups=1,
                              policy=AdmissionPolicy(max_batch=6,
                                                     max_delay_s=0.0))
        ids1 = [srv.submit(q) for q in qs]
        srv.drain()
        rng = np.random.default_rng(17)
        delta = _random_delta(g, rng, 3, 3)
        srv.apply_delta(delta)
        assert eng.g is g                 # queued, not yet applied
        ids2 = [srv.submit(q) for q in qs]
        srv.drain()                       # boundary: delta applies first
        assert eng.g is not g or not delta
        assert len(srv.delta_log) == 1
        b2 = srv.batch_log[-1]
        assert b2["n_deltas"] == 1
        assert b2["delta_edges"] == srv.delta_log[0]["n_added"] + \
            srv.delta_log[0]["n_removed"]
        g2 = eng.g
        for qid, (s, t, k) in zip(ids2, qs):
            truth = path_set(enumerate_paths_bruteforce(g2, s, t, k))
            assert path_set(srv.take(qid).paths) == truth
        for qid, (s, t, k) in zip(ids1, qs):   # pre-delta answers: old graph
            truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
            assert path_set(srv.take(qid).paths) == truth

    def test_session_run_flushes_queued_deltas(self):
        """A one-shot batch is a boundary: run() must not execute on the
        pre-delta graph while a delta sits queued behind the server."""
        g = generators.community(150, n_comm=2, avg_deg=4.0, seed=18)
        qs = generators.similar_queries(g, 4, similarity=0.8,
                                        k_range=(3, 3), seed=19)
        session = PathSession(g, EngineConfig(min_cap=64))
        session.submit(qs[0])
        session.results()
        src, dst = _edge_list(g)
        session.apply_delta(GraphDelta.from_pairs(
            remove=[(int(src[0]), int(dst[0]))]))     # queued
        assert session.engine.g is g
        r = session.run(qs)                           # boundary: flush first
        g2 = session.engine.g
        assert g2 is not g and len(session.server.delta_log) == 1
        for qi, (s, t, k) in enumerate(qs):
            truth = path_set(enumerate_paths_bruteforce(g2, s, t, k))
            assert path_set(r[qi].paths) == truth

    def test_update_graph_discards_queued_deltas(self):
        """A full swap supersedes deltas queued against the old graph —
        they must never be applied to the unrelated new one."""
        g = generators.community(150, n_comm=2, avg_deg=4.0, seed=20)
        (q,) = generators.random_queries(g, 1, (3, 3), seed=21)
        session = PathSession(g, EngineConfig(min_cap=64))
        session.submit(q)
        session.results()
        src, dst = _edge_list(g)
        session.apply_delta(GraphDelta.from_pairs(
            remove=[(int(src[0]), int(dst[0]))]))
        g2 = generators.community(150, n_comm=2, avg_deg=4.0, seed=22)
        session.update_graph(g2)
        session.submit(q)
        session.results()                             # would apply the queue
        assert session.server.delta_log == []         # delta was discarded
        assert session.engine.g is g2

    def test_session_routes_delta_to_server_when_streaming(self):
        g = generators.community(150, n_comm=2, avg_deg=4.0, seed=12)
        (q,) = generators.random_queries(g, 1, (3, 3), seed=13)
        session = PathSession(g, EngineConfig(min_cap=64))
        session.submit(q)
        src, dst = _edge_list(g)
        assert session.apply_delta(GraphDelta.from_pairs(
            remove=[(int(src[0]), int(dst[0]))])) is None   # queued
        session.results()
        assert len(session.server.delta_log) == 1

    def test_streaming_delta_validated_at_queue_time(self):
        """Out-of-range deltas are rejected when queued (like submit),
        never lost mid-flush with later deltas still applying."""
        g = generators.erdos(50, 3.0, seed=24)
        (q,) = generators.random_queries(g, 1, (3, 3), seed=25)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        srv = StreamingServer(eng, n_groups=1)
        srv.submit(q)
        with pytest.raises(ValueError, match="outside the graph"):
            srv.apply_delta(GraphDelta.from_pairs(add=[(0, g.n)]))
        srv.drain()
        assert srv.delta_log == []                # nothing was queued


class TestSatellites:
    @pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 8])
    def test_midpoint_split_is_single_source_of_truth(self, k):
        a, b = midpoint_split(k)
        assert a + b == k and a == (k + 1) // 2
        fkey, bkey = dedicated_keys(0, 1, k)
        assert fkey[2] == a and bkey[2] == b

    @pytest.mark.parametrize("k", [2, 3, 4])
    def test_engine_keys_match_dedicated_keys(self, k):
        g = generators.erdos(60, 3.0, seed=k)
        (q,) = generators.random_queries(g, 1, (k, k), seed=k + 1)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64,
                                              cache_bytes=1 << 20))
        eng.run([q])
        fkey, bkey = dedicated_keys(*q)
        assert eng.cache.contains(fkey) and eng.cache.contains(bkey)

    def test_put_estimate_equals_host_accounting(self):
        """The pre-transfer oversize estimate and the LRU accounting use
        the same byte math (pathset_nbytes) — bit-equal, not just close."""
        levels = _levels(width=5, rows=7)
        est = sum(pathset_nbytes(ps.cap, ps.width, ps.verts.dtype.itemsize)
                  for ps in levels)
        assert est == sum(offload(ps).nbytes for ps in levels)
        c = SharedPathCache(budget_bytes=est)     # fits exactly
        c.put(("f", 0, 1, ((1, 1),), -2), levels)
        assert len(c) == 1 and c.nbytes == est
        c2 = SharedPathCache(budget_bytes=est - 1)  # off by one byte: skip
        c2.put(("f", 0, 1, ((1, 1),), -2), levels)
        assert len(c2) == 0 and c2.stats.oversize_skips == 1
