"""Step builder: (arch, shape, mesh rules) -> jit-able step + abstract inputs
+ shardings + analytic roofline meta.

Single source of truth consumed by the dry-run (ShapeDtypeStruct lowering),
the train/serve drivers (real arrays) and the smoke tests (reduced configs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import (GNNConfig, LMConfig, PathEngineConfig, RecsysConfig,
                      RunOptions, ShapeSpec)
from ..models import gnn, recsys, transformer
from ..models.sharding import Rules
from ..optim import adamw_init, adamw_update, cosine_schedule
from .. import configs as config_registry

__all__ = ["StepBundle", "build_bundle"]

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass
class StepBundle:
    arch: str
    shape: str
    step_fn: Callable
    abstract_inputs: tuple          # positional args as ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    meta: dict                      # analytic roofline terms (see roofline.py)
    make_concrete: Optional[Callable] = None  # () -> real input arrays (tests)
    donate_argnums: tuple = ()      # aliased in/out buffers (params/opt/cache)


def _constrain_fn(rules: Rules):
    def constrain(x, axes):
        return jax.lax.with_sharding_constraint(x, rules.sharding(*axes))
    return constrain


def _spec_tree(rules: Rules, logical_tree):
    return jax.tree.map(
        lambda axes: rules.sharding(*axes), logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_bundle(arch: str, shape_name: str, rules: Rules,
                 opts: RunOptions | None = None, reduced: bool = False,
                 overrides: dict | None = None) -> StepBundle:
    opts = RunOptions() if opts is None else opts
    mod = config_registry.get(arch)
    cfg = mod.REDUCED if reduced else mod.CONFIG
    shape = mod.SHAPES[shape_name]
    if overrides:
        shape = ShapeSpec(shape.name, shape.kind,
                          tuple(dict(dict(shape.dims), **overrides).items()))
    fam = mod.FAMILY
    if fam == "lm":
        return _lm_bundle(arch, cfg, shape, rules, opts)
    if fam == "gnn":
        return _gnn_bundle(arch, cfg, shape, rules, opts)
    if fam == "recsys":
        return _recsys_bundle(arch, cfg, shape, rules, opts)
    if fam == "engine":
        return _engine_bundle(arch, cfg, shape, rules, opts)
    raise ValueError(fam)


# ======================================================================
# LM family
# ======================================================================

def _lm_abstract_params(cfg: LMConfig, tp: int, dtype=None):
    ap = jax.eval_shape(partial(transformer.init_lm_params, cfg=cfg, tp=tp),
                        jax.random.PRNGKey(0))
    if dtype is not None:  # serving uses cast weights, not the f32 master
        ap = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, dtype), ap)
    return ap


def _lm_meta(cfg: LMConfig, shape: ShapeSpec, rules: Rules) -> dict:
    S, B = shape.dim("seq_len"), shape.dim("global_batch")
    N, Na = cfg.param_count(), cfg.active_param_count()
    tokens = B * S if shape.kind in ("train", "prefill") else B
    mult = 6 if shape.kind == "train" else 2
    kv_read = 0
    if shape.kind == "decode":
        kv_read = (cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2) * 2  # bytes
    return {
        "family": "lm", "kind": shape.kind,
        "params": N, "active_params": Na,
        "tokens": tokens,
        "model_flops": mult * Na * tokens,
        "weight_bytes": Na * 2,
        "kv_cache_bytes": kv_read,
        "seq_len": S, "global_batch": B,
        "n_layers": cfg.n_layers,
    }


def _lm_bundle(arch, cfg: LMConfig, shape: ShapeSpec, rules: Rules,
               opts: RunOptions) -> StepBundle:
    constrain = _constrain_fn(rules)
    tp = rules.size("tensor")
    dp = rules.size("batch")
    if cfg.moe is not None and dp > 1 and opts.moe_groups != dp:
        opts = dataclasses.replace(opts, moe_groups=dp)
    S, B = shape.dim("seq_len"), shape.dim("global_batch")
    serve_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ap = _lm_abstract_params(
        cfg, tp, dtype=None if shape.kind == "train" else serve_dt)
    logical = transformer.lm_param_logical(cfg)
    if shape.kind != "train" and opts.serve_param_sharding == "tp_only":
        # weight-stationary serving: replicate over data, shard over model
        logical = jax.tree.map(
            lambda axes: tuple(None if a == "fsdp" else a for a in axes),
            logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
    p_spec = _spec_tree(rules, logical)
    meta = _lm_meta(cfg, shape, rules)

    if shape.kind == "train":
        a_opt = jax.eval_shape(adamw_init, ap)
        o_spec = type(a_opt)(m=p_spec, v=p_spec,
                             count=rules.sharding())
        tok_sh = rules.sharding("batch", None)

        A = max(opts.grad_accum, 1)
        assert B % A == 0, "global_batch must divide grad_accum"

        def train_step(params, opt_state, tokens, targets):
            def loss_fn(p, tk, tg):
                return transformer.lm_loss(p, tk, tg, cfg, opts, constrain)
            if A == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                          targets)
            else:  # gradient accumulation over A microbatches (f32 accum)
                tks = tokens.reshape(A, B // A, S)
                tgs = targets.reshape(A, B // A, S)

                def micro(acc, inp):
                    g_sum, l_sum = acc
                    tk, tg = inp
                    l, g = jax.value_and_grad(loss_fn)(params, tk, tg)
                    g_sum = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                    return (g_sum, l_sum + l), ()

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)),
                                                (tks, tgs))
                grads = jax.tree.map(lambda g: g / A, grads)
                loss = loss / A
            lr = cosine_schedule(opt_state.count)
            params, opt_state, m = adamw_update(grads, opt_state, params, lr=lr)
            return params, opt_state, {"loss": loss, **m}

        tok = jax.ShapeDtypeStruct((B, S), I32)
        return StepBundle(
            arch=arch, shape=shape.name, step_fn=train_step,
            abstract_inputs=(ap, a_opt, tok, tok),
            in_shardings=(p_spec, o_spec, tok_sh, tok_sh),
            out_shardings=(p_spec, o_spec,
                           {"loss": rules.sharding(),
                            "grad_norm": rules.sharding()}),
            meta=meta, donate_argnums=(0, 1))

    if shape.kind == "prefill":
        def prefill_step(params, tokens):
            return transformer.prefill(params, tokens, cfg, opts, constrain)

        tok = jax.ShapeDtypeStruct((B, S), I32)
        return StepBundle(
            arch=arch, shape=shape.name, step_fn=prefill_step,
            abstract_inputs=(ap, tok),
            in_shardings=(p_spec, rules.sharding("batch", None)),
            out_shardings=rules.sharding("batch", None, "tensor"),
            meta=meta)

    # decode
    wide = B == 1
    kv_dt = (jnp.float8_e4m3fn if opts.kv_cache_dtype == "f8"
             else jnp.bfloat16)
    cache = jax.eval_shape(partial(transformer.init_cache, cfg, B, S,
                                   dtype=kv_dt))
    c_spec = _spec_tree(rules, transformer.cache_logical(wide))

    def serve_step(params, token, cache):
        return transformer.decode_step(params, token, cache, cfg, opts,
                                       constrain)

    tok = jax.ShapeDtypeStruct((B, 1), I32)
    tok_sh = (rules.sharding(None, None) if wide
              else rules.sharding("batch", None))
    logit_sh = (rules.sharding(None, None, "tensor") if wide
                else rules.sharding("batch", None, "tensor"))
    return StepBundle(
        arch=arch, shape=shape.name, step_fn=serve_step,
        abstract_inputs=(ap, tok, cache),
        in_shardings=(p_spec, tok_sh, c_spec),
        out_shardings=(logit_sh, c_spec),
        meta=meta, donate_argnums=(2,))


# ======================================================================
# GNN family
# ======================================================================

def _gnn_dims(cfg: GNNConfig, shape: ShapeSpec):
    d_feat = shape.dim("d_feat", 16)
    if cfg.kind == "graphsage":
        d_out = cfg.extra("n_classes", 41)
    else:
        d_out = cfg.extra("d_out", 3)
    return d_feat, d_out


def _gnn_batch_abstract(cfg: GNNConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for a graph batch of this shape."""
    kind = shape.kind
    d_feat, d_out = _gnn_dims(cfg, shape)
    rbf = cfg.extra("rbf", 300)
    if kind == "gnn_mol":
        B = shape.dim("batch")
        N, E = shape.dim("n_nodes"), shape.dim("n_edges")
        b = {"nodes": jax.ShapeDtypeStruct((B, N, d_feat), F32),
             "edge_src": jax.ShapeDtypeStruct((B, E), I32),
             "edge_dst": jax.ShapeDtypeStruct((B, E), I32),
             "edge_mask": jax.ShapeDtypeStruct((B, E), jnp.bool_),
             "node_mask": jax.ShapeDtypeStruct((B, N), jnp.bool_)}
        if cfg.kind == "schnet":
            b["atom_types"] = jax.ShapeDtypeStruct((B, N), I32)
            b["edge_rbf"] = jax.ShapeDtypeStruct((B, E, rbf), F32)
            b["targets"] = jax.ShapeDtypeStruct((B,), F32)
        else:
            if cfg.kind in ("meshgraphnet", "graphcast"):
                b["edge_feat"] = jax.ShapeDtypeStruct((B, E, 4), F32)
            b["targets"] = jax.ShapeDtypeStruct((B, N, d_out), F32)
            if cfg.kind == "graphsage":
                b["targets"] = None
                b["labels"] = jax.ShapeDtypeStruct((B, N), I32)
        return {k: v for k, v in b.items() if v is not None}
    # flat graph (full-batch or sampled block), padded to multiples of 512
    if kind == "gnn_mini":
        roots = shape.dim("batch_nodes")
        fo = shape.dim("fanout")
        n_nodes = min(shape.dim("n_nodes"),
                      roots * (1 + fo[0] + fo[0] * fo[1]))
        n_edges = roots * fo[0] + roots * fo[0] * fo[1]
    else:
        n_nodes, n_edges = shape.dim("n_nodes"), shape.dim("n_edges")
    N = -(-n_nodes // 512) * 512
    E = -(-n_edges // 512) * 512
    b = {"nodes": jax.ShapeDtypeStruct((N, d_feat), F32),
         "edge_src": jax.ShapeDtypeStruct((E,), I32),
         "edge_dst": jax.ShapeDtypeStruct((E,), I32),
         "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
         "node_mask": jax.ShapeDtypeStruct((N,), jnp.bool_)}
    if cfg.kind == "schnet":
        b["edge_rbf"] = jax.ShapeDtypeStruct((E, rbf), F32)
        b["targets"] = jax.ShapeDtypeStruct((N,), F32)
    elif cfg.kind == "graphsage":
        b["labels"] = jax.ShapeDtypeStruct((N,), I32)
    else:
        b["edge_feat"] = jax.ShapeDtypeStruct((E, 4), F32)
        b["targets"] = jax.ShapeDtypeStruct((N, d_out), F32)
    return b


def _gnn_batch_spec(cfg: GNNConfig, shape: ShapeSpec, rules: Rules, batch):
    """NamedSharding tree matching _gnn_batch_abstract."""
    mol = shape.kind == "gnn_mol"
    out = {}
    for k, v in batch.items():
        nd = len(v.shape)
        if mol:
            out[k] = rules.sharding("batch", *([None] * (nd - 1)))
        else:
            out[k] = rules.sharding("cells", *([None] * (nd - 1)))
    return out


def _gnn_meta(cfg: GNNConfig, shape: ShapeSpec, params) -> dict:
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    if shape.kind == "gnn_mol":
        E = shape.dim("n_edges") * shape.dim("batch")
        N = shape.dim("n_nodes") * shape.dim("batch")
    elif shape.kind == "gnn_mini":
        roots, fo = shape.dim("batch_nodes"), shape.dim("fanout")
        E = roots * fo[0] + roots * fo[0] * fo[1]
        N = min(shape.dim("n_nodes"), roots * (1 + fo[0] + fo[0] * fo[1]))
    else:
        E, N = shape.dim("n_edges"), shape.dim("n_nodes")
    d = cfg.d_hidden
    # per message-passing block: edge MLP ~ edges x d^2 terms, node MLP ~ nodes
    flops = 6 * cfg.n_layers * (E * (6 * d * d) + N * (6 * d * d))
    return {"family": "gnn", "kind": shape.kind, "params": n_params,
            "edges": E, "nodes": N, "model_flops": flops,
            "weight_bytes": n_params * 4, "n_layers": cfg.n_layers}


def _gnn_bundle(arch, cfg: GNNConfig, shape: ShapeSpec, rules: Rules,
                opts: RunOptions) -> StepBundle:
    d_feat, d_out = _gnn_dims(cfg, shape)
    ap = jax.eval_shape(
        partial(gnn.init_gnn_params, cfg=cfg, d_in=d_feat, d_out=d_out),
        jax.random.PRNGKey(0))
    p_spec = jax.tree.map(lambda p: rules.sharding(*(None,) * len(p.shape)), ap)
    a_opt = jax.eval_shape(adamw_init, ap)
    o_spec = type(a_opt)(m=p_spec, v=p_spec, count=rules.sharding())
    batch = _gnn_batch_abstract(cfg, shape)
    b_spec = _gnn_batch_spec(cfg, shape, rules, batch)
    mol = shape.kind == "gnn_mol"

    constrain = _constrain_fn(rules)

    def loss_fn(p, b):
        if mol:
            per = jax.vmap(lambda bb: gnn.gnn_loss(p, bb, cfg))(b)
            return per.mean()
        return gnn.gnn_loss(p, b, cfg, constrain=constrain)

    def train_step(params, opt_state, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        lr = cosine_schedule(opt_state.count, base_lr=1e-3)
        params, opt_state, m = adamw_update(grads, opt_state, params, lr=lr,
                                            weight_decay=0.0)
        return params, opt_state, {"loss": loss, **m}

    return StepBundle(
        arch=arch, shape=shape.name, step_fn=train_step,
        abstract_inputs=(ap, a_opt, batch),
        in_shardings=(p_spec, o_spec, b_spec),
        out_shardings=(p_spec, o_spec,
                       {"loss": rules.sharding(),
                        "grad_norm": rules.sharding()}),
        meta=_gnn_meta(cfg, shape, ap), donate_argnums=(0, 1))


# ======================================================================
# recsys
# ======================================================================

def _recsys_meta(cfg: RecsysConfig, shape: ShapeSpec, params) -> dict:
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    B = shape.dim("batch")
    mlp_flops = 2 * sum(cfg.tower_mlp[i] * cfg.tower_mlp[i + 1]
                        for i in range(len(cfg.tower_mlp) - 1))
    mlp_flops += 2 * cfg.embed_dim * cfg.tower_mlp[0]
    per_ex = 2 * mlp_flops  # two towers
    if shape.kind == "recsys_train":
        flops = 3 * (B * per_ex + 2 * B * B * cfg.tower_mlp[-1])
    elif shape.kind == "recsys_retrieval":
        Nc = shape.dim("n_candidates")
        flops = Nc * (mlp_flops + 2 * cfg.tower_mlp[-1]) + mlp_flops
    else:
        flops = B * (per_ex + 2 * cfg.tower_mlp[-1])
    emb_bytes = (cfg.n_users + cfg.n_items) * cfg.embed_dim * 4
    return {"family": "recsys", "kind": shape.kind, "params": n_params,
            "batch": B, "model_flops": flops, "weight_bytes": emb_bytes}


def _recsys_bundle(arch, cfg: RecsysConfig, shape: ShapeSpec, rules: Rules,
                   opts: RunOptions) -> StepBundle:
    constrain = _constrain_fn(rules)
    ap = jax.eval_shape(partial(recsys.init_recsys_params, cfg=cfg),
                        jax.random.PRNGKey(0))
    p_spec = _spec_tree(rules, recsys.recsys_param_logical(ap))
    B = shape.dim("batch")
    H = cfg.n_user_hist
    meta = _recsys_meta(cfg, shape, ap)

    if shape.kind == "recsys_train":
        a_opt = jax.eval_shape(adamw_init, ap)
        o_spec = type(a_opt)(m=p_spec, v=p_spec, count=rules.sharding())
        batch = {"hist_ids": jax.ShapeDtypeStruct((B, H), I32),
                 "item_ids": jax.ShapeDtypeStruct((B,), I32),
                 "sampling_logq": jax.ShapeDtypeStruct((B,), F32)}
        b_spec = {"hist_ids": rules.sharding("batch", None),
                  "item_ids": rules.sharding("batch"),
                  "sampling_logq": rules.sharding("batch")}

        def train_step(params, opt_state, b):
            loss, grads = jax.value_and_grad(
                lambda p: recsys.recsys_loss(p, b, cfg, constrain))(params)
            lr = cosine_schedule(opt_state.count, base_lr=1e-3)
            params, opt_state, m = adamw_update(grads, opt_state, params,
                                                lr=lr, weight_decay=0.0)
            return params, opt_state, {"loss": loss, **m}

        return StepBundle(
            arch=arch, shape=shape.name, step_fn=train_step,
            abstract_inputs=(ap, a_opt, batch),
            in_shardings=(p_spec, o_spec, b_spec),
            out_shardings=(p_spec, o_spec,
                           {"loss": rules.sharding(),
                            "grad_norm": rules.sharding()}),
            meta=meta, donate_argnums=(0, 1))

    if shape.kind == "recsys_serve":
        def serve_step(params, hist_ids, item_ids):
            return recsys.score_candidates(params, hist_ids, item_ids)

        return StepBundle(
            arch=arch, shape=shape.name, step_fn=serve_step,
            abstract_inputs=(ap, jax.ShapeDtypeStruct((B, H), I32),
                             jax.ShapeDtypeStruct((B,), I32)),
            in_shardings=(p_spec, rules.sharding("batch", None),
                          rules.sharding("batch")),
            out_shardings=rules.sharding("batch"),
            meta=meta)

    # retrieval: 1 query vs n_candidates (padded to a shardable multiple;
    # padding ids are -1 and masked to -inf before top-k)
    Nc = shape.dim("n_candidates")
    Nc_pad = -(-Nc // 512) * 512

    def retrieval_step(params, hist_ids, cand_ids):
        u = recsys.user_tower(params, hist_ids)
        v = recsys.item_tower(params, jnp.maximum(cand_ids, 0))
        v = constrain(v, ("cells", None))
        scores = (v @ u[0]).astype(jnp.float32)
        scores = jnp.where(cand_ids >= 0, scores, -jnp.inf)
        vals, idx = jax.lax.top_k(scores, 100)
        return vals, cand_ids[idx]

    return StepBundle(
        arch=arch, shape=shape.name, step_fn=retrieval_step,
        abstract_inputs=(ap, jax.ShapeDtypeStruct((1, H), I32),
                         jax.ShapeDtypeStruct((Nc_pad,), I32)),
        in_shardings=(p_spec, rules.sharding(None, None),
                      rules.sharding("cells")),
        out_shardings=(rules.sharding(None), rules.sharding(None)),
        meta=meta)


# ======================================================================
# paper engine (billion-scale dry-run cell)
# ======================================================================

def _engine_bundle(arch, cfg: PathEngineConfig, shape: ShapeSpec,
                   rules: Rules, opts: RunOptions) -> StepBundle:
    constrain = _constrain_fn(rules)
    V = shape.dim("n_vertices")
    Q = shape.dim("n_queries")
    k = shape.dim("k")
    cap = cfg.ell_cap
    W = -(-Q // 32)                              # packed frontier words
    # pruned-subgraph enumeration working set (see DESIGN.md §4)
    Vp = min(V, 1 << 22)
    P_CAP = 1 << 20
    width = (k + 1) // 2 + 1

    def engine_superstep(ell_idx, frontier, dist, hop,
                         pruned_ell, prune_tbl, paths, count):
        """One index hop (bit-packed MS-BFS) + one enumeration expand."""
        # --- MS-BFS hop over the vertex-sharded billion-edge graph
        # frontier/dist come in without the sentinel row (shardable V);
        # append it here (pad index = V in the ELL).
        fw = jnp.concatenate(
            [frontier, jnp.zeros((1, W), jnp.uint32)], axis=0)
        gathered = fw[ell_idx]                   # (V, cap, W) via SPMD gather
        nxt = jax.lax.reduce(gathered, jnp.uint32(0), jax.lax.bitwise_or, (1,))
        nxt = constrain(nxt, ("cells", None))
        # unpack -> per-query newly-reached -> dist update -> repack frontier
        shifts = jnp.arange(32, dtype=jnp.uint32)
        bits = ((nxt[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1))
        bits = bits.reshape(V, W * 32)[:, :Q].astype(bool)
        unreached = dist == jnp.int8(127)
        newly = bits & unreached
        dist = jnp.where(newly, hop.astype(jnp.int8), dist)
        dist = constrain(dist, ("cells", None))
        pad_q = W * 32 - Q
        nb = jnp.pad(newly, ((0, 0), (0, pad_q))).reshape(V, W, 32)
        powers = jnp.uint32(1) << shifts
        frontier = jnp.sum(nb.astype(jnp.uint32) * powers[None, None, :],
                           axis=-1, dtype=jnp.uint32)
        frontier = constrain(frontier, ("cells", None))
        # --- enumeration superstep on the index-pruned subgraph
        from ..core.enumerate import expand_level
        out = expand_level(paths, count, pruned_ell, prune_tbl,
                           jnp.int32(-2),
                           level=1, budget=width - 1, out_cap=P_CAP)
        return frontier, dist, out.frontier.verts, out.frontier.count

    inputs = (
        jax.ShapeDtypeStruct((V, cap), I32),            # ell_idx
        jax.ShapeDtypeStruct((V, W), jnp.uint32),       # frontier
        jax.ShapeDtypeStruct((V, Q), jnp.int8),         # dist
        jax.ShapeDtypeStruct((), I32),                  # hop
        jax.ShapeDtypeStruct((Vp + 1, cap), I32),       # pruned ell
        jax.ShapeDtypeStruct((Vp + 1, 2), jnp.int8),    # slack+splice table
        jax.ShapeDtypeStruct((P_CAP, width), I32),      # paths
        jax.ShapeDtypeStruct((), I32),                  # count
    )
    split = opts.engine_frontier_shard == "split"
    fr_sh = (rules.sharding("batch", "tensor") if split
             else rules.sharding("cells", None))
    in_sh = (rules.sharding("batch", None) if split
             else rules.sharding("cells", None),
             fr_sh,
             rules.sharding("batch", "tensor") if split
             else rules.sharding("cells", None),
             rules.sharding(),
             rules.sharding(None, None),
             rules.sharding(None, None),
             rules.sharding("cells", None),
             rules.sharding())
    out_sh = (rules.sharding("cells", None), rules.sharding("cells", None),
              rules.sharding("cells", None), rules.sharding())
    E = V * shape.dim("avg_degree")
    meta = {"family": "engine", "kind": "engine_batch",
            "vertices": V, "edges": E, "queries": Q,
            # one hop touches E edge-words + expand touches P_CAP*cap cells
            "model_flops": float(E) * W + float(P_CAP) * cap * width,
            "weight_bytes": V * cap * 4}
    return StepBundle(arch=arch, shape=shape.name, step_fn=engine_superstep,
                      abstract_inputs=inputs, in_shardings=in_sh,
                      out_shardings=out_sh, meta=meta)
