"""ClusterQuery (Algorithm 2): threshold-stopped agglomerative clustering.

Group similarity δ (Def 4.6) is the all-pairs average of μ, so merging is
exactly average-linkage; we keep the O(|C|^2) merge scan of the paper
(|Q| is "medium in size") with the standard Lance–Williams update instead
of recomputing δ from scratch each round.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["cluster_queries"]


def cluster_queries(mu: np.ndarray, gamma: float,
                    bias: Optional[np.ndarray] = None,
                    min_clusters: int = 1) -> list[list[int]]:
    """Cluster query ids 0..Q-1 on the μ matrix; stop when max δ <= γ.

    bias : optional (Q, Q) symmetric additive bonus applied to μ before
           linkage — the streaming server uses it to pull queries into
           clusters whose shared HC-s path results are already warm in the
           cross-batch cache (cache-aware admission). The biased similarity
           is clipped back to [0, 1] so γ keeps its meaning.

    min_clusters : stop merging once this many clusters remain (before the
           γ threshold would). Sharded engines pass their replica count
           (``EngineConfig.balance_clusters``) so a highly similar batch
           cannot collapse below one data-parallel work unit per device;
           the default 1 keeps the paper's pure γ-threshold stop.

    Returns a partition (list of clusters, each a list of query indices).
    """
    Q = mu.shape[0]
    clusters: dict[int, list[int]] = {i: [i] for i in range(Q)}
    delta = mu.astype(np.float64).copy()
    if bias is not None:
        delta = np.clip(delta + np.asarray(bias, np.float64), 0.0, 1.0)
    np.fill_diagonal(delta, -np.inf)
    alive = list(range(Q))
    while len(alive) > max(int(min_clusters), 1):
        sub = delta[np.ix_(alive, alive)]
        flat = np.argmax(sub)
        i_, j_ = divmod(flat, len(alive))
        best = sub[i_, j_]
        if best <= gamma:
            break
        a, b = alive[i_], alive[j_]
        na, nb = len(clusters[a]), len(clusters[b])
        # Lance–Williams average-linkage update of δ(a∪b, c)
        for c in alive:
            if c in (a, b):
                continue
            delta[a, c] = delta[c, a] = (na * delta[a, c] + nb * delta[b, c]) / (na + nb)
        clusters[a] = clusters[a] + clusters[b]
        del clusters[b]
        delta[b, :] = -np.inf
        delta[:, b] = -np.inf
        alive.remove(b)
    return [sorted(v) for v in clusters.values()]
