"""qwen2.5-14b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

40 q-heads on a 16-way tensor axis: heads padded to 48 in sharded runs
(zeroed, exact no-op; see transformer.padded_heads).
"""
from ..config import LMConfig
from ._shapes import LM_SHAPES as SHAPES  # noqa: F401

CONFIG = LMConfig(name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
                  n_kv_heads=8, d_ff=13824, vocab=152064, qkv_bias=True,
                  head_dim=128)

REDUCED = LMConfig(name="qwen2.5-14b-reduced", n_layers=2, d_model=60,
                   n_heads=5, n_kv_heads=1, d_ff=144, vocab=256,
                   qkv_bias=True, head_dim=12, dtype="float32")

FAMILY = "lm"
