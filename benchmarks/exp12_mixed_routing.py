"""Exp-12: cost-routed adaptive planning on mixed-complexity batches.

One-size-fits-all planning leaves the most time on the table exactly
where real traffic lives: a batch mixing heavy similar path queries
(where the batch machinery's sharing pays) with trivial exists/short-k/
limited queries (where that machinery's overhead dominates). This
experiment runs the *same* mixed batch under ``Planner.AUTO`` and every
forced global planner and reports

  * warm wall per planner and AUTO's speedup vs. the best single global
    choice (the headline: routing must not lose to any one-flag setting),
  * routing decisions (``routed_green|yellow|red``) and result parity —
    AUTO must be bit-equal to the forced planners on every output kind,
  * zero warm retraces: routing may not perturb the stable-shape serving
    contract,
  * the streaming segment: the AdmissionPolicy deadline fix bounds a lone
    query's admission wait by ``max_delay_s + one pump interval``, and
    exists-only queries resolve at submit via the AUTO fast path.

``check_regression --routing`` gates the emitted BENCH_routing.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (BatchPathEngine, EngineConfig, PathQuery,
                        RouterConfig, generators)
from repro.launch.serve import AdmissionPolicy, StreamingServer
from .common import record

# workload-tuned GREEN threshold: trivial short-k queries on the bench
# community graphs cost O(10^2), heavy k=4-5 similar queries O(10^3) —
# 512 separates the two regimes (the RouterConfig default is sized for
# larger graphs, where heavy balls clear it on their own)
ROUTER = RouterConfig(green_max_cost=512.0)
PUMP_INTERVAL_S = 0.05
SCHED_SLACK_S = 0.25     # generous CI scheduling slack on the wait bound


def _mixed_workload(g, scale: float):
    n_heavy = max(8, int(16 * min(scale, 1.0)))
    n_triv = max(8, int(16 * min(scale, 1.0)))
    heavy = [PathQuery(s, t, k) for s, t, k in
             generators.similar_queries(g, n_heavy, similarity=0.7,
                                        k_range=(4, 5), seed=5)]
    triv = generators.random_queries(g, n_triv, (2, 3), seed=6)
    exists = [PathQuery(s, t, k, output="exists") for s, t, k in triv]
    lim = [PathQuery(s, t, k, output="count", limit=2) for s, t, k in
           generators.random_queries(g, n_triv, (3, 4), seed=7)]
    # interleave so clustering sees the mix the way admission would
    out = []
    for i in range(max(len(heavy), len(exists), len(lim))):
        for fam in (heavy, exists, lim):
            if i < len(fam):
                out.append(fam[i])
    return out


def _assert_parity(ra, rb, queries, tag):
    for qi, q in enumerate(queries):
        if q.output.value == "paths" and q.limit is None:
            assert set(map(tuple, ra[qi].paths)) \
                == set(map(tuple, rb[qi].paths)), f"{tag} q{qi}"
        elif q.output.value == "count":
            assert ra[qi].count == rb[qi].count, f"{tag} q{qi}"
        assert ra[qi].exists == rb[qi].exists, f"{tag} q{qi}"


def _timed(engine, queries, planner, repeats=3):
    engine.run(queries, planner=planner)        # pay jit compiles here
    best, stats, retraces = None, None, 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = engine.run(queries, planner=planner)
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best, stats = dt, res.stats
        retraces += res.stats.get("n_retraces", 0)
    return best, stats, retraces, res


def main(scale: float = 1.0) -> dict:
    n = max(400, int(6000 * scale))
    g = generators.community(n, n_comm=max(2, n // 1500), avg_deg=6.0,
                             seed=4)
    queries = _mixed_workload(g, scale)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128, log_compiles=True,
                                          router=ROUTER))

    times, reports, warm_retraces = {}, {}, {}
    for planner in ("auto", "batch", "basic"):
        times[planner], stats, warm_retraces[planner], reports[planner] = \
            _timed(eng, queries, planner)
        record(f"exp12_{planner}", times[planner] * 1e6 / len(queries),
               f"wall={times[planner] * 1e3:.1f}ms "
               f"retraces={warm_retraces[planner]}")

    # routing is a wall-time lever only: results must be planner-equal
    _assert_parity(reports["auto"], reports["batch"], queries, "auto/batch")
    _assert_parity(reports["auto"], reports["basic"], queries, "auto/basic")
    auto_stats = reports["auto"].stats
    routed = {r: auto_stats[f"routed_{r}"]
              for r in ("green", "yellow", "red")}
    assert sum(routed.values()) == len(queries)
    assert routed["green"] > 0, "mixed workload routed nothing GREEN"
    assert routed["yellow"] > 0, "mixed workload routed nothing YELLOW"
    total_warm_retraces = sum(warm_retraces.values())
    assert total_warm_retraces == 0, (
        f"routing perturbed warm shapes: {warm_retraces}")

    best_single = min(times["batch"], times["basic"])
    speedup_best = best_single / max(times["auto"], 1e-9)
    speedup_yellow = times["batch"] / max(times["auto"], 1e-9)
    record("exp12_speedup_vs_best_single", speedup_best,
           f"best_single={'batch' if times['batch'] <= times['basic'] else 'basic'}")
    record("exp12_speedup_vs_yellow", speedup_yellow,
           f"green={routed['green']} yellow={routed['yellow']}")

    # -- streaming segment: deadline-bounded admission + AUTO fast path --
    srv = StreamingServer(eng, planner="auto",
                          policy=AdmissionPolicy(min_batch=8, max_batch=32,
                                                 max_delay_s=0.2))
    heavy = next(q for q in queries if q.output.value == "paths")
    srv.submit(heavy)                 # lone sub-min_batch query: must not starve
    deadline = time.monotonic() + 10.0
    while not srv.batch_log and time.monotonic() < deadline:
        srv.pump()
        time.sleep(PUMP_INTERVAL_S)
    assert srv.batch_log, "lone query starved past the admission deadline"
    wait_max = srv.batch_log[-1]["admission_wait_max_s"]
    admission_bound = 0.2 + PUMP_INTERVAL_S + SCHED_SLACK_S
    assert wait_max <= admission_bound, (
        f"admission wait {wait_max:.3f}s exceeds bound {admission_bound:.3f}s")
    ex = next(q for q in queries if q.output.value == "exists")
    qid = srv.submit(ex)
    fast_path_ok = qid in srv.results and srv.n_fast_path == 1
    assert fast_path_ok, "exists query did not take the submit fast path"
    record("exp12_admission_wait_max", wait_max * 1e6,
           f"bound={admission_bound:.3f}s fast_path={int(fast_path_ok)}")

    summary = {
        "n": n, "n_queries": len(queries),
        "t_auto_s": times["auto"], "t_batch_s": times["batch"],
        "t_basic_s": times["basic"],
        "speedup_vs_best_single": speedup_best,
        "speedup_vs_yellow": speedup_yellow,
        "routed": routed,
        "warm_retraces": total_warm_retraces,
        "parity_ok": True,
        "admission_wait_max_s": wait_max,
        "admission_bound_s": admission_bound,
        "fast_path_ok": fast_path_ok,
        "green_max_cost": ROUTER.green_max_cost,
    }
    # the committed artifact records the full-scale workload; tiny smoke
    # runs (CI) must not clobber it — they write under results/ instead
    out = (Path("BENCH_routing.json") if scale >= 1.0
           else Path("results/BENCH_routing.json"))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, default=str))
    return summary


if __name__ == "__main__":
    main()
