# Launchers: mesh.py (topologies), steps.py (step builder), dryrun.py
# (multi-pod compile validation), train.py / serve.py (drivers),
# roofline.py (perf analysis). dryrun must be run as __main__ (it sets
# XLA_FLAGS); never import it from tests.
