"""Findings + waivers container shared by both analyzer layers.

One :class:`Finding` is one violation of a named rule at a source
location (AST lint) or inside a traced hot function (jaxpr audit). A
finding can be *waived* by an in-line ``# repro-lint: waive[RULE] reason``
comment (layer 1) or a manifest-level waiver entry (layer 2); waived
findings stay in the report — every exception is documented, none is
silent — but do not fail the run.

The CLI (``python -m repro.analysis``) and the CI gates
(``check_regression.py --static``, the ``lint-deep`` job) all consume the
same :class:`AnalysisReport`: exit nonzero iff ``report.violations`` is
non-empty.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional

__all__ = ["Finding", "AnalysisReport"]


@dataclasses.dataclass
class Finding:
    rule: str                     # "RPL001" ... or an audit check id
    path: str                     # file (lint) or hot-fn "name[backend]" (audit)
    line: int                     # 1-based source line; 0 for audit findings
    message: str
    waived: bool = False
    waiver_reason: Optional[str] = None

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def render(self) -> str:
        tag = f"waived: {self.waiver_reason}" if self.waived else "VIOLATION"
        return f"{self.location}: {self.rule} [{tag}] {self.message}"


@dataclasses.dataclass
class AnalysisReport:
    findings: list[Finding] = dataclasses.field(default_factory=list)
    n_files: int = 0              # lint: files scanned
    n_functions: int = 0          # audit: hot functions traced
    meta: dict = dataclasses.field(default_factory=dict)

    def extend(self, other: "AnalysisReport") -> "AnalysisReport":
        self.findings.extend(other.findings)
        self.n_files += other.n_files
        self.n_functions += other.n_functions
        self.meta.update(other.meta)
        return self

    @property
    def violations(self) -> list[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self) -> list[Finding]:
        return [f for f in self.findings if f.waived]

    @property
    def ok(self) -> bool:
        return not self.violations

    def add(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def render(self) -> str:
        lines = [f.render() for f in sorted(
            self.findings, key=lambda f: (f.path, f.line, f.rule))]
        lines.append(
            f"{len(self.violations)} violation(s), {len(self.waived)} "
            f"waived, {self.n_files} file(s) linted, "
            f"{self.n_functions} hot function(s) audited")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "n_files": self.n_files,
            "n_functions": self.n_functions,
            "meta": self.meta,
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }, indent=1)
