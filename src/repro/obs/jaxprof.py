"""Opt-in ``jax.profiler`` integration for :mod:`repro.obs`.

Everything here degrades to a no-op when jax (or the profiler plugin) is
unavailable, so the zero-dep tracer/metrics layers never grow a hard jax
edge. Three capabilities:

* **Span annotations on the device timeline** — :func:`attach` installs a
  ``jax.profiler.TraceAnnotation`` factory on a tracer, so every host
  span also shows up as a named region in a ``start_trace``-captured
  profile (TensorBoard / Perfetto), lining host stages up against the
  XLA device timeline. ``trace.enable(annotate=True)`` does this for the
  process tracer. Inside jitted code, per-level attribution instead
  comes from ``jax.named_scope`` metadata (see ``core/msbfs.py``) —
  named scopes ride the HLO op names and add no jaxpr equations, so the
  committed dispatch budgets are unaffected.
* **Whole-run capture** — :func:`start_trace` / :func:`stop_trace` (or
  the :func:`profile_run` context manager) bracket a run with the XLA
  profiler writing to a TensorBoard logdir; ``serve --jax-profile DIR``
  wires this around the streaming loop.
* **Device-memory sampling** — :func:`sample_device_memory` reads
  ``device.memory_stats()`` into the ``device_bytes_in_use`` gauge
  (labeled per device) and :func:`save_memory_profile` dumps the full
  ``device_memory_profile`` pprof blob for offline digging. CPU backends
  often report no memory stats; both return ``None`` rather than raise.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from . import metrics as _metrics
from . import trace as _trace

__all__ = ["available", "annotation", "annotation_factory", "attach",
           "detach", "start_trace", "stop_trace", "profile_run",
           "sample_device_memory", "save_memory_profile"]


def _profiler():
    try:
        import jax.profiler as prof
        return prof
    except Exception:
        return None


def available() -> bool:
    """True when ``jax.profiler`` can be imported."""
    return _profiler() is not None


def annotation_factory():
    """Return a ``name -> context manager`` factory for span annotation
    (``TraceAnnotation`` when available, else null contexts)."""
    prof = _profiler()
    if prof is not None and hasattr(prof, "TraceAnnotation"):
        return prof.TraceAnnotation
    return lambda name: contextlib.nullcontext()


def annotation(name: str):
    """A single named annotation context (convenience wrapper)."""
    return annotation_factory()(name)


def attach(tracer: Optional[_trace.Tracer] = None) -> _trace.Tracer:
    """Install the annotation factory on ``tracer`` (default: the process
    tracer), so recorded spans also appear on profiler timelines."""
    tr = tracer if tracer is not None else _trace.tracer()
    tr.annotator = annotation_factory()
    return tr


def detach(tracer: Optional[_trace.Tracer] = None) -> _trace.Tracer:
    tr = tracer if tracer is not None else _trace.tracer()
    tr.annotator = None
    return tr


def start_trace(logdir: str) -> bool:
    """Start an XLA profiler capture into a TensorBoard logdir; returns
    False (no-op) when the profiler is unavailable."""
    prof = _profiler()
    if prof is None:
        return False
    prof.start_trace(logdir)
    return True


def stop_trace() -> None:
    prof = _profiler()
    if prof is not None:
        prof.stop_trace()


@contextlib.contextmanager
def profile_run(logdir: Optional[str]):
    """Bracket a block with start/stop_trace when ``logdir`` is set."""
    started = bool(logdir) and start_trace(logdir)
    try:
        yield started
    finally:
        if started:
            stop_trace()


def sample_device_memory(reg: Optional[_metrics.MetricsRegistry] = None
                         ) -> Optional[int]:
    """Sample per-device bytes-in-use into ``device_bytes_in_use`` gauges.

    Returns the total bytes across devices, or ``None`` when no device
    reports memory stats (typical for the CPU backend).
    """
    try:
        import jax
        devices = jax.devices()
    except Exception:
        return None
    reg = reg if reg is not None else _metrics.registry()
    total = None
    for d in devices:
        try:
            stats = d.memory_stats()
        except Exception:
            stats = None
        if not stats:
            continue
        used = stats.get("bytes_in_use")
        if used is None:
            continue
        reg.gauge("device_bytes_in_use", device=str(d)).set(used)
        total = (total or 0) + int(used)
    return total


def save_memory_profile(path: str) -> bool:
    """Write the pprof-format ``device_memory_profile`` blob to ``path``."""
    prof = _profiler()
    if prof is None:
        return False
    blob = prof.device_memory_profile()
    with open(path, "wb") as f:
        f.write(blob)
    return True
