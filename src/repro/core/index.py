"""Query index: per-source/target distance matrices, slack vectors, DP planner.

This is PathEnum's light-weight index (Lemma 3.1) built for the whole batch
with one multi-source BFS per direction (Alg 1/4 lines 1-2), plus two
engine-internal derived products:

  * slack vectors -- per-query / per-shared-node prune thresholds
      slack[v] = max over consumers (k_q - offset_q - dist(v, endpoint_q))
    A frontier vertex v at depth d survives iff d <= slack[v]
    (equivalently Lemma 3.1's  |p| + dist(v, t) <= k).

  * walk-count DP -- c_{l+1}[v] = sum_{(u,v)} c_l[u] * [slack[v] >= l+1]
    an upper bound on per-level path counts, used to plan static buffer
    capacities and to pick the forward/backward split (the "+" variants'
    cost-based search order, after PathEnum [15]).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DeviceGraph
from .msbfs import edge_span, msbfs_dist, msbfs_dist_ell, INF_FOR

__all__ = ["QueryIndex", "build_index", "walk_counts", "walk_counts_ell",
           "slack_from_dists"]

Query = tuple[int, int, int]  # (s, t, k)


@dataclasses.dataclass(frozen=True)
class QueryIndex:
    queries: tuple[Query, ...]
    k_max: int
    sources: np.ndarray       # (Su,) unique source vertices
    targets: np.ndarray       # (Tu,) unique target vertices
    src_col: np.ndarray       # (Q,) column of q.s in dist_s
    tgt_col: np.ndarray       # (Q,) column of q.t in dist_t
    dist_s: jax.Array         # (n+1, Su) int8 -- dist_G(s, v); row n = INF
    dist_t: jax.Array         # (n+1, Tu) int8 -- dist_{G_r}(t, v) = dist_G(v, t)
    INF: int

    def fwd_slack(self, qi: int) -> jax.Array:
        """(n+1,) int8 slack for the forward search of query qi."""
        s, t, k = self.queries[qi]
        return slack_from_dists(self.dist_t[:, self.tgt_col[qi]][:, None],
                                np.array([k]), np.array([0]), self.INF)

    def bwd_slack(self, qi: int) -> jax.Array:
        s, t, k = self.queries[qi]
        return slack_from_dists(self.dist_s[:, self.src_col[qi]][:, None],
                                np.array([k]), np.array([0]), self.INF)

    def gamma_sizes(self, hops: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """|Γ(q)|, |Γ_r(q)| for each query (vertices within q.k hops)."""
        ds = np.asarray(self.dist_s)[:-1]  # (n, Su)
        dt = np.asarray(self.dist_t)[:-1]
        gs = (ds[:, self.src_col] <= hops[None, :]).sum(0)
        gr = (dt[:, self.tgt_col] <= hops[None, :]).sum(0)
        return gs, gr


def slack_from_dists(dist_cols: jax.Array, ks: np.ndarray, offsets: np.ndarray,
                     INF: int) -> jax.Array:
    """slack[v] = max_c (ks[c] - offsets[c] - dist_cols[v, c]); INF dist -> -1.

    dist_cols: (n+1, C) int8; returns (n+1,) int8 (row n forced to -1).
    """
    d = dist_cols.astype(jnp.int32)
    val = ks[None, :].astype(np.int32) - offsets[None, :].astype(np.int32) - d
    val = jnp.where(d >= INF, -1, val)
    out = jnp.max(val, axis=1)
    out = jnp.clip(out, -1, 127).astype(jnp.int8)
    return out.at[-1].set(-1)


def build_index(dg: DeviceGraph, queries: Sequence[Query],
                edge_chunk: int = 1 << 22,
                backend: Optional[str] = None) -> QueryIndex:
    """Multi-source BFS from all sources on G and all targets on G_r.

    ``dg``'s edge lists may be sentinel-padded to a pow2 bucket; the
    chunk-rounded valid-edge span (``edge_span``) is threaded into the
    MS-BFS so the sweep skips all-sentinel chunks without the raw edge
    count ever becoming a trace-shaping value.

    ``backend``: a resolved kernel backend. ``None``/``"jnp"`` runs the
    segment-op sweeps over the edge lists; ``"pallas"``/``"interpret"``
    runs the fused bit-packed ELL sweeps (``msbfs_dist_ell``) — one
    dispatch per level, bit-equal distances. Forward distances gather the
    reverse ELL table (in-neighbors of G) and vice versa; the ELL tables
    are replicated even on a sharded engine, so the kernel route never
    depends on the GSPMD edge partition.
    """
    queries = tuple((int(s), int(t), int(k)) for s, t, k in queries)
    k_max = max(k for _, _, k in queries)
    srcs = np.unique(np.array([q[0] for q in queries], np.int32))
    tgts = np.unique(np.array([q[1] for q in queries], np.int32))
    src_col = np.searchsorted(srcs, [q[0] for q in queries]).astype(np.int32)
    tgt_col = np.searchsorted(tgts, [q[1] for q in queries]).astype(np.int32)
    if backend is not None and backend != "jnp":
        dist_s = msbfs_dist_ell(dg.r_ell_idx, jnp.asarray(srcs),
                                n=dg.n, k_max=k_max, backend=backend)
        dist_t = msbfs_dist_ell(dg.ell_idx, jnp.asarray(tgts),
                                n=dg.n, k_max=k_max, backend=backend)
    else:
        m_valid = edge_span(dg.m, edge_chunk, dg.m_cap)
        dist_s = msbfs_dist(dg.esrc, dg.edst, jnp.asarray(srcs),
                            n=dg.n, k_max=k_max, edge_chunk=edge_chunk,
                            m_valid=m_valid)
        dist_t = msbfs_dist(dg.r_esrc, dg.r_edst, jnp.asarray(tgts),
                            n=dg.n, k_max=k_max, edge_chunk=edge_chunk,
                            m_valid=m_valid)
    return QueryIndex(queries=queries, k_max=k_max, sources=srcs, targets=tgts,
                      src_col=src_col, tgt_col=tgt_col,
                      dist_s=dist_s, dist_t=dist_t, INF=INF_FOR(k_max))


@partial(jax.jit, static_argnames=("n", "budget", "edge_chunk", "m_valid"))
def walk_counts(esrc: jax.Array, edst: jax.Array, source, slack: jax.Array,
                *, n: int, budget: int, edge_chunk: int = 1 << 22,
                m_valid: Optional[int] = None) -> jax.Array:
    """Per-level pruned-walk counts: upper bound on enumeration frontier sizes.

    Returns (budget+1,) float32 totals (level 0 == 1). Uses float to avoid
    overflow on explosive workloads; the planner clamps anyway.

    The count vector carries the zero sentinel row ``n``, so a sentinel
    edge ``(n, n)`` gathers 0.0 and its segment id is dropped — padded and
    exact edge lists produce bit-equal totals. ``m_valid`` is the
    chunk-rounded span from :func:`~repro.core.msbfs.edge_span` (static;
    callers must pre-round).
    """
    m = esrc.shape[0]
    m_used = m if m_valid is None else min(int(m_valid), m)
    c = jnp.zeros((n + 1,), jnp.float32).at[source].set(1.0)
    totals = [jnp.float32(1.0)]
    for lvl in range(1, budget + 1):
        nxt = jnp.zeros((n,), jnp.float32)
        for lo in range(0, m_used, edge_chunk):
            hi = min(lo + edge_chunk, m)
            # whole-list sweeps skip the slice so sharded edge lists stay
            # shard-local (see msbfs_hop); sums are integer-valued f32,
            # exact below 2**24 regardless of partitioned reduce order
            es, ed = (esrc, edst) if lo == 0 and hi == m \
                else (esrc[lo:hi], edst[lo:hi])
            msgs = c[es]
            nxt = nxt + jax.ops.segment_sum(msgs, ed, num_segments=n,
                                            indices_are_sorted=True)
        nxt = nxt * (slack[:-1] >= lvl)
        c = jnp.concatenate([nxt, jnp.zeros((1,), jnp.float32)])
        totals.append(jnp.sum(nxt))
    return jnp.stack(totals)


@partial(jax.jit, static_argnames=("n", "budget", "backend"))
def walk_counts_ell(ell_in_idx: jax.Array, source, slack: jax.Array,
                    *, n: int, budget: int,
                    backend: str = "interpret") -> jax.Array:
    """Kernel twin of :func:`walk_counts`: the per-level DP step is one
    ELL gather-reduce dispatch (kernels/ell_spmm) instead of the chunked
    edge-list segment_sum.

    ell_in_idx: (n+1, D) padded ELL *in*-neighbor table (forward counts on
    G take ``dg.r_ell_idx``, reverse counts take ``dg.ell_idx`` — same
    convention as :func:`~repro.core.msbfs.msbfs_dist_ell`). Totals are
    integer-valued f32, exact (= bit-equal to the segment path) below
    2**24 regardless of reduce order.
    """
    from ..kernels.ell_spmm.ops import ell_aggregate

    idx = ell_in_idx[:n]                       # (n, D), pad = n
    c = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    totals = [jnp.float32(1.0)]
    for lvl in range(1, budget + 1):
        nxt = ell_aggregate(idx, c[:, None], op="sum", backend=backend)[:, 0]
        nxt = nxt * (slack[:-1] >= lvl)
        c = nxt
        totals.append(jnp.sum(nxt))
    return jnp.stack(totals)
