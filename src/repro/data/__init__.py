from . import lm_data, gnn_data, recsys_data  # noqa: F401
