"""Shared benchmark utilities: workloads, timing, CSV records.

Synthetic graphs stand in for the paper's SNAP/LAW datasets (offline
container; see DESIGN.md §6). Sizes are CPU-budgeted; relative claims
(speedups, scaling curves, decomposition) are what we reproduce.
"""
from __future__ import annotations

import time

from repro.core import BatchPathEngine
from repro.core import generators

RESULTS: list[dict] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def default_graph(scale: float = 1.0, seed: int = 0):
    n = int(20_000 * scale)
    return generators.community(n, n_comm=max(4, n // 2500), avg_deg=6.0,
                                seed=seed)


def time_planner(engine: BatchPathEngine, queries, planner, repeats: int = 1,
                 warmup: bool = True):
    """Best-of-N wall time for one planner (warm: jit compiles excluded)."""
    if warmup:  # first call pays jit compiles; time the warm path
        engine.run(queries, planner=planner)
    best = None
    stats = None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        res = engine.run(queries, planner=planner)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
        stats = res.stats
    return best, stats


def measured_similarity(engine: BatchPathEngine, queries) -> float:
    from repro.core import build_index
    from repro.core.similarity import similarity_matrix
    index = build_index(engine.dg, queries)
    mu = similarity_matrix(index)
    q = len(queries)
    return float((mu.sum() - q) / max(q * (q - 1), 1))
