"""Engine correctness: every mode vs the brute-force DFS oracle.

Deliberately kept on the legacy ``process(queries, mode=...)`` API: these
pre-existing tests double as coverage that the deprecation shim stays a
faithful front for ``run()`` (the warning itself is asserted in
test_query_api.py). Property-based invariants live in
test_engine_properties.py (they need hypothesis, an optional [test]
dependency, and degrade to skips there).
"""
import pytest

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from repro.core.oracle import enumerate_paths_bruteforce, path_set

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

MODES = ["basic", "basic+", "batch", "batch+", "pathenum", "auto"]


def _run_and_compare(g, qs, mode, cfg=None):
    eng = BatchPathEngine(g, cfg or EngineConfig(min_cap=64))
    res = eng.process(qs, mode=mode)
    for qi, (s, t, k) in enumerate(qs):
        got_list = [tuple(int(x) for x in row if x >= 0)
                    for row in res.paths[qi]]
        got = set(got_list)
        truth = path_set(enumerate_paths_bruteforce(g, s, t, k))
        assert len(got_list) == len(got), f"{mode} q{qi}: duplicate paths"
        assert got == truth, (f"{mode} q{qi}: {len(got)} vs {len(truth)}; "
                              f"missing {sorted(truth - got)[:3]} "
                              f"extra {sorted(got - truth)[:3]}")
    return res


@pytest.mark.parametrize("mode", MODES)
def test_modes_match_oracle_erdos(mode):
    g = generators.erdos(70, 3.0, seed=1)
    qs = generators.random_queries(g, 6, (2, 5), seed=2)
    _run_and_compare(g, qs, mode)


@pytest.mark.parametrize("mode", ["basic", "batch", "batch+"])
def test_modes_match_oracle_powerlaw(mode):
    g = generators.powerlaw(120, 3.0, seed=3)
    qs = generators.random_queries(g, 6, (3, 5), seed=4)
    _run_and_compare(g, qs, mode)


def test_batch_community_high_similarity():
    """Community graphs: heavy sharing; paper-faithful shared-node setting."""
    g = generators.community(90, n_comm=3, avg_deg=4.0, seed=5)
    qs = generators.similar_queries(g, 8, similarity=0.9, k_range=(3, 4),
                                    seed=6)
    res = _run_and_compare(g, qs, "batch",
                           EngineConfig(min_cap=64,
                                        paper_faithful_shares=True))
    assert res.stats["n_clusters"] >= 1


def test_k_edge_cases():
    g = generators.erdos(40, 3.0, seed=7)
    qs = generators.random_queries(g, 5, (1, 2), seed=8)
    for mode in ["basic", "batch"]:
        _run_and_compare(g, qs, mode)


def test_duplicate_and_nested_queries():
    g = generators.erdos(50, 3.0, seed=9)
    base = generators.random_queries(g, 3, (3, 4), seed=10)
    qs = base + [base[0], (base[1][0], base[1][1], 2)]
    _run_and_compare(g, qs, "batch")


def test_rejects_degenerate_queries():
    g = generators.erdos(20, 2.0, seed=11)
    eng = BatchPathEngine(g)
    with pytest.raises(ValueError):
        eng.process([(3, 3, 4)])
    with pytest.raises(ValueError):
        eng.process([(0, 1, 0)])


def test_repeated_process_calls_use_fresh_index():
    """Regression: the engine memoized host distance matrices by id(index);
    a freed index's id can be reused by the next batch's index, silently
    pruning with the PREVIOUS batch's distances. Back-to-back batches with
    different query sets on one engine must both be oracle-exact."""
    g = generators.community(100, n_comm=3, avg_deg=4.0, seed=7)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))
    qs1 = generators.similar_queries(g, 6, similarity=0.8, k_range=(3, 4),
                                     seed=8)
    qs2 = qs1[:3] + generators.similar_queries(g, 3, similarity=0.8,
                                               k_range=(3, 4), seed=9)
    for qs in (qs1, qs2, qs1):
        res = eng.process(qs, mode="batch")
        for qi, (s, t, k) in enumerate(qs):
            assert path_set(res.paths[qi]) == \
                path_set(enumerate_paths_bruteforce(g, s, t, k)), (qs, qi)


def test_n_dedup_counts_per_direction():
    """n_dedup = halves that mapped onto an existing plan node, summed over
    both directions (the seed version short-circuited on an empty dict and
    double-counted otherwise)."""
    g = generators.erdos(60, 3.0, seed=12)
    qs = generators.random_queries(g, 3, (3, 4), seed=13)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))

    # 3 identical queries: each direction collapses 3 halves onto 1 node
    res = eng.process([qs[0]] * 3, mode="batch")
    assert res.stats["n_dedup"] == 4  # (3-1) forward + (3-1) backward

    # queries with pairwise-distinct sources and targets share no halves
    seen_s, seen_t, distinct = set(), set(), []
    for s, t, k in qs:
        if s not in seen_s and t not in seen_t:
            distinct.append((s, t, k))
            seen_s.add(s)
            seen_t.add(t)
    res = eng.process(distinct, mode="batch")
    assert res.stats["n_dedup"] == 0
