"""Fraud detection on a transaction network (paper §I application 1).

A burst of transactions (t -> s edges about to be added) arrives; for each
we ask whether paths s ->..-> t of <= k hops exist — each found path closes
a suspicious cycle when the new edge lands. Transactions in a burst hit
overlapping hub accounts, so the batch engine's sharing shines.

Two-stage screening with typed queries: an exists-only pass flags the
suspicious transactions without assembling a single path row, then a
limit-capped paths pass pulls a few example cycles as evidence for just
the flagged ones.

    pip install -e .            # once (or: export PYTHONPATH=src)
    python examples/fraud_detection.py
"""
import numpy as np

from repro.core import PathQuery, PathSession, EngineConfig
from repro.core import generators

K = 5
N_TX = 24
N_EVIDENCE = 3                                           # cycles per alert

net = generators.powerlaw(30_000, avg_deg=6.0, seed=7)   # account graph
session = PathSession(net, EngineConfig(gamma=0.5))

# synthesize a burst: transactions target a few hub merchants
rng = np.random.default_rng(0)
hubs = rng.integers(0, 200, size=4)                      # popular merchants
tx = []
while len(tx) < N_TX:
    payer = int(rng.integers(0, net.n))
    merchant = int(hubs[rng.integers(0, len(hubs))])
    if payer != merchant:
        # new edge payer->merchant closes a cycle for each merchant->payer path
        tx.append((merchant, payer, K))

# stage 1: screen the whole burst with exists-only queries (no path rows)
screen = session.run([PathQuery(s, t, k, output="exists") for s, t, k in tx])
flagged = [i for i in range(len(tx)) if screen[i].exists]
print(f"burst of {len(tx)} transactions, k={K}")
print(f"flagged {len(flagged)} transactions "
      f"(screening assembled {screen.stats['n_rows_assembled']} path rows)")

# stage 2: pull a few example cycles as evidence for the flagged ones only
evidence = session.run([PathQuery(*tx[i], limit=N_EVIDENCE) for i in flagged])
for j, i in enumerate(flagged[:5]):
    s, t, k = tx[i]
    paths = evidence[j].paths
    cyc = [int(v) for v in paths[0] if v >= 0]
    print(f"  tx {t}->{s}: {paths.shape[0]} example cycles; "
          f"e.g. {cyc + [cyc[0]]}")
print("sharing:", screen.stats["n_shared"], "shared HC-s path queries across",
      screen.stats["n_clusters"], "clusters")
