"""Rule catalog + waiver parsing for the repro static analyzer.

The RPL rules encode the hot-path contracts PRs 4-6 established (see
docs/analysis.md for the full catalog with examples):

  RPL001  host-sync calls inside jit-reachable code
  RPL002  kernel math bypassing ``kernels.registry.dispatch``
  RPL003  shape-bearing jit arguments not declared static
  RPL004  Python-level loops over device arrays in jit-reachable code
  RPL005  raw pow2 shape math not going through ``graph.pow2_ceil``
  RPL006  hand-rolled ``time.perf_counter()`` timing in the engine /
          serving modules instead of ``repro.obs.trace`` spans

Waiver syntax (same line, or the line directly above the finding)::

    x = dist.item()  # repro-lint: waive[RPL001] tiny scalar, post-sweep

Multiple rules: ``waive[RPL001,RPL004] reason``. The reason is
mandatory — a waiver without one is itself a violation (RPL000).
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, List, Tuple

__all__ = [
    "RULES", "HOT_MODULE_PATTERNS", "TIMED_MODULE_PATTERNS",
    "STATIC_SHAPE_PARAMS",
    "WAIVER_RE", "parse_waivers", "is_hot_module", "is_timed_module",
]

RULES: Dict[str, str] = {
    "RPL000": "malformed waiver (missing reason or unknown rule id)",
    "RPL001": "host sync inside jit-reachable code (.item(), int()/float()/"
              "bool() on arrays, np.asarray/np.array, jax.device_get)",
    "RPL002": "kernel math bypassing kernels.registry dispatch (importing or "
              "calling *_ref/*_pallas arms outside ref.py/kernel.py or "
              "register_op(...))",
    "RPL003": "shape-bearing argument of a jitted function not declared in "
              "static_argnames (forces per-value retrace or traced shapes)",
    "RPL004": "Python loop over a device array in jit-reachable code "
              "(unrolls into the trace or forces a host transfer per step)",
    "RPL005": "raw pow2/parity shape math (2**x, 1<<x, x%2) outside "
              "graph.pow2_ceil/pad_edge_list (breaks the stable-shape "
              "bucket contract)",
    "RPL006": "hand-rolled time.perf_counter() timing in an engine/serving "
              "module — stage timing must go through repro.obs.trace spans "
              "so every wall lands in one trace/metrics pipeline",
}

# Modules where jit-reachability matters for RPL001/RPL004 (relative to
# the lint root, i.e. src/repro/). kernels/* bodies are all hot; the
# listed core modules hold every jitted engine sweep.
HOT_MODULE_PATTERNS: Tuple[str, ...] = (
    "core/msbfs.py",
    "core/join.py",
    "core/enumerate.py",
    "core/index.py",
    "kernels/*.py",
    "kernels/*/*.py",
)

# Modules whose stage timing must go through repro.obs.trace spans
# (RPL006): the hot modules above plus the host-side engine / serving
# layer that owns the per-stage walls. obs/ itself is exempt (it is the
# blessed definition site); ft/driver.py and launch/dryrun.py stay off
# the list on purpose — their walls time external processes, not
# pipeline stages.
TIMED_MODULE_PATTERNS: Tuple[str, ...] = HOT_MODULE_PATTERNS + (
    "core/engine.py",
    "core/distributed.py",
    "core/delta.py",
    "core/cache.py",
    "core/planner.py",
    "core/session.py",
    "launch/serve.py",
)

# Parameter names that carry shapes (or select compiled variants) in this
# codebase; RPL003 requires them in static_argnames wherever they appear
# on a jitted function's signature.
STATIC_SHAPE_PARAMS = frozenset({
    "n", "k_max", "m_valid", "edge_chunk", "backend",
    "level", "budget", "out_cap", "out_width", "cap",
    "col", "a_col", "b_col", "p_col", "c_col", "pair_cap",
})

WAIVER_RE = re.compile(
    r"#\s*repro-lint:\s*waive\[(?P<rules>[A-Z0-9,\s]+)\]\s*(?P<reason>.*)$")


def is_hot_module(relpath: str) -> bool:
    """True if ``relpath`` (posix, relative to the lint root) is one of
    the jit-reachable modules RPL001/RPL004 apply to."""
    from fnmatch import fnmatch
    rel = relpath.replace("\\", "/")
    return any(fnmatch(rel, pat) for pat in HOT_MODULE_PATTERNS)


def is_timed_module(relpath: str) -> bool:
    """True if ``relpath`` must route stage timing through obs spans
    (RPL006). Anything under ``obs/`` is exempt — the span/metrics
    implementation necessarily reads the clock."""
    from fnmatch import fnmatch
    rel = relpath.replace("\\", "/")
    if rel.split("/")[0] == "obs":
        return False
    return any(fnmatch(rel, pat) for pat in TIMED_MODULE_PATTERNS)


def parse_waivers(source: str) -> Tuple[Dict[int, Tuple[frozenset, str]],
                                        List[Tuple[int, str]]]:
    """Scan ``source`` for waiver comments.

    Returns ``(waivers, malformed)``:
      * ``waivers`` maps *covered* line numbers (the comment's own line
        and the one below it, so a waiver can sit above a long call) to
        ``(rule_ids, reason)``.
      * ``malformed`` lists ``(line, message)`` pairs for waivers with an
        empty reason or an unknown rule id — surfaced as RPL000.
    """
    waivers: Dict[int, Tuple[frozenset, str]] = {}
    malformed: List[Tuple[int, str]] = []
    # only genuine COMMENT tokens count — a waiver example quoted in a
    # docstring must not register (or trip RPL000)
    comments: List[Tuple[int, str, bool]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                code_before = tok.line[:tok.start[1]].strip()
                comments.append((tok.start[0], tok.string,
                                 not code_before))
    except tokenize.TokenError:
        return waivers, malformed
    for lineno, text, own_line in comments:
        m = WAIVER_RE.search(text)
        if not m:
            continue
        rules = frozenset(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
        reason = m.group("reason").strip()
        unknown = sorted(r for r in rules if r not in RULES)
        if unknown:
            malformed.append(
                (lineno, f"unknown rule id(s) {unknown} in waiver"))
            continue
        if not reason:
            malformed.append(
                (lineno, "waiver has no reason — every exception must be "
                         "documented in-line"))
            continue
        waivers[lineno] = (rules, reason)
        if own_line:
            # comment-only line: the waiver covers the next code line
            waivers[lineno + 1] = (rules, reason)
    return waivers, malformed
