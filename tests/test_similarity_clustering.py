"""Def 4.5 similarity properties + Alg 2 clustering behaviour + Alg 3 plans."""
import numpy as np
import pytest

from repro.core import generators, build_index
from repro.core.graph import DeviceGraph
from repro.core.similarity import similarity_matrix, gamma_matrix
from repro.core.clustering import cluster_queries
from repro.core.detect import detect_common_queries


@pytest.fixture(scope="module")
def setup():
    g = generators.community(80, n_comm=2, avg_deg=4.0, seed=1)
    qs = generators.random_queries(g, 8, (3, 4), seed=2)
    dg = DeviceGraph.build(g)
    index = build_index(dg, qs)
    return g, qs, dg, index


class TestSimilarity:
    def test_mu_properties(self, setup):
        g, qs, dg, index = setup
        mu = similarity_matrix(index)
        assert mu.shape == (len(qs), len(qs))
        assert np.allclose(mu, mu.T)
        assert np.all((mu >= 0) & (mu <= 1 + 1e-9))
        assert np.allclose(np.diag(mu), 1.0)

    def test_identical_queries_mu_one(self, setup):
        g, qs, dg, _ = setup
        index = build_index(dg, [qs[0], qs[0]])
        mu = similarity_matrix(index)
        assert mu[0, 1] == pytest.approx(1.0)

    def test_kernel_backend_matches_jnp(self, setup):
        g, qs, dg, index = setup
        a = similarity_matrix(index, backend="jnp")
        b = similarity_matrix(index, backend="interpret")
        assert np.array_equal(np.asarray(a), np.asarray(b))
        from repro.kernels.pairwise_popcount import ops as pops
        gm = gamma_matrix(index)
        ref = np.asarray(pops.pairwise_intersections(gm, backend="jnp"))
        itp = np.asarray(pops.pairwise_intersections(gm, backend="interpret"))
        assert np.array_equal(ref, itp)

    def test_gamma_counts_match_bfs(self, setup):
        g, qs, dg, index = setup
        from repro.core.oracle import bfs_dist_from
        gm = np.asarray(gamma_matrix(index))
        for qi, (s, t, k) in enumerate(qs):
            truth = (bfs_dist_from(g, s, k) <= k).sum()
            assert gm[qi].sum() == truth


class TestClustering:
    def test_threshold_extremes(self, setup):
        g, qs, dg, index = setup
        mu = similarity_matrix(index)
        singles = cluster_queries(mu, gamma=1.01)
        assert len(singles) == len(qs)
        one = cluster_queries(np.ones_like(mu), gamma=0.5)
        assert len(one) == 1

    def test_partition_validity(self, setup):
        g, qs, dg, index = setup
        mu = similarity_matrix(index)
        clusters = cluster_queries(mu, gamma=0.5)
        flat = sorted(q for c in clusters for q in c)
        assert flat == list(range(len(qs)))

    def test_block_structure_recovered(self):
        """Two obvious blocks -> two clusters at suitable gamma."""
        mu = np.full((6, 6), 0.05)
        mu[:3, :3] = 0.9
        mu[3:, 3:] = 0.9
        np.fill_diagonal(mu, 1.0)
        clusters = sorted(cluster_queries(mu, gamma=0.5), key=min)
        assert [sorted(c) for c in clusters] == [[0, 1, 2], [3, 4, 5]]


class TestDetect:
    def test_plan_is_dag_with_valid_topo(self, setup):
        g, qs, dg, index = setup
        cluster = list(range(len(qs)))
        halves = {qi: (qs[qi][0], (qs[qi][2] + 1) // 2) for qi in cluster}
        hop_ok = np.ones(g.n, bool)
        plan = detect_common_queries(g, cluster, halves, hop_ok,
                                     reverse=False, min_shared_budget=0)
        pos = {nid: i for i, nid in enumerate(plan.topo)}
        assert sorted(pos) == sorted(n.nid for n in plan.nodes)
        for node in plan.nodes:
            for child in node.in_edges:
                assert pos[child] < pos[node.nid], "child must precede parent"

    def test_every_query_has_half_and_consumers(self, setup):
        g, qs, dg, index = setup
        cluster = list(range(len(qs)))
        halves = {qi: (qs[qi][0], (qs[qi][2] + 1) // 2) for qi in cluster}
        plan = detect_common_queries(g, cluster, halves, np.ones(g.n, bool),
                                     reverse=False)
        for qi in cluster:
            assert qi in plan.half_of_query
        for node in plan.nodes:
            assert node.consumers, f"node {node.nid} unreachable from queries"
            for q, off in node.consumers:
                assert off >= 0

    def test_identical_halves_deduped(self, setup):
        g, qs, dg, index = setup
        q0 = qs[0]
        halves = {0: (q0[0], 2), 1: (q0[0], 2)}
        plan = detect_common_queries(g, [0, 1], halves, np.ones(g.n, bool),
                                     reverse=False)
        assert plan.half_of_query[0] == plan.half_of_query[1]

    def test_sharing_found_on_community_graph(self):
        g = generators.community(60, n_comm=1, avg_deg=6.0, seed=3)
        qs = generators.similar_queries(g, 6, similarity=1.0, k_range=(4, 4),
                                        seed=4)
        halves = {i: (q[0], 2) for i, q in enumerate(qs)}
        plan = detect_common_queries(g, list(range(len(qs))), halves,
                                     np.ones(g.n, bool), reverse=False,
                                     min_shared_budget=0)
        # overlapping queries on one community should share something
        assert plan.n_shared >= 1 or len(set(h[0] for h in halves.values())) == len(qs)
