"""Typed query/result API: PathQuery coercion + validation, per-query
output kinds (count/exists/limit) oracle-exact across planners with the
⊕-join materialization genuinely skipped, the process() deprecation shim,
QueryResult laziness, and the PathSession facade over batch + streaming."""
import numpy as np
import pytest

from repro.core import (BatchPathEngine, BatchReport, EngineConfig, Output,
                        PathQuery, PathSession, Planner, QueryResult,
                        generators)
from repro.core.oracle import enumerate_paths_bruteforce, path_set


@pytest.fixture(scope="module")
def workload():
    g = generators.erdos(60, 3.0, seed=1)
    qs = generators.random_queries(g, 4, (3, 4), seed=2)
    truth = {q: path_set(enumerate_paths_bruteforce(g, *q)) for q in qs}
    assert any(truth.values()), "workload needs at least one non-empty query"
    return g, qs, truth


class TestPathQuery:
    def test_coerce_tuple_list_and_numpy(self):
        q = PathQuery.coerce((1, 2, 3))
        assert q == PathQuery(1, 2, 3) and q.key == (1, 2, 3)
        assert PathQuery.coerce([4, 5, 6]).key == (4, 5, 6)
        arr = np.array([7, 8, 9])
        qn = PathQuery.coerce(arr)
        assert qn.key == (7, 8, 9) and isinstance(qn.s, int)
        assert PathQuery.coerce(q) is q          # PathQuery passes through

    def test_unpacks_like_legacy_tuple(self):
        s, t, k = PathQuery(1, 2, 3)
        assert (s, t, k) == (1, 2, 3)
        assert tuple(PathQuery(1, 2, 3, output="count")) == (1, 2, 3)

    @pytest.mark.parametrize("bad", [
        (3, 3, 4),           # s == t
        (0, 1, 0),           # k < 1
        (-1, 2, 3),          # negative vertex
        (1, 2),              # wrong arity
        "nonsense",
    ])
    def test_invalid_queries_rejected(self, bad):
        with pytest.raises(ValueError):
            PathQuery.coerce(bad)

    def test_invalid_options_rejected(self):
        with pytest.raises(ValueError):
            PathQuery(0, 1, 3, limit=0)
        with pytest.raises(ValueError):
            PathQuery(0, 1, 3, output="exists", limit=5)
        with pytest.raises(ValueError):
            PathQuery(0, 1, 3, output="bogus")

    def test_planner_and_output_coercion(self):
        assert Planner.coerce("batch+") is Planner.BATCH_PLUS
        assert Planner.coerce(Planner.BASIC) is Planner.BASIC
        assert Planner.BATCH_PLUS.plus and Planner.BATCH_PLUS.batched
        assert not Planner.PATHENUM.batched
        with pytest.raises(ValueError):
            Planner.coerce("turbo")
        assert Output.coerce("COUNT") is Output.COUNT
        with pytest.raises(ValueError):
            Output.coerce("all")


class TestOutputKinds:
    @pytest.mark.parametrize("planner", ["basic", "batch", "pathenum"])
    def test_count_exists_limit_oracle_exact(self, workload, planner):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        mixed = []
        for s, t, k in qs:
            mixed += [PathQuery(s, t, k),
                      PathQuery(s, t, k, output="count"),
                      PathQuery(s, t, k, output="exists"),
                      PathQuery(s, t, k, limit=2),
                      PathQuery(s, t, k, output="count", limit=2)]
        rep = eng.run(mixed, planner=planner)
        assert isinstance(rep, BatchReport) and len(rep) == len(mixed)
        for i, q in enumerate(qs):
            full, cnt, exi, lim, climit = rep[5 * i:5 * i + 5]
            assert path_set(full.paths) == truth[q]
            assert cnt.count == len(truth[q])
            assert cnt.exists == bool(truth[q])
            assert exi.exists == bool(truth[q])
            got = path_set(lim.paths)
            assert got <= truth[q]
            assert len(got) == lim.paths.shape[0] == min(2, len(truth[q]))
            assert climit.count == min(2, len(truth[q]))

    @pytest.mark.parametrize("planner", ["basic", "batch", "pathenum"])
    def test_count_exists_skip_materialization(self, workload, planner):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        silent = [PathQuery(s, t, k, output=o)
                  for s, t, k in qs for o in (Output.COUNT, Output.EXISTS)]
        rep = eng.run(silent, planner=planner)
        assert rep.stats["n_rows_assembled"] == 0
        for i, q in enumerate(qs):
            assert rep[2 * i].count == len(truth[q])
            assert rep[2 * i + 1].exists == bool(truth[q])

    def test_paths_rows_assembled_accounted(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        rep = eng.run(qs)
        assert rep.stats["n_rows_assembled"] == \
            sum(len(truth[q]) for q in qs)

    def test_tuple_batches_still_work(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        rep = eng.run(qs)                       # bare tuples
        for qi, q in enumerate(qs):
            assert path_set(rep[qi].paths) == truth[q]
            assert rep[qi].time_s >= 0
        assert rep.stats["planner"] == "batch"

    def test_out_of_range_vertices_rejected(self, workload):
        g, qs, _ = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        with pytest.raises(ValueError):
            eng.run([(0, g.n + 5, 3)])

    @pytest.mark.parametrize("planner", ["basic", "batch", "pathenum"])
    def test_empty_batch_is_legal(self, workload, planner):
        g, _, _ = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        rep = eng.run([], planner=planner)
        assert len(rep) == 0 and rep.paths == {}
        assert rep.stats["n_queries"] == 0
        assert rep.stats["n_rows_assembled"] == 0

    def test_limit_met_forward_skips_backward_enumeration(self):
        """exists-only and limit-satisfied queries whose forward levels
        already answer must not force the backward enumeration (the bwd
        thunk stays unforced)."""
        from repro.core.graph import Graph
        # 0->3 direct, plus 0->1->2->3: the k=3 forward half (a=2) sees
        # the direct edge at level 1, so forward completions exist
        g = Graph.from_edges(4, [0, 0, 1, 2], [3, 1, 2, 3])
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        calls = []
        orig = eng._run_node

        def spy(reverse, *args, **kwargs):
            calls.append(reverse)
            return orig(reverse, *args, **kwargs)

        eng._run_node = spy
        r = eng.run([PathQuery(0, 3, 3, output="exists")],
                    planner="basic")[0]
        assert r.exists and calls == [False]     # backward never enumerated
        calls.clear()
        r = eng.run([PathQuery(0, 3, 3, limit=1)], planner="basic")[0]
        assert r.paths.shape[0] == 1 and calls == [False]
        calls.clear()
        # an unlimited paths query does need both halves (0->1->2->3)
        r = eng.run([PathQuery(0, 3, 3)], planner="basic")[0]
        assert r.count == 2 and calls == [False, True]


class TestLegacyShim:
    def test_process_warns_and_matches_run(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        with pytest.warns(DeprecationWarning):
            res = eng.process(qs, mode="batch")
        assert isinstance(res.paths, dict)
        for qi, q in enumerate(qs):
            assert isinstance(res.paths[qi], np.ndarray)
            assert path_set(res.paths[qi]) == truth[q]
        for key in ("n_queries", "t_enumerate", "n_clusters"):
            assert key in res.stats

    def test_process_still_validates(self, workload):
        g, _, _ = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError):
                eng.process([(3, 3, 4)])


class TestQueryResultLazy:
    def test_paths_materialize_on_demand(self, workload):
        g, qs, truth = workload
        q = max(qs, key=lambda q: len(truth[q]))
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        r = eng.run([q])[0]
        assert not r._store.materialized
        assert r.count == len(truth[q])          # count: no host transfer
        assert not r._store.materialized
        assert path_set(r.paths) == truth[q]     # now materialized + cached
        assert r._store.materialized and r.paths is r.paths

    def test_duplicate_queries_share_one_host_transfer(self, workload):
        g, qs, truth = workload
        q = max(qs, key=lambda q: len(truth[q]))
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        rep = eng.run([q, q, q])
        assert rep[0]._store is rep[1]._store is rep[2]._store
        first = rep[0].paths
        assert rep[2]._store.materialized        # aliases, not a re-transfer
        assert rep[2].paths is first

    def test_offload_releases_device_buffer(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        r = eng.run([qs[0]])[0].offload()
        assert r._store.materialized and r._store._pathset is None
        assert path_set(r.paths) == truth[qs[0]]
        # count/exists results have no buffer; offload is a no-op
        s, t, k = qs[0]
        rc = eng.run([PathQuery(s, t, k, output="count")])[0].offload()
        assert rc.count == len(truth[qs[0]])

    def test_count_only_has_no_paths(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        s, t, k = qs[0]
        r = eng.run([PathQuery(s, t, k, output="count")])[0]
        assert r.count == len(truth[qs[0]])
        with pytest.raises(ValueError):
            r.paths

    def test_exists_only_has_no_count(self, workload):
        g, qs, truth = workload
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        s, t, k = qs[0]
        r = eng.run([PathQuery(s, t, k, output="exists")])[0]
        assert r.exists == bool(truth[qs[0]])
        with pytest.raises(ValueError):
            r.count
        assert "exists" in repr(r)               # repr never materializes


class TestPathSession:
    def test_batch_and_streaming_return_same_result_type(self):
        g = generators.community(100, n_comm=3, avg_deg=4.0, seed=1)
        qs = generators.similar_queries(g, 6, similarity=0.7,
                                        k_range=(3, 4), seed=2)
        ses = PathSession(g, EngineConfig(min_cap=64), n_groups=2)
        rep = ses.run(qs)
        qids = [ses.submit(q) for q in qs]
        streamed = ses.results()
        assert set(streamed) == set(qids)
        for qid, (qi, q) in zip(qids, enumerate(qs)):
            assert type(streamed[qid]) is type(rep[qi]) is QueryResult
            truth = path_set(enumerate_paths_bruteforce(g, *q))
            assert path_set(streamed[qid].paths) == truth
            assert path_set(rep[qi].paths) == truth
        assert streamed[qids[0]].query == PathQuery.coerce(qs[0])
        assert ses.results() == {}               # popped, like take()

    def test_streaming_output_kinds(self):
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=3)
        (q,) = generators.random_queries(g, 1, (3, 3), seed=4)
        truth = path_set(enumerate_paths_bruteforce(g, *q))
        ses = PathSession(g, EngineConfig(min_cap=64))
        s, t, k = q
        qid_c = ses.submit(PathQuery(s, t, k, output="count"))
        qid_e = ses.submit(PathQuery(s, t, k, output="exists"))
        out = ses.results()
        assert out[qid_c].count == len(truth)
        assert out[qid_e].exists == bool(truth)

    def test_submit_rejects_malformed_before_admission(self):
        g = generators.erdos(30, 2.0, seed=5)
        ses = PathSession(g, EngineConfig(min_cap=64))
        for bad in [(3, 3, 4), (0, 1, 0), (0, g.n, 3), (1,)]:
            with pytest.raises(ValueError):
                ses.submit(bad)
        assert not ses.server._waiting           # nothing was enqueued

    def test_update_graph_invalidates_cache(self):
        g = generators.community(80, n_comm=2, avg_deg=4.0, seed=6)
        qs = generators.similar_queries(g, 4, similarity=0.8,
                                        k_range=(3, 3), seed=7)
        ses = PathSession(g, EngineConfig(min_cap=64, cache_bytes=64 << 20))
        ses.run(qs)
        assert len(ses.cache) > 0
        rng = np.random.default_rng(0)
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        keep = rng.random(src.size) > 0.33
        from repro.core.graph import Graph
        g2 = Graph.from_edges(g.n, src[keep], g.indices[keep])
        ses.update_graph(g2)
        assert len(ses.cache) == 0
        rep = ses.run(qs)
        for qi, q in enumerate(qs):
            assert path_set(rep[qi].paths) == \
                path_set(enumerate_paths_bruteforce(g2, *q))

    def test_session_wraps_existing_engine(self):
        g = generators.erdos(40, 3.0, seed=8)
        eng = BatchPathEngine(g, EngineConfig(min_cap=64))
        ses = PathSession(eng, planner="basic")
        assert ses.engine is eng
        qs = generators.random_queries(g, 2, (3, 3), seed=9)
        rep = ses.run(qs)
        assert rep.stats["planner"] == "basic"
