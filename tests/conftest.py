import os
import sys

# Tests run on the real single CPU device — never set the 512-device flag
# here (that is exclusively dryrun.py's job).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
