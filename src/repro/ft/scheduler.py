"""Work-stealing cluster scheduler for batch query serving.

Straggler mitigation for the paper's engine at pod scale: query *clusters*
(the unit of sharing — a cluster's queries must stay together to reuse the
sharing graph) are assigned to data-parallel replica groups by estimated
cost; when a group runs dry it steals the largest pending cluster from the
most loaded group. The queue is checkpointable so a group failure only
loses its in-flight cluster, which returns to the queue (at-least-once;
results are idempotent by query id).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from pathlib import Path
from typing import Callable, Optional

__all__ = ["WorkStealingScheduler"]


@dataclasses.dataclass
class _Item:
    cluster_id: int
    queries: list
    cost: float


class WorkStealingScheduler:
    def __init__(self, n_groups: int, cost_fn: Optional[Callable] = None):
        self.n_groups = n_groups
        self.cost_fn = cost_fn or (lambda qs: float(len(qs)))
        self.queues: list[list[_Item]] = [[] for _ in range(n_groups)]
        self.done: dict[int, object] = {}
        self.in_flight: dict[int, _Item] = {}
        self._inflight_group: dict[int, int] = {}   # cluster_id -> group
        self.steals = 0
        self.failovers = 0          # group failures absorbed (fail_group calls)
        self.requeued = 0           # in-flight items returned to a queue
        self._next_id = 0
        self._lock = threading.Lock()

    # -- planning ------------------------------------------------------
    def submit(self, clusters: list[list]) -> list[int]:
        """Greedy longest-processing-time assignment of clusters to groups.

        Returns the assigned cluster ids (in input order). Ids are globally
        monotonic so repeated submissions — the streaming admission loop
        feeds one micro-batch of clusters at a time — never collide.
        """
        with self._lock:
            ids = [self._alloc_id() for _ in clusters]
            items = [_Item(cid, qs, self.cost_fn(qs))
                     for cid, qs in zip(ids, clusters)]
            items.sort(key=lambda it: -it.cost)
            # account for load already queued or executing (streaming:
            # earlier micro-batches may still be in flight on a group)
            loads = [sum(i.cost for i in q) for q in self.queues]
            for cid, grp in self._inflight_group.items():
                it = self.in_flight.get(cid)
                if it is not None:
                    loads[grp] += it.cost
            for it in items:
                g = loads.index(min(loads))
                self.queues[g].append(it)
                loads[g] += it.cost
            return ids

    def submit_one(self, queries: list) -> int:
        """Streaming admission: enqueue a single cluster onto the least
        loaded group and return its cluster id."""
        return self.submit([queries])[0]

    def _alloc_id(self) -> int:
        cid = self._next_id
        self._next_id += 1
        return cid

    # -- execution -----------------------------------------------------
    def next_for(self, group: int) -> Optional[_Item]:
        with self._lock:
            if self.queues[group]:
                it = self.queues[group].pop(0)
            else:
                victim = max(range(self.n_groups),
                             key=lambda g: sum(i.cost for i in self.queues[g]))
                if not self.queues[victim]:
                    return None
                it = self.queues[victim].pop()      # steal from the back
                self.steals += 1
            self.in_flight[it.cluster_id] = it
            self._inflight_group[it.cluster_id] = group
            return it

    def complete(self, cluster_id: int, result) -> None:
        with self._lock:
            self.in_flight.pop(cluster_id, None)
            self._inflight_group.pop(cluster_id, None)
            self.done[cluster_id] = result

    def fail_group(self, group: int,
                   lost_cluster_ids: Optional[list[int]] = None) -> list[int]:
        """A replica group died: its in-flight clusters go back to the queue.

        ``lost_cluster_ids`` defaults to every cluster currently in flight
        on ``group`` (the scheduler tracks that mapping, so callers don't
        have to). Requeued items land on the least-loaded *surviving*
        queue — never back on the failed group, whose queue would only
        drain through steals. Returns the requeued cluster ids; already-
        completed clusters are not re-run (at-least-once, idempotent by
        query id downstream).
        """
        with self._lock:
            self.failovers += 1
            if lost_cluster_ids is None:
                lost_cluster_ids = [cid for cid, g in
                                    self._inflight_group.items() if g == group]
            survivors = [g for g in range(self.n_groups) if g != group] \
                or [group]
            requeued = []
            for cid in lost_cluster_ids:
                it = self.in_flight.pop(cid, None)
                self._inflight_group.pop(cid, None)
                if it is not None and cid not in self.done:
                    target = min(survivors,
                                 key=lambda g: sum(i.cost for i in self.queues[g]))
                    self.queues[target].append(it)
                    requeued.append(cid)
            self.requeued += len(requeued)
            return requeued

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self.queues) + len(self.in_flight)

    # -- persistence (restart safety) ----------------------------------
    def snapshot(self, path: str | Path) -> None:
        with self._lock:
            state = {"queues": [[(i.cluster_id, i.queries, i.cost)
                                 for i in q] for q in self.queues],
                     "in_flight": [(i.cluster_id, i.queries, i.cost)
                                   for i in self.in_flight.values()],
                     "done": sorted(self.done)}
        Path(path).write_text(json.dumps(state))

    @classmethod
    def restore(cls, path: str | Path, n_groups: int) -> "WorkStealingScheduler":
        state = json.loads(Path(path).read_text())
        sched = cls(n_groups)
        for g, q in enumerate(state["queues"]):
            for cid, qs, cost in q:
                sched.queues[g % n_groups].append(_Item(cid, qs, cost))
        # in-flight work was lost with the crash: requeue it
        for cid, qs, cost in state["in_flight"]:
            sched.queues[0].append(_Item(cid, qs, cost))
        sched.done = dict.fromkeys(state["done"])
        seen = [i.cluster_id for q in sched.queues for i in q] + list(sched.done)
        sched._next_id = max(seen, default=-1) + 1
        return sched
