"""schnet [gnn] — 3 interactions d=64, rbf=300, cutoff=10 [arXiv:1706.08566]."""
from ..config import GNNConfig
from ._shapes import GNN_SHAPES as SHAPES  # noqa: F401

CONFIG = GNNConfig(name="schnet", kind="schnet", n_layers=3, d_hidden=64,
                   aggregator="sum", mlp_layers=2,
                   extras=(("rbf", 300), ("cutoff", 10.0), ("d_out", 1)))

REDUCED = GNNConfig(name="schnet-reduced", kind="schnet", n_layers=2,
                    d_hidden=16, aggregator="sum", mlp_layers=2,
                    extras=(("rbf", 32), ("cutoff", 10.0), ("d_out", 1)))

FAMILY = "gnn"
