"""Recompile regression harness (core.compilelog over the serving path).

The batch advantage dies at the compiler if shapes drift: one stray
``(m,)`` change retraces every edge kernel on the next batch. These tests
pin the shape-stability contract of the sentinel-padded pow2 buckets:

  (a) repeated batches of *different* queries on the same graph compile
      nothing after the first batch;
  (b) an insert-heavy churn loop of 20 ``apply_delta`` rounds inside one
      pow2 edge bucket compiles nothing — while staying oracle-exact and
      bit-identical to unpadded execution;
  (c) a bucket-crossing delta retraces each kernel at most as often as
      its cold start did (once per shape it uses), then the loop is
      immediately warm again.

The workload is a circulant graph (every vertex sees the same local
structure), so rotated queries are isomorphic and any compile observed
in a warm window is a genuine shape leak, not workload noise. Churn
edges live in the hop-cold region (outside every query ball and prune
radius), so the cross-batch cache stays fully warm and retraces cannot
hide behind rematerialization.
"""
import numpy as np
import pytest

from repro.core import BatchPathEngine, EngineConfig, GraphDelta
from repro.core.graph import DeviceGraph, Graph
from repro.core.oracle import enumerate_paths_bruteforce, path_set

OFFSETS = (1, 2, 3)
N = 64


def circulant(n=N, offsets=OFFSETS) -> Graph:
    """Vertex-transitive graph: v -> v + d (mod n) for each offset d."""
    src = np.repeat(np.arange(n, dtype=np.int64), len(offsets))
    dst = (src + np.tile(np.array(offsets, np.int64), n)) % n
    return Graph.from_edges(n, src, dst)


def _engine(**cfg) -> BatchPathEngine:
    base = dict(min_cap=256, cache_bytes=8 << 20, log_compiles=True)
    base.update(cfg)
    return BatchPathEngine(circulant(), EngineConfig(**base))


def _assert_oracle_exact(engine, report, queries):
    for qi, (s, t, k) in enumerate(queries):
        truth = path_set(enumerate_paths_bruteforce(engine.g, s, t, k))
        assert path_set(report[qi].paths) == truth, f"q{qi}"


# queries whose balls (fwd [s, s+3k], bwd [t-3k, t]) avoid [20, 52): the
# churn pool below. k=3 with sources 0 and 8 keeps the hot region inside
# {58..63, 0..17}.
CHURN_QS = [(0, 3, 3), (8, 11, 3)]


def _churn_delta(i: int) -> GraphDelta:
    """Round i inserts the single cold-region edge (20+i, 27+i): absent in
    the circulant (offset 7), endpoints > 3 hops from every query ball, and
    each endpoint's degree grows to exactly the pow2 ELL cap (4)."""
    return GraphDelta.from_pairs(add=[(20 + i, 27 + i)])


class TestRepeatedBatches:
    def test_different_query_batches_compile_nothing_after_first(self):
        eng = _engine()
        assert eng.dg.m_cap == 256 and eng.g.m == 192   # headroom by design

        def batch(i):
            return [(8 * j + i, (8 * j + i + 3) % N, 3) for j in range(6)]

        r0 = eng.run(batch(0))
        assert r0.stats["n_compiles"] > 0               # cold start
        for i in (1, 2, 3):
            r = eng.run(batch(i))
            assert r.stats["n_compiles"] == 0, \
                (i, r.stats["compiled_kernels"])
            assert r.stats["n_retraces"] == 0
            _assert_oracle_exact(eng, r, batch(i))


class TestInBucketChurn:
    @pytest.mark.parametrize("backend", ["host", "msbfs"])
    def test_20_delta_rounds_zero_retraces(self, backend):
        eng = _engine(delta_backend=backend)
        cl = eng.compile_log
        eng.run(CHURN_QS)
        # warmup round: first delta compiles the (shape-stable) delta-path
        # kernels — ELL row scatters, the msbfs invalidation sweep
        rep0 = eng.apply_delta(_churn_delta(0))
        assert rep0["device_update"] == "incremental"
        eng.run(CHURN_QS)

        snap = cl.snapshot()
        for i in range(1, 21):                          # 20 churn rounds
            rep = eng.apply_delta(_churn_delta(i))
            assert rep["device_update"] == "incremental"
            assert rep["cache_evicted"] == 0            # hop-cold churn
            assert rep["n_compiles"] == 0, (i, rep["compiled_kernels"])
            r = eng.run(CHURN_QS)
            assert r.stats["n_compiles"] == 0, \
                (i, r.stats["compiled_kernels"])
            assert eng.dg.m_cap == 256 and eng.dg.m == 192 + i + 1
            _assert_oracle_exact(eng, r, CHURN_QS)
        assert cl.compiles_since(snap) == 0             # the whole window

        # parity against unpadded execution on the churned graph: sentinel
        # padding must not change a single enumerated path
        exact = BatchPathEngine(eng.g, EngineConfig(min_cap=256))
        exact.dg = DeviceGraph.build(eng.g, pad=False)
        r_pad = eng.run(CHURN_QS)
        r_exact = exact.run(CHURN_QS)
        for qi in range(len(CHURN_QS)):
            assert path_set(r_pad[qi].paths) == path_set(r_exact[qi].paths)


class TestBucketCrossing:
    def test_crossing_retraces_at_most_cold_counts_then_warm(self):
        eng = _engine()
        cl = eng.compile_log
        eng.run(CHURN_QS)
        eng.apply_delta(_churn_delta(0))                # warm the delta path
        eng.run(CHURN_QS)

        # crossing delta: cold-region inserts pushing m past the 256 bucket
        adds = [(u, (u + d) % N) for d in (5, 6, 7) for u in range(20, 45)]
        # cumulative per-kernel history: one compile per (kernel, shape)
        # ever used — jit caches (and the recorder) are process-global, so
        # this is the tightest sound "once per kernel per shape" bound
        warm_snap = cl.snapshot()
        rep = eng.apply_delta(GraphDelta.from_pairs(add=adds))
        assert eng.dg.m_cap == 512                      # next pow2 bucket
        r = eng.run(CHURN_QS)
        _assert_oracle_exact(eng, r, CHURN_QS)
        crossed = cl.since(warm_snap)
        assert crossed, "bucket crossing must retrace the edge kernels"
        assert "msbfs_dist" in crossed                  # the (m,) consumers
        for kernel, count in crossed.items():
            assert count <= warm_snap.get(kernel, 0), (
                f"{kernel}: crossing compiled {count}x vs "
                f"{warm_snap.get(kernel, 0)}x before — more than once per "
                f"shape it uses")

        # one warm-up round after the crossing (the incremental ELL scatter
        # meets the rebuilt, larger ELL cap here for the first time) ...
        eng.apply_delta(GraphDelta.from_pairs(add=[(30, 46)]))
        eng.run(CHURN_QS)
        # ... and the loop is fully warm again inside the new bucket
        rep = eng.apply_delta(GraphDelta.from_pairs(add=[(31, 47)]))
        assert rep["n_compiles"] == 0, rep["compiled_kernels"]
        r = eng.run(CHURN_QS)
        assert r.stats["n_compiles"] == 0, r.stats["compiled_kernels"]
        _assert_oracle_exact(eng, r, CHURN_QS)


class TestRecorder:
    def test_snapshot_diff_and_retrace_accounting(self):
        from repro.core import compilelog
        cl = compilelog.enable()
        assert compilelog.active() is cl
        snap = {"a": 2, "b": 1}
        cl.counts.update({"a": 3, "b": 1, "c": 2})
        # since(): positive diffs only; retraces: only already-known names
        before = dict(cl.counts)
        diff = {k: v - snap.get(k, 0)
                for k, v in before.items() if v - snap.get(k, 0) > 0}
        assert cl.since(snap) == diff
        assert cl.retraces_since(snap) == diff.get("a", 0)
        stats = cl.annotate({}, snap)
        assert stats["n_compiles"] == sum(diff.values())
        assert stats["n_retraces"] == diff.get("a", 0)
