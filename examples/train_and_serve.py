"""End-to-end framework driver (deliverable b): fault-tolerant training of a
reduced LM with checkpoint/restart, then batched query serving with the
work-stealing scheduler.

    PYTHONPATH=src python examples/train_and_serve.py
"""
import sys, tempfile
sys.path.insert(0, "src")

from repro.launch.train import run_training
from repro.launch.serve import serve_batch
from repro.core import BatchPathEngine, EngineConfig, generators

# --- 1. train a reduced granite-8b for a few hundred steps, with a crash
with tempfile.TemporaryDirectory() as ckpt:
    print("== training (with injected failure at step 60 + auto-resume) ==")
    try:
        run_training("granite-8b", "train_4k", steps=120, ckpt_dir=ckpt,
                     reduced=True, overrides={"seq_len": 64, "global_batch": 8},
                     fail_at=60, ckpt_every=25)
    except RuntimeError as e:
        print(f"  crash: {e} -> restarting from latest checkpoint")
    out = run_training("granite-8b", "train_4k", steps=120, ckpt_dir=ckpt,
                       reduced=True,
                       overrides={"seq_len": 64, "global_batch": 8},
                       ckpt_every=25)
    h = out["history"]
    print(f"  resumed at step {h[0]['step']}; "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")

# --- 2. serve a batch of path queries on a graph
print("== serving ==")
g = generators.community(10_000, n_comm=4, avg_deg=6.0, seed=0)
engine = BatchPathEngine(g, EngineConfig())
queries = generators.similar_queries(g, 32, similarity=0.6, k_range=(4, 5),
                                     seed=1)
results, info = serve_batch(engine, queries, n_groups=2)
print(f"  {len(queries)} queries -> "
      f"{sum(r.shape[0] for r in results.values())} paths "
      f"in {info['wall_s']:.2f}s; {info['steals']} steals")
