"""Production meshes. Functions only — importing never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_by_name",
           "use_mesh"]


def _axis_types_kw(n_axes: int) -> dict:
    # jax >= 0.6 wants explicit axis types; older jax has no such kwarg
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def use_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh:
    ``jax.set_mesh`` on modern jax, the Mesh context manager on older."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices (CPU tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kw(2))


def mesh_by_name(name: str):
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "host":
        return make_host_mesh()
    raise KeyError(name)
