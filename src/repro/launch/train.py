"""Training driver CLI: any assigned arch, fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --reduced --steps 200 --ckpt-dir /tmp/ckpt

On this CPU host use --reduced (same-family small config); on a TPU pod the
full CONFIG lowers through the identical code path with --mesh pod.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs as config_registry
from ..config import RunOptions
from ..ft import DriverConfig, FailureInjector, TrainDriver
from ..models import gnn, recsys, transformer
from ..models.sharding import Rules
from ..optim import adamw_init
from .mesh import mesh_by_name, use_mesh
from .steps import build_bundle, _gnn_dims

__all__ = ["run_training"]


def make_init_and_batches(arch: str, bundle, cfg, shape, over, opts):
    mod = config_registry.get(arch)
    if mod.FAMILY == "lm":
        from ..data.lm_data import TokenStream
        dims = dict(shape.dims, **(over or {}))
        stream = TokenStream(cfg.vocab, dims["global_batch"],
                             dims["seq_len"], seed=opts.seed)

        def init_state():
            p = transformer.init_lm_params(jax.random.PRNGKey(opts.seed), cfg)
            return p, adamw_init(p)

        def batch_fn(step):
            tok, tgt = stream.batch_at(step)
            return jnp.asarray(tok), jnp.asarray(tgt)

        return init_state, batch_fn
    if mod.FAMILY == "gnn":
        from ..core import generators
        from ..data import gnn_data
        d_in, d_out = _gnn_dims(cfg, shape)
        sdims = dict(shape.dims, **(over or {}))
        g = generators.powerlaw(sdims.get("n_nodes", 2000), 4.0, seed=opts.seed)

        def init_state():
            p = gnn.init_gnn_params(jax.random.PRNGKey(opts.seed), cfg,
                                    d_in=d_in, d_out=d_out)
            return p, adamw_init(p)

        abstract_batch = bundle.abstract_inputs[2]

        def batch_fn(step):
            if shape.kind == "gnn_mol":
                b = gnn_data.molecule_batch(cfg, sdims["batch"],
                                            sdims["n_nodes"], sdims["n_edges"],
                                            d_in, d_out, seed=step)
            elif shape.kind == "gnn_mini":
                roots = np.random.default_rng(step).integers(
                    0, g.n, sdims["batch_nodes"])
                b = gnn_data.sampled_batch(
                    cfg, g, roots, sdims["fanout"], d_in, d_out, seed=step,
                    n_pad=abstract_batch["nodes"].shape[0],
                    e_pad=abstract_batch["edge_src"].shape[0])
            else:
                b = gnn_data.flat_batch(cfg, shape, g, d_in, d_out, seed=step,
                                        n_pad=abstract_batch["nodes"].shape[0],
                                        e_pad=abstract_batch["edge_src"].shape[0])
            return (jax.tree.map(jnp.asarray, b),)

        return init_state, batch_fn
    # recsys
    from ..data.recsys_data import InteractionStream
    sdims = dict(shape.dims, **(over or {}))
    stream = InteractionStream(cfg, sdims["batch"], seed=opts.seed)

    def init_state():
        p = recsys.init_recsys_params(jax.random.PRNGKey(opts.seed), cfg)
        return p, adamw_init(p)

    def batch_fn(step):
        return (jax.tree.map(jnp.asarray, stream.batch_at(step)),)

    return init_state, batch_fn


def run_training(arch: str, shape_name: str, steps: int, ckpt_dir: str,
                 reduced: bool = True, mesh_name: str = "host",
                 overrides: dict | None = None, fail_at: int | None = None,
                 ckpt_every: int = 50, opts: RunOptions | None = None):
    mesh = mesh_by_name(mesh_name)
    rules = Rules(mesh)
    opts = opts or RunOptions(seq_parallel=(mesh_name != "host"),
                              loss_chunk=64, attn_chunk=256, moe_groups=4)
    bundle = build_bundle(arch, shape_name, rules, opts, reduced=reduced,
                          overrides=overrides)
    mod = config_registry.get(arch)
    cfg = mod.REDUCED if reduced else mod.CONFIG
    from ..config import ShapeSpec
    shape = mod.SHAPES[shape_name]
    if overrides:
        shape = ShapeSpec(shape.name, shape.kind,
                          tuple(dict(dict(shape.dims), **overrides).items()))
    init_state, batch_fn = make_init_and_batches(arch, bundle, cfg, shape,
                                                 overrides, opts)
    step_fn = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings)
    driver = TrainDriver(
        DriverConfig(total_steps=steps, ckpt_dir=ckpt_dir,
                     ckpt_every=ckpt_every),
        lambda p, o, *b: step_fn(p, o, *b),
        init_state, batch_fn, injector=FailureInjector(fail_at))
    with use_mesh(mesh):
        return driver.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    mod = config_registry.get(args.arch)
    shape = args.shape or list(mod.SHAPES)[0]
    over = None
    if mod.FAMILY == "lm" and args.reduced:
        over = {"seq_len": args.seq_len, "global_batch": args.batch}
    elif mod.FAMILY == "recsys" and args.reduced:
        over = {"batch": max(args.batch, 8)}  # full shape is 65k; CPU-size it
    elif mod.FAMILY == "gnn" and args.reduced and shape == "minibatch_lg":
        over = {"n_nodes": 2000, "batch_nodes": 16, "fanout": (4, 3),
                "d_feat": 16}
    out = run_training(args.arch, shape, args.steps, args.ckpt_dir,
                       reduced=args.reduced, mesh_name=args.mesh,
                       overrides=over, fail_at=args.fail_at)
    hist = out["history"]
    print(f"steps: {len(hist)}; loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}; stragglers: {out['stragglers']}")


if __name__ == "__main__":
    main()
