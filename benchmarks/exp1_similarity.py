"""Exp-1 (Fig 7): processing time & speedup vs query similarity.

Paper claims reproduced: (1) at low similarity BatchEnum ~= BasicEnum (low
sharing overhead); (2) speedup grows with similarity, bounded by the ideal
limit 1/(1-mu_Q); (3) BasicEnum+ >= BasicEnum.
"""
from __future__ import annotations

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, measured_similarity, record, time_planner


def main(scale: float = 1.0) -> list[dict]:
    g = default_graph(scale)
    eng = BatchPathEngine(g, EngineConfig(min_cap=128))
    rows = []
    for sim in [0.0, 0.3, 0.6, 0.9]:
        qs = generators.similar_queries(g, 24, similarity=sim,
                                        k_range=(5, 5), seed=int(sim * 10))
        mu = measured_similarity(eng, qs)
        t_basic, _ = time_planner(eng, qs, "basic")
        t_basicp, _ = time_planner(eng, qs, "basic+")
        t_batch, sb = time_planner(eng, qs, "batch")
        t_batchp, _ = time_planner(eng, qs, "batch+")
        speedup = t_basic / t_batch
        limit = 1.0 / max(1.0 - mu, 1e-9)
        rows.append(dict(similarity=sim, mu=mu, t_basic=t_basic,
                         t_basic_plus=t_basicp, t_batch=t_batch,
                         t_batch_plus=t_batchp, speedup=speedup, limit=limit,
                         n_shared=sb.get("n_shared", 0)))
        record(f"exp1_sim{sim:.1f}_basic", t_basic * 1e6,
               f"mu={mu:.3f}")
        record(f"exp1_sim{sim:.1f}_batch", t_batch * 1e6,
               f"speedup={speedup:.2f};limit={limit:.2f};"
               f"n_shared={sb.get('n_shared', 0)}")
    return rows


if __name__ == "__main__":
    main()
