"""Static-shape path buffers (``PathSet``) and compaction utilities.

A PathSet stores up to ``cap`` paths as a dense int32 matrix. The first
``count`` rows are valid and packed at the front; unused cells are -1. All
sizes are static so every consumer is jit-compilable; data-dependent sizes
surface as (count, overflow) pairs that the host driver inspects.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PathSet", "HostPathSet", "empty", "singleton", "compact_rows",
           "concat", "to_host", "offload", "upload", "pathset_nbytes"]

# per-PathSet bookkeeping charged on top of the vertex matrix (count +
# overflow scalars); shared by HostPathSet.nbytes and the cache's
# pre-transfer size estimate so the two can never diverge
PATHSET_BOOKKEEPING_BYTES = 16


def pathset_nbytes(cap: int, width: int, itemsize: int = 4) -> int:
    """Bytes one (cap, width) path buffer accounts for — the *single*
    byte-math used both for ``HostPathSet.nbytes`` (LRU budget accounting)
    and for size estimates taken from device shapes before any transfer."""
    return int(cap) * int(width) * int(itemsize) + PATHSET_BOOKKEEPING_BYTES


class PathSet(NamedTuple):
    verts: jax.Array    # (cap, L) int32, row i cols 0..length_i are vertices
    count: jax.Array    # () int32 -- number of valid (packed) rows
    overflow: jax.Array  # () bool -- True if rows were dropped to fit cap

    @property
    def cap(self) -> int:
        return self.verts.shape[0]

    @property
    def width(self) -> int:
        return self.verts.shape[1]


def empty(cap: int, width: int) -> PathSet:
    return PathSet(verts=jnp.full((cap, width), -1, jnp.int32),
                   count=jnp.int32(0), overflow=jnp.bool_(False))


def singleton(vertex, width: int) -> PathSet:
    """PathSet holding the single length-0 path [vertex]."""
    verts = jnp.full((1, width), -1, jnp.int32).at[0, 0].set(vertex)
    return PathSet(verts=verts, count=jnp.int32(1), overflow=jnp.bool_(False))


def compact_rows(mask: jax.Array, payload: jax.Array, out_cap: int,
                 fill: int = -1) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Scatter payload rows where mask is True into a packed (out_cap, ...) buffer.

    mask: (N,) bool; payload: (N, ...) -- returns (out, count, overflow).
    Rows beyond out_cap are dropped (overflow=True).
    """
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    total = jnp.where(mask.shape[0] > 0, pos[-1] + 1, 0).astype(jnp.int32)
    dest = jnp.where(mask & (pos < out_cap), pos, out_cap)
    out = jnp.full((out_cap + 1,) + payload.shape[1:], fill, payload.dtype)
    out = out.at[dest].set(payload)
    return out[:out_cap], jnp.minimum(total, out_cap), total > out_cap


@jax.jit
def _concat2(a_verts, a_count, b_verts, b_count):
    cap = a_verts.shape[0] + b_verts.shape[0]
    width = a_verts.shape[1]
    out = jnp.full((cap, width), -1, jnp.int32)
    out = jax.lax.dynamic_update_slice(out, a_verts, (0, 0))
    # mask invalid rows of b before placing at offset a_count
    bmask = jnp.arange(b_verts.shape[0])[:, None] < b_count
    b = jnp.where(bmask, b_verts, -1)
    shifted = jnp.full((cap, width), -1, jnp.int32)
    shifted = jax.lax.dynamic_update_slice(shifted, b, (a_count, 0))
    out = jnp.where(jnp.arange(cap)[:, None] < a_count, out, shifted)
    return out, a_count + b_count


def concat(sets: list[PathSet]) -> PathSet:
    """Concatenate packed PathSets (same width) into one packed PathSet."""
    sets = [s for s in sets if s is not None]
    if not sets:
        raise ValueError("concat of no PathSets")
    if len(sets) == 1:
        return sets[0]
    acc = sets[0]
    ov = sets[0].overflow
    for s in sets[1:]:
        verts, count = _concat2(acc.verts, acc.count, s.verts, s.count)
        ov = ov | s.overflow
        acc = PathSet(verts=verts, count=count, overflow=ov)
    return acc


def to_host(ps: PathSet) -> np.ndarray:
    """Valid rows as a host numpy array (n, L)."""
    n = int(ps.count)
    return np.asarray(ps.verts[:n])


class HostPathSet(NamedTuple):
    """Host-pinned copy of a PathSet (the cross-batch cache's storage form).

    The full padded buffer is kept (not just the valid rows) so a device
    re-upload restores the exact capacity bucket and stays within the same
    jit shape cache as the original materialization.
    """

    verts: np.ndarray   # (cap, L) int32
    count: int
    overflow: bool

    @property
    def nbytes(self) -> int:
        return pathset_nbytes(self.verts.shape[0], self.verts.shape[1],
                              self.verts.itemsize)

    @property
    def cap(self) -> int:
        return self.verts.shape[0]


def offload(ps: PathSet) -> HostPathSet:
    """Device -> host copy preserving capacity, count and overflow."""
    return HostPathSet(verts=np.asarray(ps.verts), count=int(ps.count),
                       overflow=bool(ps.overflow))


def upload(hps: HostPathSet) -> PathSet:
    """Host -> device round-trip inverse of :func:`offload`."""
    return PathSet(verts=jnp.asarray(hps.verts), count=jnp.int32(hps.count),
                   overflow=jnp.bool_(hps.overflow))
