"""Two-tower retrieval (YouTube RecSys'19): sampled-softmax retrieval.

EmbeddingBag built from first principles (JAX has no nn.EmbeddingBag):
``jnp.take`` over the (row-sharded) table + masked mean over the bag —
padding ids are -1. In-batch sampled softmax with logQ correction. Serve
paths: pointwise scoring (p99/bulk) and 1-vs-1M candidate retrieval with
sharded top-k.

Sharding: embedding tables row-sharded over every mesh axis ("cells");
batch over "batch"; the 1M-candidate matrix over "cells" with a local
top-k -> global top-k combine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..config import RecsysConfig

__all__ = ["init_recsys_params", "recsys_param_logical", "embedding_bag",
           "user_tower", "item_tower", "recsys_loss", "score_candidates",
           "retrieve_topk"]


def _mlp_init(rng, dims):
    keys = jax.random.split(rng, len(dims))
    return {"w": [jax.random.normal(keys[i], (dims[i], dims[i + 1]), jnp.float32)
                  / np.sqrt(dims[i]) for i in range(len(dims) - 1)],
            "b": [jnp.zeros((dims[i + 1],), jnp.float32)
                  for i in range(len(dims) - 1)]}


def _mlp(p, x):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_recsys_params(rng, cfg: RecsysConfig) -> dict:
    k = jax.random.split(rng, 4)
    dim = cfg.embed_dim
    mlp_dims = (dim,) + tuple(cfg.tower_mlp)
    return {
        "user_table": jax.random.normal(k[0], (cfg.n_users, dim), jnp.float32) * 0.02,
        "item_table": jax.random.normal(k[1], (cfg.n_items, dim), jnp.float32) * 0.02,
        "user_mlp": _mlp_init(k[2], mlp_dims),
        "item_mlp": _mlp_init(k[3], mlp_dims),
    }


def recsys_param_logical(params) -> dict:
    def of(path_leaf):
        return path_leaf
    return {
        "user_table": ("cells", None),
        "item_table": ("cells", None),
        "user_mlp": jax.tree.map(lambda p: tuple(None for _ in p.shape),
                                 params["user_mlp"]),
        "item_mlp": jax.tree.map(lambda p: tuple(None for _ in p.shape),
                                 params["item_mlp"]),
    }


def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "mean"):
    """ids: (..., H) int32 with -1 padding -> (..., dim)."""
    valid = ids >= 0
    emb = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    emb = emb * valid[..., None]
    s = emb.sum(axis=-2)
    if mode == "sum":
        return s
    return s / jnp.maximum(valid.sum(axis=-1, keepdims=True), 1.0)


def user_tower(params, hist_ids):
    """hist_ids: (B, H) item-interaction history (bag)."""
    bag = embedding_bag(params["user_table"], hist_ids)
    u = _mlp(params["user_mlp"], bag)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_tower(params, item_ids):
    emb = jnp.take(params["item_table"], item_ids, axis=0)
    v = _mlp(params["item_mlp"], emb)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def recsys_loss(params, batch, cfg: RecsysConfig, constrain=None,
                temperature: float = 0.05):
    """In-batch sampled softmax with logQ correction."""
    u = user_tower(params, batch["hist_ids"])          # (B, d)
    v = item_tower(params, batch["item_ids"])          # (B, d)
    if constrain is not None:
        u = constrain(u, ("batch", None))
        v = constrain(v, ("batch", None))
    logits = (u @ v.T) / temperature                   # (B, B)
    logq = batch.get("sampling_logq")
    if logq is not None:                               # logQ correction
        logits = logits - logq[None, :]
    if constrain is not None:
        logits = constrain(logits, ("batch", None))
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def score_candidates(params, hist_ids, item_ids):
    """Pointwise serve: score (B,) pairs."""
    u = user_tower(params, hist_ids)
    v = item_tower(params, item_ids)
    return jnp.sum(u * v, axis=-1)


def retrieve_topk(params, hist_ids, cand_ids, k: int = 100, constrain=None):
    """1 query vs n_candidates: batched dot + top-k (sharded candidates)."""
    u = user_tower(params, hist_ids)                   # (1, d)
    v = item_tower(params, cand_ids)                   # (Nc, d)
    if constrain is not None:
        v = constrain(v, ("cells", None))
    scores = (v @ u[0]).astype(jnp.float32)            # (Nc,)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, cand_ids[idx]
