import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, capture memory_analysis / cost_analysis / collective
census for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); smoke tests and benchmarks never import this
module, so they see the real single CPU device.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from .mesh import mesh_by_name, use_mesh
from .steps import build_bundle
from .hlo_analysis import analyze_hlo
from ..config import RunOptions
from ..models.sharding import Rules
from .. import configs as config_registry

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")


# per-cell launch options (memory plans; justified in EXPERIMENTS.md §Dry-run)
CELL_OPTS: dict[tuple, dict] = {
    ("qwen1.5-110b", "train_4k"): {"grad_accum": 4},
    ("qwen2.5-14b", "train_4k"): {"grad_accum": 2},
    ("moonshot-v1-16b-a3b", "train_4k"): {"grad_accum": 2},
    ("olmoe-1b-7b", "train_4k"): {"grad_accum": 2},
}


def dryrun_cell(arch: str, shape: str, mesh_name: str,
                opts: RunOptions | None = None) -> dict:
    """Lower + compile one cell; returns the roofline record."""
    if opts is None:
        import dataclasses as _dc
        opts = RunOptions(**CELL_OPTS.get((arch, shape), {}))
    mesh = mesh_by_name(mesh_name)
    rules = Rules(mesh)
    t0 = time.perf_counter()
    bundle = build_bundle(arch, shape, rules, opts)
    jitted = jax.jit(bundle.step_fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    with use_mesh(mesh):
        lowered = jitted.lower(*bundle.abstract_inputs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    census = collective_census(hlo)
    hlo_est = analyze_hlo(hlo)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "n_devices": mesh.devices.size,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes,
        },
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")},
        "collectives": census,
        "hlo_flops_est": hlo_est["flops_per_device"],
        "collective_bytes_est": hlo_est["collective_bytes_per_device"],
        "collective_by_kind": hlo_est["collective_by_kind"],
        "meta": bundle.meta,
        "ok": True,
    }
    return rec


DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1}
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                      r"\[([0-9,]*)\]")


def _first_shape_bytes(line: str) -> int:
    """Bytes of the op's result shape(s) on an HLO text line (lhs of =)."""
    lhs = line.split("=")[0] if "=" in line else line
    total = 0
    for m in SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_census(hlo: str) -> dict:
    """Per-collective op counts and result bytes, split by computation so
    while-body (scan) collectives can be trip-count-adjusted downstream."""
    comps: dict[str, dict] = {}
    cur = "_entry"
    trip_re = re.compile(r"trip_count=(\d+)")
    known_trips: dict[str, int] = {}
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ENTRY"):
            if "{" in ls and ("(" in ls):
                name = ls.split()[0].lstrip("%")
                cur = name
        m = COLLECTIVE_RE.search(ls)
        if m and "=" in ls and not ls.startswith("ROOT tuple"):
            kind = m.group(1)
            if "-done" in ls and "-start" not in ls.split("=")[1][:40]:
                continue  # count the -start only
            by = comps.setdefault(cur, {})
            ent = by.setdefault(kind, {"count": 0, "bytes": 0})
            ent["count"] += 1
            ent["bytes"] += _first_shape_bytes(ls)
        tm = trip_re.search(ls)
        if tm and "while" in ls:
            known_trips[cur] = int(tm.group(1))
    return {"per_computation": comps, "trip_counts": known_trips}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--print-hlo", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in config_registry.ARCHS:
            for shape in config_registry.shapes_for(arch):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_fail = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = outdir / f"{tag}.json"
            try:
                rec = dryrun_cell(arch, shape, mesh_name)
                per_dev_gb = rec["memory"]["peak_device_bytes"] / 2**30
                print(f"[OK]   {tag}: compile {rec['t_compile_s']}s, "
                      f"peak/device {per_dev_gb:.2f} GiB, "
                      f"flops/device {rec['cost_analysis']['flops']:.3g}")
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                n_fail += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"\n{len(cells) * len(meshes) - n_fail}/{len(cells) * len(meshes)} "
          f"cells compiled")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
