"""repro.obs: hierarchical span tracer, metrics registry, Chrome-trace
export, engine/serving integration, and the zero-overhead contracts —
tracing off must not change results or warm retraces, and the t_* stats
must stay derived views over spans either way."""
import json
import threading

import numpy as np
import pytest

from repro.core import BatchPathEngine, EngineConfig, PathSession
from repro.core.graph import Graph
from repro.core.oracle import path_set
from repro.obs import metrics as obsmetrics
from repro.obs import trace as obstrace

OFFSETS = (1, 2, 3)
# NOT 64: test_recompile.py uses the same circulant harness at n=64 and
# asserts its cold start compiles > 0 — the jit cache is process-global,
# so this suite (alphabetically earlier) must warm different shapes
N = 48


def circulant(n=N, offsets=OFFSETS) -> Graph:
    """Vertex-transitive graph (same harness as test_recompile): any
    compile observed in a warm window is a genuine leak, not workload
    noise."""
    src = np.repeat(np.arange(n, dtype=np.int64), len(offsets))
    dst = (src + np.tile(np.array(offsets, np.int64), n)) % n
    return Graph.from_edges(n, src, dst)


QS = [(0, 3, 3), (8, 11, 3), (16, 19, 3)]


@pytest.fixture(autouse=True)
def _clean_tracer():
    """The tracer is a process singleton — leave it disabled and empty so
    obs tests cannot leak recording into unrelated suites."""
    tr = obstrace.tracer()
    was = tr.enabled
    yield
    tr.enabled = was
    obstrace.disable()
    tr.reset()


# ----------------------------------------------------------------------
# trace.py unit behavior
# ----------------------------------------------------------------------
class TestSpans:
    def test_nesting_depth_and_order(self):
        tr = obstrace.Tracer(enabled=True)
        with tr.span("outer") as so:
            with tr.span("inner", level=1) as si:
                pass
        spans = tr.spans()
        # inner finishes (and records) first; depths reflect the stack
        assert [s.name for s in spans] == ["inner", "outer"]
        assert si.depth == 1 and so.depth == 0
        assert si.tid == so.tid == threading.get_ident()
        assert 0 <= si.duration <= so.duration

    def test_exception_safety_records_and_unwinds(self):
        tr = obstrace.Tracer(enabled=True)
        with pytest.raises(ValueError):
            with tr.span("outer"):
                with tr.span("boom"):
                    raise ValueError("x")
        # both spans recorded, error tagged, stack fully unwound
        by_name = {s.name: s for s in tr.spans()}
        assert by_name["boom"].attrs["error"] == "ValueError"
        assert by_name["outer"].attrs["error"] == "ValueError"
        assert tr._stack() == []
        with tr.span("after") as sp:
            pass
        assert sp.depth == 0

    def test_disabled_tracer_still_times(self):
        tr = obstrace.Tracer(enabled=False)
        with tr.span("stage") as sp:
            sum(range(1000))
        assert sp.duration > 0.0          # t_* stats work untraced
        assert len(tr) == 0               # ...but nothing is recorded

    def test_set_and_elapsed(self):
        tr = obstrace.Tracer(enabled=True)
        with tr.span("s", a=1) as sp:
            assert sp.elapsed >= 0.0
            sp.set(hit=True)
        assert sp.attrs == {"a": 1, "hit": True}

    def test_ring_buffer_bounded(self):
        tr = obstrace.Tracer(enabled=True, capacity=4)
        for i in range(10):
            with tr.span(f"s{i}"):
                pass
        assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]

    def test_thread_local_stacks_give_thread_roots(self):
        tr = obstrace.Tracer(enabled=True)
        done = threading.Event()

        def worker():
            with tr.span("worker.root"):
                pass
            done.set()

        with tr.span("main.root"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert done.is_set()
        by_name = {s.name: s for s in tr.spans()}
        # the worker's span is a root on its own thread, not a child
        assert by_name["worker.root"].depth == 0
        assert by_name["worker.root"].tid != by_name["main.root"].tid

    def test_chrome_trace_round_trip(self, tmp_path):
        tr = obstrace.Tracer(enabled=True)
        with tr.span("engine.run", n_queries=3):
            with tr.span("msbfs.level", level=0):
                pass
            with tr.span("join.keyed", lam=2):
                pass
        path = tmp_path / "trace.json"
        doc = tr.export(path)
        loaded = obstrace.load(path)
        assert loaded == json.loads(json.dumps(doc))
        assert loaded["displayTimeUnit"] == "ms"
        assert obstrace.stage_names(loaded) == \
            {"engine.run", "msbfs.level", "join.keyed"}
        ev = {e["name"]: e for e in loaded["traceEvents"]
              if e.get("ph") == "X"}
        assert ev["engine.run"]["args"] == {"n_queries": 3, "depth": 0}
        assert ev["msbfs.level"]["args"]["depth"] == 1
        assert ev["msbfs.level"]["ts"] >= ev["engine.run"]["ts"]
        # metadata thread_name event present
        assert any(e.get("ph") == "M" for e in loaded["traceEvents"])

    def test_summarize_and_coverage(self):
        tr = obstrace.Tracer(enabled=True)
        with tr.span("engine.run"):
            for lv in range(3):
                with tr.span("msbfs.level", level=lv):
                    sum(range(20000))
        doc = tr.to_chrome()
        rows = {r["name"]: r for r in obstrace.summarize(doc)}
        assert rows["msbfs.level"]["count"] == 3
        assert rows["engine.run"]["total_ms"] >= \
            rows["msbfs.level"]["total_ms"] * 0.9
        cov = obstrace.coverage(doc, root="engine.run")
        assert 0.5 <= cov <= 1.0

    def test_singleton_enable_disable(self):
        tr = obstrace.enable()
        assert tr is obstrace.tracer() and tr.enabled
        with obstrace.span("via.module"):
            pass
        assert "via.module" in {s.name for s in tr.spans()}
        obstrace.disable()
        n = len(tr)
        with obstrace.span("dropped"):
            pass
        assert len(tr) == n


# ----------------------------------------------------------------------
# metrics.py unit behavior
# ----------------------------------------------------------------------
class TestMetrics:
    def test_counter_gauge_get_or_create(self):
        reg = obsmetrics.MetricsRegistry()
        c = reg.counter("hits", cache="0")
        c.inc()
        c.inc(2.0)
        assert reg.counter("hits", cache="0") is c and c.value == 3.0
        assert reg.counter("hits", cache="1") is not c
        g = reg.gauge("bytes")
        g.set(10)
        g.dec(4)
        assert g.value == 6.0

    def test_histogram_quantiles_match_numpy(self):
        # bucket width is ~19% relative — interpolated quantiles must land
        # within one bucket of the exact order statistic
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=-4.0, sigma=1.5, size=5000)
        h = obsmetrics.Histogram()
        for x in samples:
            h.record(float(x))
        for q in (0.5, 0.95, 0.99):
            exact = float(np.percentile(samples, q * 100))
            got = h.quantile(q)
            assert abs(got - exact) <= 0.25 * exact, (q, got, exact)
        assert h.count == 5000
        assert h.quantile(0.0) >= float(samples.min())
        assert h.quantile(1.0) <= float(samples.max())
        assert abs(h.mean - samples.mean()) < 1e-9 * samples.sum() + 1e-12

    def test_histogram_clamped_to_observed_range(self):
        h = obsmetrics.Histogram()
        h.record(0.010)
        h.record(0.012)
        for q in (0.5, 0.99):
            assert 0.010 <= h.quantile(q) <= 0.012

    def test_since_windows_isolate_samples(self):
        reg = obsmetrics.MetricsRegistry()
        h = reg.histogram("lat_s")
        h.record(1.0)
        snap = reg.snapshot()
        for _ in range(10):
            h.record(0.001)
        win = reg.since(snap)[("lat_s", ())]
        assert win.count == 10
        # the pre-snapshot 1.0s outlier must not contaminate the window
        assert win.quantile(0.99) < 0.01
        assert reg.since(reg.snapshot()) == {}   # empty window -> no entry

    def test_render_exposition(self):
        reg = obsmetrics.MetricsRegistry()
        reg.counter("cache_hits_total", cache="0").inc(5)
        reg.histogram("lat_s").record(0.5)
        text = reg.render()
        assert "# TYPE cache_hits_total counter" in text
        assert 'cache_hits_total{cache="0"} 5' in text
        assert "lat_s_count 1" in text
        assert 'quantile="0.99"' in text


# ----------------------------------------------------------------------
# engine / session integration
# ----------------------------------------------------------------------
def _engine(**cfg) -> BatchPathEngine:
    base = dict(min_cap=256, cache_bytes=8 << 20)
    base.update(cfg)
    return BatchPathEngine(circulant(), EngineConfig(**base))


class TestEngineIntegration:
    def test_traced_run_exports_full_taxonomy(self, tmp_path):
        eng = _engine(trace=True)
        eng.obs.reset()
        r = eng.run(QS)
        assert r.stats["t_wall_s"] > 0
        doc = eng.obs.export(tmp_path / "t.json")
        names = obstrace.stage_names(doc)
        # join.splice is absent here by design: it fires only when a
        # cluster splices shared-prefix children (the exp8 obs benchmark
        # pins the fuller taxonomy on a sharing-heavy workload)
        for stage in ("engine.run", "cluster.queries", "detect.cluster",
                      "cache.get", "index.build", "msbfs.level",
                      "enumerate.node", "enumerate.cluster",
                      "join.keyed", "assemble.query"):
            assert stage in names, stage
        assert obstrace.coverage(doc, root="engine.run") >= 0.9

    def test_stats_are_span_derived_views(self):
        # t_* keys exist traced AND untraced (always-on timing)
        for trace in (False, True):
            r = _engine(trace=trace).run(QS)
            for k in ("t_wall_s", "t_cluster", "t_detect",
                      "t_build_index", "t_enumerate"):
                assert k in r.stats and r.stats[k] >= 0.0, (trace, k)

    def test_tracing_off_is_bit_identical(self):
        r0 = _engine(trace=False).run(QS)
        r1 = _engine(trace=True).run(QS)
        for qi in range(len(QS)):
            assert path_set(r0[qi].paths) == path_set(r1[qi].paths)

    def test_traced_warm_batches_compile_nothing(self):
        # the recompile pin of test_recompile, with tracing ON: spans and
        # metrics must not introduce retraces or host-shape drift
        eng = _engine(trace=True, log_compiles=True)

        def batch(i):
            return [(8 * j + i, (8 * j + i + 3) % N, 3) for j in range(6)]

        eng.run(batch(0))
        for i in (1, 2):
            r = eng.run(batch(i))
            assert r.stats["n_compiles"] == 0, r.stats["compiled_kernels"]
            assert r.stats["n_retraces"] == 0

    def test_cache_metrics_isolated_per_engine_via_since(self):
        reg = obsmetrics.registry()
        e1 = _engine()
        snap = reg.snapshot()
        e1.run(QS)
        e1.run(QS)                         # warm: hits
        win1 = reg.since(snap)
        hits1 = sum(v for (name, labels), v in win1.items()
                    if name == "cache_hits_total")
        assert hits1 > 0
        # a second engine's traffic lands on different cache labels and
        # in a different window
        snap2 = reg.snapshot()
        e2 = _engine()
        e2.run(QS)
        win2 = reg.since(snap2)
        lbl1 = {labels for (name, labels), _ in win1.items()
                if name.startswith("cache_")}
        lbl2 = {labels for (name, labels), _ in win2.items()
                if name.startswith("cache_")}
        assert lbl1 and lbl2 and lbl1.isdisjoint(lbl2)

    def test_query_latency_histogram_recorded(self):
        reg = obsmetrics.registry()
        snap = reg.snapshot()
        _engine().run(QS)
        win = reg.since(snap)
        lat = [w for (name, labels), w in win.items()
               if name == "query_latency_s"]
        assert lat and lat[0].count >= len(QS)
        assert [w for (name, labels), w in win.items()
                if name == "engine_batch_wall_s"]

    def test_session_trace_kwarg_and_tracer_property(self, tmp_path):
        sess = PathSession(circulant(), trace=True)
        assert sess.tracer is obstrace.tracer()
        sess.tracer.reset()
        sess.run(QS)
        doc = sess.tracer.export(tmp_path / "s.json")
        assert "engine.run" in obstrace.stage_names(doc)
        # trace=None defers to config default (off)
        sess2 = PathSession(circulant())
        assert sess2.engine.cfg.trace is False

    def test_apply_delta_span_and_stats(self):
        from repro.core import GraphDelta
        eng = _engine(trace=True)
        eng.run(QS)
        eng.obs.reset()
        rep = eng.apply_delta(GraphDelta.from_pairs(add=[(20, 27)]))
        assert rep["t_apply_s"] > 0
        assert "engine.apply_delta" in {s.name for s in eng.obs.spans()}


# ----------------------------------------------------------------------
# serving integration (incl. the serve_batch aliasing fix)
# ----------------------------------------------------------------------
class TestServing:
    def test_serve_batch_info_is_mutation_safe(self):
        # regression: serve_batch returned a shallow copy whose nested
        # dicts (cache info, per-device stats) later batches kept mutating
        from repro.launch.serve import serve_batch
        eng = _engine()
        results, info = serve_batch(eng, QS)
        assert set(results) == {0, 1, 2}
        frozen = json.loads(json.dumps(info, default=str))
        # run more traffic through the same engine/cache, then mutate the
        # live cache info dict the old shallow copy would have aliased
        serve_batch(eng, [(1, 4, 3), (9, 12, 3)])
        if eng.cache is not None:
            eng.cache.info()["entries"] = -1
        assert json.loads(json.dumps(info, default=str)) == frozen

    def test_streaming_batch_log_latency_fields(self):
        sess = PathSession(circulant())
        for q in QS:
            sess.submit(q)
        res = sess.results()
        assert len(res) == len(QS)
        entry = sess.batch_log[-1]
        for k in ("t_assemble_s", "admission_wait_p50_s",
                  "admission_wait_max_s", "e2e_p50_s", "e2e_p99_s"):
            assert k in entry and entry[k] >= 0.0, k
        assert entry["e2e_p99_s"] >= entry["e2e_p50_s"]
        # admission wait + e2e histograms landed in the process registry
        reg = obsmetrics.registry()
        assert reg.histogram("serve_query_e2e_s").count >= len(QS)
        assert reg.histogram("serve_admission_wait_s").count >= len(QS)

    def test_traced_streaming_has_serve_spans(self):
        sess = PathSession(circulant(), trace=True)
        sess.tracer.reset()
        for q in QS:
            sess.submit(q)
        sess.results()
        names = {s.name for s in sess.tracer.spans()}
        assert {"serve.batch", "serve.assemble", "engine.run"} <= names


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------
class TestCli:
    def _write_trace(self, tmp_path):
        tr = obstrace.Tracer(enabled=True)
        with tr.span("engine.run"):
            with tr.span("msbfs.level", level=0):
                pass
        p = tmp_path / "t.json"
        tr.export(p)
        return p

    def test_summarize_command(self, tmp_path, capsys):
        from repro.obs.__main__ import main
        p = self._write_trace(tmp_path)
        assert main(["summarize", str(p)]) == 0
        out = capsys.readouterr().out
        assert "msbfs.level" in out and "coverage" in out

    def test_export_filter(self, tmp_path):
        from repro.obs.__main__ import main
        p = self._write_trace(tmp_path)
        out = tmp_path / "f.json"
        assert main(["export", str(p), "-o", str(out),
                     "--filter", "msbfs."]) == 0
        doc = obstrace.load(out)
        assert obstrace.stage_names(doc) == {"msbfs.level"}

    def test_summarize_empty_trace_fails(self, tmp_path):
        from repro.obs.__main__ import main
        p = tmp_path / "empty.json"
        p.write_text('{"traceEvents": []}')
        assert main(["summarize", str(p)]) == 1
