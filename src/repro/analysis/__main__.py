"""CLI entry point: ``python -m repro.analysis``.

Modes (default ``--all``):
  --lint            layer 1 only (AST lint, no JAX import needed)
  --audit           layer 2 only (jaxpr trace audit)
  --all             both layers, one merged report
  --write-budgets   measure the manifest and rewrite the committed
                    DISPATCH_BUDGETS.json baseline (then exits 0)

Exit status: 0 iff no unwaived finding.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--all", action="store_true",
                      help="run both layers (default)")
    mode.add_argument("--lint", action="store_true",
                      help="layer 1 AST lint only")
    mode.add_argument("--audit", action="store_true",
                      help="layer 2 jaxpr audit only")
    mode.add_argument("--write-budgets", action="store_true",
                      help="measure and rewrite the dispatch-budget baseline")
    ap.add_argument("--root", type=Path, default=None,
                    help="source tree to lint (default: the repro package)")
    ap.add_argument("--budgets", type=Path, default=None,
                    help="DISPATCH_BUDGETS.json path (default: "
                         "benchmarks/baselines/DISPATCH_BUDGETS.json)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)

    if args.write_budgets:
        from .jaxpr_audit import DEFAULT_BUDGETS_PATH, measure_budgets
        path = args.budgets or DEFAULT_BUDGETS_PATH
        budgets = {
            "_comment": "Committed per-backend dispatch budgets for the "
                        "hot-function manifest (jaxpr eqns; a pallas_call "
                        "counts as one eqn). Regenerate with `python -m "
                        "repro.analysis --write-budgets` and justify any "
                        "increase in the PR.",
        }
        budgets.update(measure_budgets())
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(budgets, indent=1, sort_keys=True) + "\n")
        print(f"wrote {path}")
        return 0

    if args.lint:
        from .astlint import lint_tree
        root = args.root or Path(__file__).resolve().parents[1]
        report = lint_tree(root)
    elif args.audit:
        from .jaxpr_audit import run_audit
        report = run_audit(args.budgets)
    else:
        from . import run_all
        report = run_all(args.root, args.budgets)

    print(report.to_json() if args.json else report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
