"""Hierarchical runtime span tracer (zero-dependency layer of ``repro.obs``).

The engine's batch-sharing claims are per-stage claims — detection,
clustering, cache hits, per-level MS-BFS, joins, assembly each get
shorter when sharing works — so wall time must be attributable per stage.
This module provides the one timing primitive every hot module uses:

    with tracer().span("enumerate.level", level=3) as sp:
        out = expand_level(...)
    stats["t_level"] = sp.duration

Design points:

* **Always-on timing, opt-in recording.** A ``Span`` handle measures its
  duration whether or not tracing is enabled, so the engine's
  backward-compatible ``t_*`` stats are *derived views over spans* — one
  start/stop site, no duplicated ``perf_counter`` bookkeeping. Only when
  the tracer is enabled does the finished span land in the ring buffer
  (bounded memory; old spans are dropped, never the run).
* **Thread-aware nesting.** The span stack is thread-local, so replica
  worker threads (``distributed.ShardedExecutor``) produce their own
  root-level spans while the admission thread keeps its hierarchy; the
  ring buffer itself is shared (appends are atomic under the GIL).
* **Optional device fencing.** Async dispatch means a span can close
  before the device work it launched finishes. ``Span.fence(value)``
  marks arrays to ``block_until_ready`` at span exit *when the tracer was
  enabled with* ``fence=True`` — attribution at the cost of overlap, off
  by default so traced serving keeps its pipelining. The block function
  is injected lazily (jax import only on first fenced exit), keeping this
  module importable with no third-party dependency.
* **Chrome-trace export.** :meth:`Tracer.export` writes the standard
  ``traceEvents`` JSON that chrome://tracing and https://ui.perfetto.dev
  open directly; :func:`summarize` / :func:`coverage` aggregate a saved
  trace (also exposed via ``python -m repro.obs``).

Like the jit cache and :mod:`repro.core.compilelog`, the default tracer
is a process-wide singleton: ``EngineConfig.trace`` /
``PathSession(trace=True)`` / ``serve --trace`` all enable the same
recorder, so one export covers every engine and replica in the process.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

__all__ = ["Span", "Tracer", "tracer", "enable", "disable", "span",
           "summarize", "coverage", "load"]

_DEFAULT_CAPACITY = 1 << 16
_KEEP = object()          # configure() sentinel: leave annotator as-is


class Span:
    """One timed region: context-manager handle *and* finished record.

    ``duration`` is valid after exit; ``elapsed`` gives a mid-span
    reading (used for early-return stats). Attributes set at creation or
    via :meth:`set` ride into the exported trace's ``args``.
    """

    __slots__ = ("name", "attrs", "t0", "t1", "tid", "depth",
                 "_tracer", "_fence", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self._tracer = tracer
        self.t0 = 0.0
        self.t1 = 0.0
        self.tid = 0
        self.depth = 0
        self._fence: Any = None
        self._ann = None

    # -- context manager ----------------------------------------------
    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        ann = tr.annotator
        if ann is not None and tr.enabled:
            self._ann = ann(self.name)
            self._ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        tr = self._tracer
        if self._fence is not None and tr.fence:
            tr._block(self._fence)
        self.t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
            self._ann = None
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:            # exception skipped inner exits
            del stack[stack.index(self):]
        if tr.enabled:
            if exc_type is not None:
                self.attrs = dict(self.attrs, error=exc_type.__name__)
            tr._record(self)

    # -- API -----------------------------------------------------------
    @property
    def duration(self) -> float:
        """Seconds between enter and exit (0.0 while still open)."""
        return max(self.t1 - self.t0, 0.0)

    @property
    def elapsed(self) -> float:
        """Seconds since enter, readable mid-span (early returns)."""
        return time.perf_counter() - self.t0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes after creation (e.g. a hit flag
        known only once the work ran)."""
        self.attrs = dict(self.attrs, **attrs)
        return self

    def fence(self, value) -> "Span":
        """Mark ``value`` (array/pytree) to block on at exit when the
        tracer runs with ``fence=True``; a no-op otherwise."""
        self._fence = value
        return self


class Tracer:
    """Ring-buffered span recorder with thread-local span stacks."""

    def __init__(self, enabled: bool = False,
                 capacity: int = _DEFAULT_CAPACITY,
                 fence: bool = False,
                 annotator: Optional[Callable[[str], Any]] = None):
        self.enabled = enabled
        self.fence = fence
        # annotator: name -> context manager entered for the span's
        # lifetime (jaxprof.attach installs jax.profiler.TraceAnnotation
        # so host spans also appear on the device timeline)
        self.annotator = annotator
        self._buf: deque = deque(maxlen=int(capacity))
        self._local = threading.local()
        self.t_origin = time.perf_counter()
        self._block_fn: Optional[Callable] = None

    # -- span creation -------------------------------------------------
    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, sp: Span) -> None:
        self._buf.append(sp)

    def _block(self, value) -> None:
        if self._block_fn is None:
            try:
                import jax
                self._block_fn = jax.block_until_ready
            except Exception:            # fencing degrades to a no-op
                self._block_fn = lambda v: v
        self._block_fn(value)

    # -- lifecycle -----------------------------------------------------
    def configure(self, *, enabled: Optional[bool] = None,
                  fence: Optional[bool] = None,
                  capacity: Optional[int] = None,
                  annotator=_KEEP) -> "Tracer":
        if enabled is not None:
            self.enabled = enabled
        if fence is not None:
            self.fence = fence
        if capacity is not None and capacity != self._buf.maxlen:
            self._buf = deque(self._buf, maxlen=int(capacity))
        if annotator is not _KEEP:
            self.annotator = annotator
        return self

    def reset(self) -> "Tracer":
        """Drop recorded spans and re-zero the export time origin."""
        self._buf.clear()
        self.t_origin = time.perf_counter()
        return self

    # -- queries / export ----------------------------------------------
    def __len__(self) -> int:
        return len(self._buf)

    def spans(self) -> list[Span]:
        """Finished spans, oldest first (a snapshot copy)."""
        return list(self._buf)

    def to_chrome(self) -> dict:
        """Chrome-trace ``traceEvents`` dict (complete 'X' events in
        microseconds; opens in chrome://tracing and Perfetto)."""
        pid = os.getpid()
        events = []
        tids = {}
        for sp in self._buf:
            events.append({
                "name": sp.name, "ph": "X", "pid": pid, "tid": sp.tid,
                "ts": (sp.t0 - self.t_origin) * 1e6,
                "dur": (sp.t1 - sp.t0) * 1e6,
                "cat": sp.name.split(".", 1)[0],
                "args": {**{k: _jsonable(v) for k, v in sp.attrs.items()},
                         "depth": sp.depth},
            })
            tids.setdefault(sp.tid, len(tids))
        for tid, i in tids.items():
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid,
                           "args": {"name": f"hcsp-{i}" if i else "main"}})
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def export(self, path) -> dict:
        """Write the Chrome-trace JSON to ``path``; returns the dict."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# ----------------------------------------------------------------------
# saved-trace analysis (shared by the CLI and the CI obs gate)
# ----------------------------------------------------------------------
def load(path) -> dict:
    with open(path) as f:
        return json.load(f)


def _complete_events(doc: dict) -> list[dict]:
    return [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]


def summarize(doc: dict) -> list[dict]:
    """Aggregate a Chrome-trace dict per span name: count, total/mean/max
    duration (ms), sorted by total descending."""
    agg: dict[str, list] = {}
    for e in _complete_events(doc):
        a = agg.setdefault(e["name"], [0, 0.0, 0.0])
        a[0] += 1
        a[1] += e.get("dur", 0.0)
        a[2] = max(a[2], e.get("dur", 0.0))
    rows = [{"name": name, "count": c, "total_ms": tot / 1e3,
             "mean_ms": tot / max(c, 1) / 1e3, "max_ms": mx / 1e3}
            for name, (c, tot, mx) in agg.items()]
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def coverage(doc: dict, root: str = "engine.run",
             occurrence: int = -1) -> float:
    """Fraction of a root span's wall covered by its direct children.

    Picks the ``occurrence``-th event named ``root`` (default: last, i.e.
    the warm run), then sums the durations of same-thread events one
    level deeper that fall inside its interval. This is the acceptance
    metric: per-stage durations must explain >= 90% of the batch wall,
    or the span taxonomy has a hole.
    """
    events = _complete_events(doc)
    roots = [e for e in events if e["name"] == root]
    if not roots:
        return 0.0
    r = sorted(roots, key=lambda e: e["ts"])[occurrence]
    r_depth = r.get("args", {}).get("depth", 0)
    lo, hi = r["ts"], r["ts"] + r.get("dur", 0.0)
    child = sum(
        e.get("dur", 0.0) for e in events
        if e is not r and e["tid"] == r["tid"]
        and e.get("args", {}).get("depth") == r_depth + 1
        and lo <= e["ts"] and e["ts"] + e.get("dur", 0.0) <= hi + 1.0)
    return min(child / r["dur"], 1.0) if r.get("dur") else 0.0


def stage_names(doc: dict) -> set:
    return {e["name"] for e in _complete_events(doc)}


# ----------------------------------------------------------------------
# the process-wide default tracer
# ----------------------------------------------------------------------
_TRACER = Tracer(enabled=False)


def tracer() -> Tracer:
    """The process-wide tracer (disabled until :func:`enable`)."""
    return _TRACER


def span(name: str, **attrs) -> Span:
    """Convenience: a span on the process-wide tracer (for modules that
    have no engine handle, e.g. the lazy host transfer in ``query.py``)."""
    return _TRACER.span(name, **attrs)


def enable(*, fence: bool = False, annotate: bool = False,
           capacity: Optional[int] = None) -> Tracer:
    """Enable (and return) the process-wide tracer.

    fence : block_until_ready fenced values at span exit (attribute
        device work to the launching span; costs dispatch overlap).
    annotate : wrap each span in a ``jax.profiler.TraceAnnotation`` so
        spans show up on the device timeline of a jax profiler trace.
    Idempotent; repeated calls reconfigure the same singleton.
    """
    ann = _TRACER.annotator
    if annotate:
        from . import jaxprof
        ann = jaxprof.annotation_factory()
    elif annotate is False:
        ann = None
    _TRACER.enabled = True
    _TRACER.fence = bool(fence)
    _TRACER.annotator = ann
    if capacity is not None:
        _TRACER.configure(capacity=capacity)
    return _TRACER


def disable() -> Tracer:
    """Stop recording (span handles keep timing; nothing is stored)."""
    _TRACER.enabled = False
    return _TRACER
