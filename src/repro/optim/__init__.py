from .adamw import adamw_init, adamw_update, cosine_schedule
from .compress import compress_int8, decompress_int8, ef_compressed_psum

__all__ = ["adamw_init", "adamw_update", "cosine_schedule",
           "compress_int8", "decompress_int8", "ef_compressed_psum"]
