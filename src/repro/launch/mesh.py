"""Production meshes. Functions only — importing never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_by_name"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices (CPU tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_by_name(name: str):
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "host":
        return make_host_mesh()
    raise KeyError(name)
