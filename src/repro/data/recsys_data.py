"""Synthetic recsys interaction stream (Zipf item popularity)."""
from __future__ import annotations

import numpy as np

from ..config import RecsysConfig

__all__ = ["InteractionStream"]


class InteractionStream:
    def __init__(self, cfg: RecsysConfig, batch: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, H = self.batch, self.cfg.n_user_hist
        items = (rng.zipf(1.3, size=(B,)) - 1) % self.cfg.n_items
        hist = (rng.zipf(1.3, size=(B, H)) - 1) % self.cfg.n_items
        # pad short histories with -1
        lens = rng.integers(1, H + 1, size=(B,))
        mask = np.arange(H)[None, :] < lens[:, None]
        hist = np.where(mask, hist, -1)
        # uniform-sampler logQ correction (Zipf popularity estimate)
        freq = 1.0 / (1.0 + items.astype(np.float64)) ** 1.3
        logq = np.log(freq / freq.sum() * B).astype(np.float32)
        return {"hist_ids": hist.astype(np.int32),
                "item_ids": items.astype(np.int32),
                "sampling_logq": logq}
