"""Property-based engine invariants (hypothesis).

hypothesis is an optional [test] extra; without it this whole module
degrades to a skip instead of a collection error.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import BatchPathEngine, EngineConfig  # noqa: E402
from repro.core.graph import Graph  # noqa: E402
from repro.core import generators  # noqa: E402

from test_engine import _run_and_compare  # noqa: E402

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@given(st.integers(10, 60), st.integers(10, 160), st.integers(0, 30),
       st.integers(2, 5))
@settings(max_examples=12, deadline=None)
def test_property_batch_equals_oracle(n, m, seed, k):
    """Property: for ANY random digraph and query set, batch mode returns
    exactly the oracle's simple-path set (no dupes, no misses)."""
    r = np.random.default_rng(seed)
    g = Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))
    pairs = set()
    while len(pairs) < 4:
        s, t = int(r.integers(0, n)), int(r.integers(0, n))
        if s != t:
            pairs.add((s, t))
    qs = [(s, t, k) for s, t in pairs]
    _run_and_compare(g, qs, "batch")


@given(st.integers(10, 60), st.integers(10, 160), st.integers(0, 30),
       st.integers(2, 5))
@settings(max_examples=12, deadline=None)
def test_property_auto_equals_forced_planners(n, m, seed, k):
    """Property: for ANY random digraph and query set, cost-routed AUTO
    returns exactly the same path sets as the forced planners (routing
    may only move wall time, never results)."""
    r = np.random.default_rng(seed)
    g = Graph.from_edges(n, r.integers(0, n, m), r.integers(0, n, m))
    pairs = set()
    while len(pairs) < 4:
        s, t = int(r.integers(0, n)), int(r.integers(0, n))
        if s != t:
            pairs.add((s, t))
    qs = [(s, t, k) for s, t in pairs]
    auto = _run_and_compare(g, qs, "auto")
    forced = _run_and_compare(g, qs, "batch")
    for qi in range(len(qs)):
        got_a = {tuple(int(x) for x in row if x >= 0)
                 for row in auto.paths[qi]}
        got_f = {tuple(int(x) for x in row if x >= 0)
                 for row in forced.paths[qi]}
        assert got_a == got_f, f"auto vs batch diverge on q{qi}"


@given(st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_property_results_are_simple_and_bounded(seed):
    g = generators.powerlaw(80, 3.0, seed=seed)
    qs = generators.random_queries(g, 4, (3, 5), seed=seed + 50)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))
    res = eng.process(qs, mode="batch")
    edge_set = {(int(s), int(t)) for s in range(g.n) for t in g.neighbors(s)}
    for qi, (s, t, k) in enumerate(qs):
        for row in res.paths[qi]:
            p = [int(x) for x in row if x >= 0]
            assert p[0] == s and p[-1] == t
            assert len(p) - 1 <= k                      # hop constraint
            assert len(set(p)) == len(p)                # simple
            for a, b in zip(p, p[1:]):                  # real edges
                assert (a, b) in edge_set
