"""int8 error-feedback gradient compression for the cross-pod all-reduce.

Cross-pod links are the scarcest bandwidth at 1000+ node scale (DCN between
pods vs ICI within). We compress pod-axis gradient all-reduces to int8 with
per-tensor scales and error feedback (the residual of quantization is
carried to the next step), following 1-bit Adam / EF-SGD practice: unbiased
enough for Adam while cutting cross-pod bytes 4x vs f32 (2x vs bf16).

Used inside shard_map over the "pod" axis; within-pod reduction stays full
precision (ICI is cheap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ef_compressed_psum"]


def compress_int8(x: jax.Array):
    """x (f32/bf16) -> (int8 codes, scale). Symmetric per-tensor scale."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def decompress_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def ef_compressed_psum(grad: jax.Array, error: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over `axis_name`.

    Returns (reduced_grad_f32, new_error). Call per gradient leaf inside
    shard_map; `error` is the persistent per-leaf EF buffer.
    """
    g = grad.astype(jnp.float32) + error
    # shared quantization grid: pmax of local scales (one scalar all-reduce),
    # so that psum of int codes is exact in the shared grid.
    amax = jnp.max(jnp.abs(g))
    smax = jax.lax.pmax(jnp.maximum(amax, 1e-12) / 127.0, axis_name)
    codes = jnp.clip(jnp.round(g / smax), -127, 127)
    reduced = jax.lax.psum(codes.astype(jnp.int32), axis_name).astype(jnp.float32) * smax
    new_error = g - codes.astype(jnp.float32) * smax  # EF: what was actually sent
    return reduced, new_error
