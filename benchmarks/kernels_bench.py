"""Kernel micro-benchmarks: jnp reference path timings on CPU (the Pallas
paths are TPU-target; interpret mode is not a performance proxy, so we
time the jnp twins that the engine actually executes here) plus working-set
documentation per kernel BlockSpec.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import record


def _bench(fn, *args, repeats=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def main(scale: float = 1.0) -> None:
    rng = np.random.default_rng(0)

    # MS-BFS hop: 200k vertices, 1.6M edges, 128 sources
    from repro.core.msbfs import msbfs_hop
    n, m, S = int(200_000 * scale), int(1_600_000 * scale), 128
    esrc = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
    edst = jnp.asarray(np.sort(rng.integers(0, n, m).astype(np.int32)))
    frontier = jnp.asarray((rng.random((n + 1, S)) < 0.05).astype(np.int8))
    f = jax.jit(lambda fr: msbfs_hop(fr, esrc, edst, n))
    dt = _bench(f, frontier)
    record("kernel_msbfs_hop_jnp", dt * 1e6,
           f"edges={m};sources={S};GTEPS={m * S / dt / 1e9:.2f}")

    # pairwise popcount (similarity): 128 queries x 200k vertices
    from repro.kernels.pairwise_popcount.ref import intersections_bool_ref
    g = jnp.asarray(rng.random((128, n)) < 0.1)
    f = jax.jit(intersections_bool_ref)
    dt = _bench(f, g)
    record("kernel_similarity_jnp", dt * 1e6, f"Q=128;V={n}")

    # path join overlap: 4096 x 4096 pairs, L=6
    from repro.kernels.path_join.ref import path_overlap_ref
    A = jnp.asarray(rng.integers(0, 1000, (4096, 6)).astype(np.int32))
    B = jnp.asarray(rng.integers(0, 1000, (4096, 6)).astype(np.int32))
    f = jax.jit(path_overlap_ref)
    dt = _bench(f, A, B)
    record("kernel_path_join_jnp", dt * 1e6,
           f"pairs={4096 * 4096};Mpairs_s={4096 * 4096 / dt / 1e6:.1f}")

    # ELL SpMM: 100k x deg16 x 128 feats
    from repro.kernels.ell_spmm.ref import ell_spmm_ref
    V, D, F = int(100_000 * scale), 16, 128
    ell = jnp.asarray(rng.integers(0, V + 1, (V, D)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((V + 1, F)).astype(np.float32))
    f = jax.jit(lambda e, xx: ell_spmm_ref(e, xx, "sum"))
    dt = _bench(f, ell, x)
    record("kernel_ell_spmm_jnp", dt * 1e6,
           f"gflops={2 * V * D * F / dt / 1e9:.1f}")

    # chunked attention (flash twin): B4 S2048 H8 hd64
    from repro.models.transformer import chunked_attention
    q = jnp.asarray(rng.standard_normal((4, 2048, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((4, 2048, 2, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True,
                                                  q_offset=0, chunk=512))
    dt = _bench(f, q, k, k)
    flops = 4 * 4 * 2048 * 2048 * 8 * 64 / 2
    record("kernel_attention_jnp", dt * 1e6,
           f"gflops={flops / dt / 1e9:.1f}")


if __name__ == "__main__":
    main()
