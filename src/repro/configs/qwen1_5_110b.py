"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from ..config import LMConfig
from ._shapes import LM_SHAPES as SHAPES  # noqa: F401

CONFIG = LMConfig(name="qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64,
                  n_kv_heads=8, d_ff=49152, vocab=152064, qkv_bias=True)

REDUCED = LMConfig(name="qwen1.5-110b-reduced", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=192, vocab=256,
                   qkv_bias=True, dtype="float32")

FAMILY = "lm"
