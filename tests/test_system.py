"""End-to-end behaviour tests for the paper's system.

Scenario: a batch of HC-s-t path queries arrives at a serving cluster; the
engine clusters them, builds sharing plans, enumerates with reuse, and the
scheduler distributes clusters across replica groups with work stealing —
results identical to sequential processing, duplicates-free, oracle-exact.
Uses the typed run()/BatchReport API throughout (the deprecated process()
shim is covered by tests/test_engine.py and tests/test_query_api.py).
"""
from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from repro.core.oracle import enumerate_paths_bruteforce, path_set
from repro.ft.scheduler import WorkStealingScheduler


def test_end_to_end_batch_serving():
    g = generators.community(120, n_comm=3, avg_deg=4.0, seed=1)
    queries = generators.similar_queries(g, 12, similarity=0.7,
                                         k_range=(3, 4), seed=2)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64, gamma=0.5))
    res = eng.run(queries)
    # results must match both the basic engine and the oracle
    basic = eng.run(queries, planner="basic")
    for qi, (s, t, k) in enumerate(queries):
        got = path_set(res[qi].paths)
        assert got == path_set(basic[qi].paths)
        assert got == path_set(enumerate_paths_bruteforce(g, s, t, k))
    assert res.stats["t_enumerate"] > 0
    assert res.stats["n_clusters"] >= 1
    assert all(r.time_s >= 0 for r in res)


def test_sharing_reduces_expansion_work():
    """With identical queries, the shared run must materialize fewer
    enumeration nodes than |Q| independent runs would."""
    g = generators.community(100, n_comm=1, avg_deg=5.0, seed=3)
    base = generators.random_queries(g, 1, (4, 4), seed=4)[0]
    queries = [base] * 6
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))
    res = eng.run(queries)
    # identical queries collapse to one half-query per direction
    assert res.stats["n_clusters"] == 1
    for qi in range(6):
        assert path_set(res[qi].paths) == path_set(res[0].paths)


def test_cluster_scheduler_pipeline():
    """Distribute clusters to 2 replica groups, steal, crash one group,
    and still produce complete results."""
    g = generators.community(100, n_comm=4, avg_deg=4.0, seed=5)
    queries = generators.similar_queries(g, 10, similarity=0.8,
                                         k_range=(3, 3), seed=6)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))

    # plan clusters exactly as the engine would
    from repro.core import build_index
    from repro.core.similarity import similarity_matrix
    from repro.core.clustering import cluster_queries
    index = build_index(eng.dg, queries)
    mu = similarity_matrix(index)
    clusters = cluster_queries(mu, 0.5)

    sched = WorkStealingScheduler(n_groups=2,
                                  cost_fn=lambda qs: float(len(qs)))
    sched.submit(clusters)

    # group 0 crashes mid-flight once
    crashed = {"done": False}
    results = {}
    while sched.pending():
        for grp in (0, 1):
            item = sched.next_for(grp)
            if item is None:
                continue
            if grp == 0 and not crashed["done"]:
                crashed["done"] = True
                sched.fail_group(0, [item.cluster_id])
                continue
            sub = [queries[qi] for qi in item.queries]
            r = eng.run(sub)
            results.update({item.queries[i]: r[i]
                            for i in range(len(sub))})
            sched.complete(item.cluster_id, True)

    assert len(results) == len(queries)
    for qi, (s, t, k) in enumerate(queries):
        assert path_set(results[qi].paths) == \
            path_set(enumerate_paths_bruteforce(g, s, t, k))


def test_engine_scales_with_reuse_quality():
    """The similar-queries generator really produces overlapping workloads
    (Exp-1's mechanism) and the engine's stats expose it."""
    g = generators.community(150, n_comm=1, avg_deg=5.0, seed=7)
    queries = generators.similar_queries(g, 8, similarity=1.0,
                                         k_range=(4, 4), seed=8)
    eng = BatchPathEngine(g, EngineConfig(min_cap=64))
    rb = eng.run(queries)
    assert rb.stats["mu_mean"] > 0.3
