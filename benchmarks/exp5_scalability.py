"""Exp-5 (Fig 11): scalability with graph size (20%..100% samples), plus
the sharded-execution arm (exp5s).

Paper claim: all engines grow with graph size; BatchEnum(+) stays fastest.
The sharded arm (``sharded_main``) measures cluster-parallel BatchEnum
over every visible local device against the identical single-device
engine: results must be bit-equal, the warm loop must not retrace, and
the warm wall should drop with devices (CI runs it under 8 forced CPU
devices and gates the speedup — see benchmarks/check_regression.py).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import BatchPathEngine, EngineConfig
from repro.core import generators
from .common import default_graph, record, time_planner


def main(scale: float = 1.0) -> list[dict]:
    rows = []
    for frac in [0.2, 0.4, 0.6, 0.8, 1.0]:
        g = default_graph(scale * frac, seed=6)
        eng = BatchPathEngine(g, EngineConfig(min_cap=128))
        qs = generators.similar_queries(g, 20, similarity=0.6,
                                        k_range=(5, 5), seed=7)
        t_basic, _ = time_planner(eng, qs, "basic")
        t_batch, _ = time_planner(eng, qs, "batch")
        rows.append(dict(frac=frac, n=g.n, m=g.m, t_basic=t_basic,
                         t_batch=t_batch))
        record(f"exp5_frac{frac:.1f}_basic", t_basic * 1e6, f"n={g.n};m={g.m}")
        record(f"exp5_frac{frac:.1f}_batch", t_batch * 1e6,
               f"speedup={t_basic / t_batch:.2f}")
    return rows


def sharded_main(scale: float = 1.0) -> dict:
    """Exp-5s: sharded multi-device batch execution (needs several local
    devices to show a speedup — CI forces 8 virtual CPU devices; on one
    device it degenerates to an identity-parity check).

    Workload choice: low query similarity (many sharing clusters — the
    data-parallel work units) and a graph big enough that XLA compute,
    not Python orchestration, dominates the warm wall. Writes
    results/BENCH_sharded.json for the CI regression gate.
    """
    import jax

    n_dev = len(jax.devices())
    # 8 *disconnected* dense communities: Γ balls cannot cross components,
    # so clustering naturally yields one heavy sharing-cluster per
    # component — the data-parallel work units the mesh spreads. (A
    # connected community graph at k=6 merges into ONE cluster: every
    # 6-hop ball overlaps every other, and a single cluster cannot shard.)
    n = max(int(60_000 * scale), 2_000)
    g = generators.community(n, n_comm=8, avg_deg=7.0, p_intra=1.0, seed=6)
    qs = generators.random_queries(g, 32, k_range=(6, 6), seed=7)
    cfg = dict(min_cap=128, log_compiles=True)
    e1 = BatchPathEngine(g, EngineConfig(**cfg))
    eD = BatchPathEngine(g, EngineConfig(**cfg, n_devices=n_dev))

    # warm both engines (compiles + per-device executables), then time
    for _ in range(2):
        r1 = e1.run(qs, planner="batch")
        rD = eD.run(qs, planner="batch")
    equal = all(np.array_equal(r1[qi].paths, rD[qi].paths)
                for qi in range(len(qs)))

    def timed(engine, repeats=3):
        walls, retraces = [], 0
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = engine.run(qs, planner="batch")
            walls.append(time.perf_counter() - t0)
            retraces += r.stats.get("n_retraces", 0)
        return float(np.median(walls)), retraces, r

    t1, retr1, _ = timed(e1)
    tD, retrD, rD = timed(eD)
    warm_retraces = retr1 + retrD
    speedup = t1 / tD if tD > 0 else float("inf")
    import os
    out = {
        "n_devices": n_dev, "cpu_count": os.cpu_count(),
        "n": g.n, "m": g.m, "n_queries": len(qs),
        "n_clusters": rD.stats["n_clusters"],
        "t_single_warm_s": t1, "t_sharded_warm_s": tD,
        "speedup": speedup, "equal": bool(equal),
        "warm_retraces": int(warm_retraces),
        "per_device": rD.stats.get("per_device"),
    }
    # write the artifact BEFORE asserting: on a parity failure the
    # per-device walls/placement are exactly the data needed to debug,
    # and the CI gate (check_regression --sharded) re-judges the fields
    path = Path("results/BENCH_sharded.json")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    record("exp5s_single", t1 * 1e6, f"n={g.n};m={g.m}")
    record("exp5s_sharded", tD * 1e6,
           f"devices={n_dev};speedup={speedup:.2f}")
    assert equal, "sharded result diverged from single-device"
    assert warm_retraces == 0, f"warm loop retraced: {warm_retraces}"
    return out


if __name__ == "__main__":
    main()
