"""Public wrapper: neighbor aggregation over padded ELL with backend switch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..registry import BackendLike, dispatch, register_op
from .kernel import ell_spmm_pallas
from .ref import ell_spmm_ref

__all__ = ["ell_aggregate"]


register_op(
    "ell_spmm",
    pallas=lambda ell, xs, op: ell_spmm_pallas(ell, xs, op=op),
    interpret=lambda ell, xs, op: ell_spmm_pallas(ell, xs, op=op,
                                                  interpret=True),
    jnp=ell_spmm_ref,
)


def ell_aggregate(ell_idx: jax.Array, x: jax.Array, op: str = "sum",
                  backend: BackendLike = None) -> jax.Array:
    """x: (V, F) node features -> (V, F) aggregated over out-neighbors.

    Appends the neutral sentinel row internally (pad index = V).
    """
    neutral = jnp.zeros((1, x.shape[1]), x.dtype) if op == "sum" else \
        jnp.full((1, x.shape[1]), -jnp.inf, x.dtype)
    xs = jnp.concatenate([x, neutral], axis=0)
    out = dispatch("ell_spmm", backend)(ell_idx, xs, op)
    if op == "max":
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    return out
