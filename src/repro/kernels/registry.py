"""Unified kernel-backend registry: one typed enum, one dispatch table.

Every compute hot spot the engine can route through a Pallas kernel is
registered here as a named *op* with three interchangeable
implementations:

  ``pallas``    -- the TPU kernel (pl.pallas_call; fails to lower on CPU)
  ``interpret`` -- the same kernel body under the Pallas interpreter
                   (CPU-runnable, bit-equal to ``pallas``; the CI parity
                   backend, not a performance proxy)
  ``jnp``       -- the pure-jnp segment-op reference twin (the default
                   everywhere off-TPU; property-tested bit-equal)

Backend resolution order (``resolve_backend``):

  1. an explicit value (string or :class:`KernelBackend`) wins;
  2. else the ``REPRO_KERNEL_BACKEND`` environment variable;
  3. else auto: ``pallas`` on TPU, ``jnp`` elsewhere.

Unknown names raise ``ValueError`` listing the valid backends — there is
deliberately no silent fallback (misspelling "pallas" must not quietly
run the reference path).

Op tables self-register when a kernel package's ``ops`` module imports;
:func:`dispatch` lazily imports the owning module, so callers never need
to pre-import kernel packages.
"""
from __future__ import annotations

import enum
import importlib
import os
from typing import Callable, Union

__all__ = ["KernelBackend", "BackendLike", "resolve_backend", "register_op",
           "dispatch", "registered_ops", "op_manifest", "ENV_VAR"]

ENV_VAR = "REPRO_KERNEL_BACKEND"

BackendLike = Union["KernelBackend", str, None]


class KernelBackend(str, enum.Enum):
    """Typed kernel-backend selector (str subclass: compares to its value)."""

    PALLAS = "pallas"
    INTERPRET = "interpret"
    JNP = "jnp"

    @classmethod
    def coerce(cls, value: Union["KernelBackend", str]) -> "KernelBackend":
        if isinstance(value, cls):
            return value
        try:
            return cls(str(value).lower())
        except ValueError:
            raise ValueError(
                f"unknown kernel backend {value!r}; valid backends: "
                f"{' | '.join(b.value for b in cls)}") from None

    @property
    def uses_kernel(self) -> bool:
        """True when the Pallas kernel body runs (compiled or interpreted)."""
        return self is not KernelBackend.JNP

    def __str__(self) -> str:  # str(Enum) would print "KernelBackend.JNP"
        return self.value


def resolve_backend(backend: BackendLike = None) -> KernelBackend:
    """Resolve an explicit/env/auto backend choice to a KernelBackend.

    Raises ``ValueError`` (listing the valid names) on unknown values —
    including an unknown ``REPRO_KERNEL_BACKEND`` — so a typo surfaces at
    config time, not as a silently different code path.
    """
    if backend is None:
        backend = os.environ.get(ENV_VAR) or None
    if backend is None:
        import jax
        return (KernelBackend.PALLAS if jax.default_backend() == "tpu"
                else KernelBackend.JNP)
    return KernelBackend.coerce(backend)


# ---------------------------------------------------------------------------
# per-op dispatch table
# ---------------------------------------------------------------------------

# op name -> module that registers it (imported lazily on first dispatch)
_OP_MODULES = {
    "msbfs_expand": "repro.kernels.msbfs_expand.ops",
    "msbfs_step": "repro.kernels.msbfs_expand.ops",
    "path_overlap": "repro.kernels.path_join.ops",
    "rowwise_overlap": "repro.kernels.path_join.ops",
    "path_member": "repro.kernels.path_join.ops",
    "ell_spmm": "repro.kernels.ell_spmm.ops",
    "pairwise_popcount": "repro.kernels.pairwise_popcount.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
}

_TABLE: dict[str, dict[KernelBackend, Callable]] = {}


def register_op(name: str, *, pallas: Callable, interpret: Callable,
                jnp: Callable) -> None:
    """Register the three backend implementations of one op."""
    _TABLE[name] = {KernelBackend.PALLAS: pallas,
                    KernelBackend.INTERPRET: interpret,
                    KernelBackend.JNP: jnp}


def dispatch(name: str, backend: BackendLike = None) -> Callable:
    """The implementation of op ``name`` for the resolved ``backend``."""
    kb = resolve_backend(backend)
    if name not in _TABLE:
        if name not in _OP_MODULES:
            raise KeyError(f"unknown kernel op {name!r}; registered ops: "
                           f"{registered_ops()}")
        importlib.import_module(_OP_MODULES[name])
        if name not in _TABLE:   # module imported but forgot to register
            raise KeyError(f"kernel op {name!r} not registered by "
                           f"{_OP_MODULES[name]}")
    return _TABLE[name][kb]


def registered_ops() -> list[str]:
    """Every known op name (registered or lazily registrable)."""
    return sorted(set(_TABLE) | set(_OP_MODULES))


def op_manifest() -> dict[str, str]:
    """Op name -> owning ops-module path, for every known op.

    The static analyzer (``repro.analysis.jaxpr_audit``) consumes this to
    enforce audit coverage: a newly registered op must either appear in
    the audit manifest or be explicitly listed as exempt — registering
    kernel math that no static check ever traces is itself a finding.
    """
    return dict(sorted(_OP_MODULES.items()))
