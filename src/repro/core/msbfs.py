"""Bit-parallel multi-source BFS (the paper's BuildIndex, Alg 1/4 lines 1-2).

TPU adaptation of "The More the Merrier" MS-BFS [36]: instead of per-source
queues, the frontier is a dense (n+1, S) int8/bool matrix (one column per
source; row n is a sentinel for padded ELL gathers). One hop is an
edge-gather + ``segment_max`` (max == OR on {0,1}), i.e. a sparse-matrix ×
dense-frontier product in the boolean semiring — MXU/VPU-friendly and
shardable.

Two backends:
  * ``jnp``    -- reference path used everywhere (chunked edge gathers).
  * ``pallas`` -- bit-packed ELL OR-gather kernel (kernels/msbfs_expand),
                  validated against this reference in interpret mode.

Distances are int8 (k_max <= 120); unreached = INF = k_max + 1.

Sentinel padding: edge lists may be pow2-bucketed with sentinel edges
``(n, n)`` (``graph.pad_edge_list``). A sentinel edge gathers the all-zero
frontier row ``n`` and its ``edst = n`` falls outside ``num_segments = n``,
so segment reductions drop it — padded and exact edge lists are
bit-equivalent. Callers pass ``m_valid`` (the chunk-rounded valid-edge
span from :func:`edge_span`) so the chunk loop skips all-sentinel chunks;
it is a static jit argument, which is why it must be pre-rounded — raw
per-delta edge counts would retrace on every mutation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["msbfs_dist", "msbfs_set_dist", "msbfs_hop", "msbfs_dist_ell",
           "msbfs_set_dist_ell", "INF_FOR", "edge_span", "K_MAX_INT8"]

# Largest hop budget the int8 distance representation supports. INF_FOR
# (k_max + 1) must stay representable AND keep headroom below int8 max
# for downstream +1/-offset hop arithmetic (prune tables, splice
# budgets); 120 leaves 127 - 121 = 6 values of slack above the sentinel.
K_MAX_INT8 = 120
_INT8_MAX = 127


def INF_FOR(k_max: int) -> int:
    return k_max + 1


def _check_k_max(k_max: int) -> None:
    """Static int8-range guard for the sweep entry points.

    ``k_max`` is a static jit argument, so this raises at trace time —
    before any device work — instead of silently clamping (the historical
    behaviour) and computing wrong-radius distances.
    """
    if not 0 <= int(k_max) <= K_MAX_INT8:
        raise ValueError(
            f"k_max={k_max} out of range for int8 MS-BFS distances: "
            f"requires 0 <= k_max <= K_MAX_INT8={K_MAX_INT8} so the "
            f"sentinel INF_FOR(k_max)={int(k_max) + 1} fits int8 "
            f"(max {_INT8_MAX}) with {_INT8_MAX - K_MAX_INT8 - 1} values "
            f"of headroom above INF for downstream hop arithmetic; "
            f"reduce the hop budget (or bucket it) before the sweep")


def edge_span(m_valid: int, edge_chunk: int, m_cap: int) -> int:
    """Chunk-rounded prefix of a sentinel-padded edge list that the chunked
    sweeps must visit: ``m_valid`` rounded *up* to an ``edge_chunk``
    multiple, clamped to ``m_cap``. Rounding up means every edge count
    inside one chunk-granule maps to the same static value — in-bucket
    churn cannot retrace a kernel, only crossing a chunk (or bucket)
    boundary can."""
    if m_valid >= m_cap:
        return int(m_cap)
    return int(min(-(-int(m_valid) // int(edge_chunk)) * int(edge_chunk),
                   m_cap))


def msbfs_hop(frontier: jax.Array, esrc: jax.Array, edst: jax.Array,
              n: int, edge_chunk: int = 1 << 22,
              m_valid: Optional[int] = None) -> jax.Array:
    """One BFS relaxation: next[v, s] = OR over edges (u->v) frontier[u, s].

    frontier: (n+1, S) int8 in {0,1} (row n = sentinel zeros).
    m_valid: chunk-rounded valid-edge span (see :func:`edge_span`); None
    sweeps the full (possibly sentinel-padded) list — correct either way,
    the rounding only skips provably all-sentinel chunks.
    Returns (n+1, S) int8.
    """
    S = frontier.shape[1]
    m = esrc.shape[0]
    m_used = m if m_valid is None else min(int(m_valid), m)
    nxt = jnp.zeros((n, S), dtype=jnp.int8)
    # static chunking keeps the (Ec, S) gather bounded; a whole-list
    # sweep (the common case — m fits one chunk) skips the slice ops
    # entirely, so a GSPMD-sharded edge list is gathered shard-local
    # instead of being resharded at a mid-shard slice boundary
    for lo in range(0, m_used, edge_chunk):
        hi = min(lo + edge_chunk, m)
        es, ed = (esrc, edst) if lo == 0 and hi == m \
            else (esrc[lo:hi], edst[lo:hi])
        msgs = frontier[es]                               # (Ec, S) int8
        part = jax.ops.segment_max(msgs, ed, num_segments=n,
                                   indices_are_sorted=True)
        nxt = jnp.maximum(nxt, part)
    return jnp.concatenate([nxt, jnp.zeros((1, S), jnp.int8)], axis=0)


@partial(jax.jit, static_argnames=("n", "k_max", "edge_chunk", "m_valid"))
def msbfs_set_dist(esrc: jax.Array, edst: jax.Array, seed_mask: jax.Array,
                   *, n: int, k_max: int, edge_chunk: int = 1 << 22,
                   m_valid: Optional[int] = None) -> jax.Array:
    """Distance from a vertex *set*: one bit-column seeded with every
    member, so ``dist[v] = min over seeds of hops(seed -> v)`` in a single
    S=1 sweep. This is what hop-scoped cache invalidation asks ("how close
    is the nearest touched vertex?") — one compile per (n, k_max) instead
    of one per frontier size.

    seed_mask : (n+1,) int8 in {0,1} (row n must be 0).
    Returns (n+1,) int8 with unreached = INF = k_max + 1, row n = INF.
    """
    _check_k_max(k_max)
    INF = np.int8(INF_FOR(k_max))
    seed = seed_mask.astype(jnp.int8)[:, None]          # (n+1, 1)
    dist = jnp.where(seed[:, 0].astype(bool), jnp.int8(0), INF)
    frontier = seed
    for hop in range(1, k_max + 1):
        # named_scope tags this hop's HLO ops for profiler device
        # timelines (metadata only: zero jaxpr eqns, budgets unaffected)
        with jax.named_scope(f"msbfs.hop{hop}"):
            reached = (dist < INF).astype(jnp.int8)
            nxt = msbfs_hop(frontier, esrc, edst, n, edge_chunk, m_valid)
            new = nxt * (1 - reached)[:, None]
            dist = jnp.where(new[:, 0].astype(bool), jnp.int8(hop), dist)
            frontier = new.at[n].set(0)
    return dist.at[n].set(INF)


@partial(jax.jit, static_argnames=("n", "k_max", "edge_chunk", "m_valid"))
def msbfs_dist(esrc: jax.Array, edst: jax.Array, sources: jax.Array,
               *, n: int, k_max: int, edge_chunk: int = 1 << 22,
               m_valid: Optional[int] = None) -> jax.Array:
    """Distances from each source, capped at k_max.

    esrc/edst : (m,) int32 edges sorted by dst (use reverse edges for G_r).
    sources   : (S,) int32 (padded entries may repeat; they are independent).
    Returns dist (n+1, S) int8; dist[v, i] = min(hops(sources[i] -> v), INF),
    row n is INF (sentinel for padded gathers).
    """
    _check_k_max(k_max)
    S = sources.shape[0]
    INF = np.int8(INF_FOR(k_max))
    dist = jnp.full((n + 1, S), INF, dtype=jnp.int8)
    dist = dist.at[sources, jnp.arange(S)].min(jnp.int8(0))
    frontier = jnp.zeros((n + 1, S), jnp.int8).at[sources, jnp.arange(S)].set(1)
    for hop in range(1, k_max + 1):
        with jax.named_scope(f"msbfs.hop{hop}"):
            reached = (dist < INF).astype(jnp.int8)
            nxt = msbfs_hop(frontier, esrc, edst, n, edge_chunk, m_valid)
            new = nxt * (1 - reached)                      # newly reached only
            dist = jnp.where(new.astype(bool), jnp.int8(hop), dist)
            frontier = new.at[n].set(0)
        # NOTE: no early exit under jit; k_max is small (<= 8 in the paper).
    return dist.at[n].set(INF)


# ---------------------------------------------------------------------------
# fused-kernel twins: bit-packed sweeps over the padded ELL in-neighbor
# table (kernels/msbfs_expand). One level = ONE device dispatch (expand +
# visited dedup + distance write fused in msbfs_step) instead of the
# segment-op path's gather / segment_max / mask-mul / where chain. The ELL
# tables are already sentinel-padded to stable pow2 capacities
# (DeviceGraph.build), so these sweeps inherit the zero-warm-retrace
# guarantee without edge chunking: m_valid has no analogue here because
# sentinel rows gather the all-zero frontier row n and contribute nothing.
#
# Direction convention (matches msbfs_dist's edge-list arguments):
# relaxation is next[v] = OR over in-neighbors u of v, so forward
# distances on G take the *reverse* table dg.r_ell_idx (out-neighbors in
# G_r == in-neighbors in G) and distances on G_r take dg.ell_idx.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n", "k_max", "backend"))
def msbfs_dist_ell(ell_in_idx: jax.Array, sources: jax.Array,
                   *, n: int, k_max: int, backend: str = "jnp") -> jax.Array:
    """Fused-kernel twin of :func:`msbfs_dist`.

    ell_in_idx : (n+1, D) int32 padded ELL *in*-neighbor table (pad = n;
                 row n is the sentinel row, never expanded).
    sources    : (S,) int32.
    backend    : static "pallas" | "interpret" | "jnp" (resolved by the
                 caller; a registry enum's value — strings keep the jit
                 cache key plain).
    Returns (n+1, S) int8, bit-equal to :func:`msbfs_dist` on the same
    graph (distances are set-membership facts; only the dispatch shape of
    a level differs between backends).
    """
    _check_k_max(k_max)
    from ..kernels.msbfs_expand.ops import msbfs_step, pack_bits

    S = sources.shape[0]
    W = -(-S // 32)
    INF = np.int8(INF_FOR(k_max))
    idx = ell_in_idx[:n]                                   # drop sentinel row
    cols = jnp.arange(S)
    seed_bits = jnp.zeros((n + 1, S), bool).at[sources, cols].set(True)
    seed_bits = seed_bits.at[n].set(False)                 # sentinel stays 0
    frontier = pack_bits(seed_bits)                        # (n+1, W)
    visited = frontier[:n]                                 # seeds reached @0
    dist = jnp.full((n, W * 32), INF, jnp.int8)
    dist = dist.at[sources, cols].min(jnp.int8(0))
    for hop in range(1, k_max + 1):
        with jax.named_scope(f"msbfs.hop{hop}"):
            frontier, visited, dist = msbfs_step(idx, frontier, visited,
                                                 dist, hop, backend=backend)
            frontier = jnp.concatenate(
                [frontier, jnp.zeros((1, W), jnp.uint32)], axis=0)
    dist = dist[:, :S]                                     # drop word padding
    return jnp.concatenate([dist, jnp.full((1, S), INF, jnp.int8)], axis=0)


@partial(jax.jit, static_argnames=("n", "k_max", "backend"))
def msbfs_set_dist_ell(ell_in_idx: jax.Array, seed_mask: jax.Array,
                       *, n: int, k_max: int,
                       backend: str = "jnp") -> jax.Array:
    """Fused-kernel twin of :func:`msbfs_set_dist` (one bit column seeded
    with the whole vertex set; 31 of the word's 32 lanes idle — the fused
    dispatch still wins by collapsing the per-level op chain).

    seed_mask : (n+1,) int8 in {0,1} (row n must be 0).
    Returns (n+1,) int8 bit-equal to :func:`msbfs_set_dist`.
    """
    _check_k_max(k_max)
    from ..kernels.msbfs_expand.ops import msbfs_step, pack_bits

    INF = np.int8(INF_FOR(k_max))
    idx = ell_in_idx[:n]
    seed = seed_mask.astype(bool).at[n].set(False)
    frontier = pack_bits(seed[:, None])                    # (n+1, 1)
    visited = frontier[:n]
    dist = jnp.full((n, 32), INF, jnp.int8)
    dist = dist.at[:, 0].set(jnp.where(seed[:n], jnp.int8(0), INF))
    for hop in range(1, k_max + 1):
        with jax.named_scope(f"msbfs.hop{hop}"):
            frontier, visited, dist = msbfs_step(idx, frontier, visited,
                                                 dist, hop, backend=backend)
            frontier = jnp.concatenate(
                [frontier, jnp.zeros((1, 1), jnp.uint32)], axis=0)
    return jnp.concatenate([dist[:, 0], jnp.full((1,), INF, jnp.int8)])
