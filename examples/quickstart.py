"""Quickstart: batch HC-s-t path query processing in five minutes.

    pip install -e .            # once (or: export PYTHONPATH=src)
    python examples/quickstart.py
"""
from repro.core import PathQuery, PathSession, EngineConfig
from repro.core import generators

# 1. a graph (use Graph.from_edges(n, src, dst) for your own edge lists)
g = generators.community(5000, n_comm=4, avg_deg=6.0, seed=0)
print(f"graph: {g.n} vertices, {g.m} edges")

# 2. a batch of hop-constrained s-t path queries. PathQuery is the typed
#    form; bare (s, t, k) tuples are coerced automatically.
queries = generators.similar_queries(g, 16, similarity=0.6, k_range=(4, 5),
                                     seed=1)
print(f"queries: {len(queries)}, e.g. {queries[0]}")

# 3. the session facade: one entry point for batch runs, streaming
#    submission, and graph mutation. The default planner is BatchEnum
#    (Alg 4) — clusters queries, detects shared HC-s path queries,
#    enumerates with computation reuse.
session = PathSession(g, EngineConfig(gamma=0.5))
report = session.run(queries)

for qi in range(3):
    r = report[qi]
    s, t, k = r.query
    show = [tuple(int(v) for v in p if v >= 0) for p in r.paths[:3]]
    print(f"q{qi} ({s}->{t}, k={k}): {r.count} paths, first: {show}")

print("stats:", {k: round(v, 4) if isinstance(v, float) else v
                 for k, v in report.stats.items()})

# 4. per-query output kinds: count-only and exists-only queries skip path
#    materialization entirely (the engine counts with reduction joins)
s, t, k = queries[0]
variants = session.run([PathQuery(s, t, k, output="count"),
                        PathQuery(s, t, k, output="exists"),
                        PathQuery(s, t, k, limit=2)])
print(f"count-only: {variants[0].count} paths, exists: {variants[1].exists}, "
      f"limit=2 returned {variants[2].paths.shape[0]} rows "
      f"(the whole variant batch assembled "
      f"{variants.stats['n_rows_assembled']} path rows — "
      f"only the limit query materialized any)")

# 5. compare against per-query processing (BasicEnum, Alg 1).
#    The first call of each planner pays jit compilation; compare warm runs.
session.run(queries, planner="basic")
basic = session.run(queries, planner="basic")
warm = session.run(queries)
t_b = basic.stats["t_enumerate"]
t_s = warm.stats["t_enumerate"]
print(f"enumeration (warm): basic {t_b:.3f}s vs batch {t_s:.3f}s "
      f"(speedup {t_b / max(t_s, 1e-9):.2f}x; "
      f"{warm.stats['n_dedup']} deduped half-queries, "
      f"{warm.stats['n_share_edges']} sharing edges)")
